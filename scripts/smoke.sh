#!/usr/bin/env bash
# Post-test smoke check: run the quickstart example end-to-end (compress ->
# lower to DecodeGraph -> compile through the ProgramCache -> decode on device)
# and fail on any assertion or import error.
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python examples/quickstart.py
echo "smoke: OK"
