#!/usr/bin/env bash
# Benchmark smoke: run fig19 (end-to-end TPC-H movement+decode) at tiny scale
# and record the per-query Z_run / Zc_run / planned / measured makespans in
# BENCH_fig19.json, so every PR leaves a machine-readable perf datapoint
# (wall-clock is CPU-noisy; the planned-vs-baseline fields are deterministic
# given the measured timings and are the regression-relevant signal).
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH=src:.${PYTHONPATH:+:$PYTHONPATH} python - <<'EOF'
import json

from benchmarks import fig19_e2e

rows = fig19_e2e.main(quick=True)
out = {}
for line in rows:
    name, _, derived = line.split(",", 2)
    key = name.split("/", 1)[1]
    fields = dict(kv.split("=", 1) for kv in derived.split(";") if "=" in kv)
    if key.startswith("q"):
        out[key] = {k: fields[k] for k in
                    ("Z_run", "Zc_run", "planned", "measured",
                     "plan_fifo", "plan_johnson", "auto_chunk_kib",
                     "chunk_cols", "launches") if k in fields}
with open("BENCH_fig19.json", "w") as f:
    json.dump(out, f, indent=2, sort_keys=True)
    f.write("\n")
print(f"bench-smoke: wrote BENCH_fig19.json ({len(out)} queries)")
EOF
