#!/usr/bin/env bash
# Benchmark smoke: run fig19 (end-to-end TPC-H movement+decode) and fig20
# (multi-query serving) at tiny scale and record the per-query Z_run / Zc_run /
# planned / measured makespans plus the serving rows in BENCH_fig19.json, so
# every PR leaves a machine-readable perf datapoint (wall-clock is CPU-noisy;
# the planned-vs-baseline / shared-vs-naive fields are deterministic given the
# measured timings and are the regression-relevant signal).
#
# Guards (exit non-zero, failing CI loudly):
#   * planned makespan must not exceed the FIFO baseline on any row -- the
#     adaptive planner's documented invariant under the shared model;
#   * the GP-column Zc_run row (measured group-boundary chunked decode over
#     Group-Parallel / Non-Parallel columns) must be present;
#   * the decode-fused Q6 row must be present and fused must not be slower
#     than materialize-then-query (the late-materialization win, measured);
#   * the fig20 shared serving plan's aggregate makespan must not exceed the
#     naive per-query FIFO composition (the serve planner's dominance-by-
#     construction invariant), cross-query signature batching must reduce
#     decode launches on the closed mix, and the SLO policy's point-class
#     tail must not degrade past the naive composition.
#   * the fig21 sharded-decode rows must be PRESENT (a silently-skipped
#     multi-device benchmark would pass forever) and the modeled N=4 sharded
#     makespan must not exceed the single-device baseline -- the mesh
#     planner's dominance-by-construction invariant;
#   * the fig21 D2D rebalance rows must be present: the modeled fabric plan
#     must carry legs and strictly beat decode-in-place on the skewed
#     topology, and the measured run must be bit-exact, execute every
#     planned leg, and land shards on their requested placement devices;
#   * the async dispatch engine rows (fig19 worker-thread issuance, fig21
#     concurrent 4-device issuance) must be present, bit-exact, and within
#     a noise tolerance of the sequential path on the same plan, and the
#     fig20 open-loop background-drain row must show requests completing
#     with no explicit drain() call.
set -euo pipefail
cd "$(dirname "$0")/.."

# fig21 needs forced host devices, which must be set before jax initializes --
# so it runs in its OWN process and hands its rows to the guard step via file
FIG21_ROWS="$(mktemp)"
trap 'rm -f "$FIG21_ROWS"' EXIT
XLA_FLAGS="--xla_force_host_platform_device_count=4" \
PYTHONPATH=src:.${PYTHONPATH:+:$PYTHONPATH} python - "$FIG21_ROWS" <<'EOF'
import sys

from benchmarks import fig21_sharded

with open(sys.argv[1], "w") as f:
    for line in fig21_sharded.main(quick=True):
        f.write(line + "\n")
EOF

FIG21_ROWS="$FIG21_ROWS" \
PYTHONPATH=src:.${PYTHONPATH:+:$PYTHONPATH} python - <<'EOF'
import json
import os
import sys

from benchmarks import fig19_e2e, fig20_serving

rows = fig19_e2e.main(quick=True)
out = {}
for line in rows:
    name, _, derived = line.split(",", 2)
    key = name.split("/", 1)[1]
    fields = dict(kv.split("=", 1) for kv in derived.split(";") if "=" in kv)
    if key.startswith("fused_q"):
        out[key] = {k: fields[k] for k in
                    ("fused", "materialized", "sel", "chunks", "launches",
                     "traffic", "prefuse_traffic", "never_materialized")
                    if k in fields}
    elif key.startswith("q"):
        out[key] = {k: fields[k] for k in
                    ("Z_run", "Zc_run", "planned", "measured",
                     "plan_fifo", "plan_johnson", "auto_chunk_kib",
                     "chunk_cols", "launches", "gp_cols", "gp_chunk_cols",
                     "fused", "materialized", "fused_sel", "fused_cols")
                    if k in fields}
    elif key == "gp_columns":
        out["gp_columns"] = {k: fields[k] for k in
                             ("Zc_run", "gp_cols", "gp_chunk_cols")
                             if k in fields}
    elif key == "async_overlap":
        out["async_overlap"] = fields
for line in fig20_serving.main(quick=True):
    name, _, derived = line.split(",", 2)
    key = "serving_" + name.split("/", 1)[1]
    out[key] = dict(kv.split("=", 1) for kv in derived.split(";") if "=" in kv)
with open(os.environ["FIG21_ROWS"]) as f:
    for line in f.read().splitlines():
        if not line.strip():
            continue
        name, _, derived = line.split(",", 2)
        key = name.split("/", 1)[1]
        out[key] = dict(kv.split("=", 1)
                        for kv in derived.split(";") if "=" in kv)
failures = []
for key, fields in out.items():
    if not key.startswith("q") or key.startswith("fused_"):
        continue
    planned = float(fields["planned"].rstrip("s"))
    fifo = float(fields["plan_fifo"].rstrip("s"))
    if planned > fifo * (1 + 1e-6):
        failures.append(f"{key}: planned {planned:.6f}s > FIFO {fifo:.6f}s")
if "gp_columns" not in out:
    failures.append("missing GP-column Zc_run row")
if "fused_q6" not in out:
    failures.append("missing decode-fused Q6 row")
else:
    fused = float(out["fused_q6"]["fused"].rstrip("s"))
    mat = float(out["fused_q6"]["materialized"].rstrip("s"))
    if fused > mat:
        failures.append(
            f"fused Q6 {fused:.4f}s slower than materialized {mat:.4f}s")
    traffic = int(out["fused_q6"]["traffic"])
    pre = int(out["fused_q6"]["prefuse_traffic"])
    if traffic >= pre:
        failures.append(
            f"fused Q6 traffic {traffic} not below pre-fusion {pre}")
for key in ("serving_closed_mix", "serving_open_loop", "serving_slo_mix"):
    if key not in out:
        failures.append(f"missing fig20 {key} row")
for key in ("serving_closed_mix", "serving_open_loop"):
    if key not in out:
        continue
    shared = float(out[key]["shared_mk"].rstrip("s"))
    naive = float(out[key]["naive_mk"].rstrip("s"))
    if shared > naive * (1 + 1e-6):
        failures.append(f"{key}: shared makespan {shared:.6f}s > "
                        f"naive per-query FIFO {naive:.6f}s")
if "serving_closed_mix" in out:
    l_s = int(out["serving_closed_mix"]["launches"])
    l_n = int(out["serving_closed_mix"]["naive_launches"])
    if l_s >= l_n:
        failures.append(f"cross-query batching did not reduce launches "
                        f"({l_s} shared vs {l_n} naive)")
if "serving_slo_mix" in out:
    pt = float(out["serving_slo_mix"]["point_p99_mk"].rstrip("s"))
    pt_naive = float(out["serving_slo_mix"]["point_p99_naive_mk"].rstrip("s"))
    if pt > pt_naive * (1 + 1e-6):
        failures.append(f"SLO point p99 {pt:.6f}s exceeds naive composition "
                        f"{pt_naive:.6f}s")
# fig21 sharded decode: rows must exist (fail LOUDLY if the multi-device
# benchmark silently skipped), and the mesh planner's modeled N=4 makespan
# must not exceed the single-device baseline it dominates by construction
for key in ("sharded_model_n1", "sharded_model_n4"):
    if key not in out:
        failures.append(f"missing fig21 {key} row")
if "sharded_model_n4" in out:
    sharded = float(out["sharded_model_n4"]["sharded_mk"])
    single = float(out["sharded_model_n4"]["single_mk"])
    rr = float(out["sharded_model_n4"]["rr_mk"])
    if sharded > single * (1 + 1e-6):
        failures.append(f"sharded N=4 modeled makespan {sharded:.1f}us > "
                        f"single-device {single:.1f}us")
    if sharded > rr * (1 + 1e-6):
        failures.append(f"sharded N=4 modeled makespan {sharded:.1f}us > "
                        f"round-robin {rr:.1f}us")
if "sharded_measured_n4" in out and out["sharded_measured_n4"].get(
        "bit_exact") != "1":
    failures.append("sharded measured N=4 decode was not bit-exact")
# D2D rebalance tier: both rows must exist (a silently-skipped fabric
# benchmark would pass forever); the modeled fabric-rebalanced makespan must
# carry real legs and STRICTLY beat decode-in-place on the skewed topology;
# the measured run must stay bit-exact, execute every planned leg, and land
# shards on the requested placement devices
if "d2d_rebalance_model" not in out:
    failures.append("missing fig21 d2d_rebalance_model row")
else:
    redist = float(out["d2d_rebalance_model"]["redist_mk"])
    direct = float(out["d2d_rebalance_model"]["direct_mk"])
    if int(out["d2d_rebalance_model"]["n_legs"]) < 1:
        failures.append("d2d_rebalance_model carries no fabric legs")
    if not redist < direct:
        failures.append(f"d2d rebalance modeled makespan {redist:.1f}us does "
                        f"not beat decode-in-place {direct:.1f}us")
if "d2d_rebalance_measured" not in out:
    failures.append("missing fig21 d2d_rebalance_measured row")
else:
    f21d = out["d2d_rebalance_measured"]
    if f21d.get("bit_exact") != "1":
        failures.append("d2d rebalanced decode was not bit-exact")
    if f21d.get("legs") != f21d.get("planned_legs") or int(
            f21d.get("legs", "0")) < 1:
        failures.append(f"d2d executed legs {f21d.get('legs')} != planned "
                        f"{f21d.get('planned_legs')} (or zero)")
    if f21d.get("placement_ok") != "1":
        failures.append("d2d rebalanced shards missed their requested "
                        "placement devices")
# async dispatch engine: worker-thread issuance must not regress past the
# inline sequential path on the same plan (both best-of-N, interleaved; a
# single-core host cannot show true overlap, so the guard is no-regression
# within a noise tolerance, not speedup).  The walls are ~20ms, so the
# tolerance absorbs scheduler noise; a real regression -- a serialization
# bug or a stalled worker -- shows as >=2x, which this still catches.
ASYNC_TOL = 1.25
if "async_overlap" not in out:
    failures.append("missing fig19 async_overlap row")
else:
    a = float(out["async_overlap"]["async"].rstrip("s"))
    s = float(out["async_overlap"]["sequential"].rstrip("s"))
    if a > s * ASYNC_TOL:
        failures.append(f"fig19 async dispatch {a:.4f}s regresses past "
                        f"sequential {s:.4f}s (tol {ASYNC_TOL}x)")
if "async_overlap_n4" not in out:
    failures.append("missing fig21 async_overlap_n4 row")
else:
    c = float(out["async_overlap_n4"]["concurrent"].rstrip("s"))
    s = float(out["async_overlap_n4"]["sequential"].rstrip("s"))
    if c > s * ASYNC_TOL:
        failures.append(f"fig21 concurrent 4-device issuance {c:.4f}s "
                        f"regresses past sequential {s:.4f}s "
                        f"(tol {ASYNC_TOL}x)")
    if out["async_overlap_n4"].get("bit_exact") != "1":
        failures.append("fig21 concurrent 4-device decode was not bit-exact")
# the always-on serve drain loop must complete an open-loop mix with no
# explicit drain() call from the submitting thread
if "serving_open_loop_drain" not in out:
    failures.append("missing fig20 open_loop_drain row")
elif out["serving_open_loop_drain"].get("background_drain") != "1":
    failures.append("fig20 open_loop_drain row did not run via the "
                    "background drain loop")
with open("BENCH_fig19.json", "w") as f:
    json.dump(out, f, indent=2, sort_keys=True)
    f.write("\n")
print(f"bench-smoke: wrote BENCH_fig19.json ({len(out)} rows)")
if failures:
    print("bench-smoke: GUARD FAILED:\n  " + "\n  ".join(failures),
          file=sys.stderr)
    sys.exit(1)
print("bench-smoke: planned <= FIFO on every row; GP Zc_run recorded; "
      "fused Q6 beats materialize-then-query; serving shared <= naive FIFO "
      "with cross-query batching reducing launches; sharded N=4 modeled "
      "makespan <= single-device and round-robin; D2D rebalance beats "
      "decode-in-place with bit-exact placed shards; async dispatch within "
      "tolerance of sequential on fig19+fig21; background drain loop "
      "completed the open-loop mix")
EOF
