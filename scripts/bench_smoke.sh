#!/usr/bin/env bash
# Benchmark smoke: run fig19 (end-to-end TPC-H movement+decode) at tiny scale
# and record the per-query Z_run / Zc_run / planned / measured makespans in
# BENCH_fig19.json, so every PR leaves a machine-readable perf datapoint
# (wall-clock is CPU-noisy; the planned-vs-baseline fields are deterministic
# given the measured timings and are the regression-relevant signal).
#
# Guards (exit non-zero, failing CI loudly):
#   * planned makespan must not exceed the FIFO baseline on any row -- the
#     adaptive planner's documented invariant under the shared model;
#   * the GP-column Zc_run row (measured group-boundary chunked decode over
#     Group-Parallel / Non-Parallel columns) must be present;
#   * the decode-fused Q6 row must be present and fused must not be slower
#     than materialize-then-query (the late-materialization win, measured).
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH=src:.${PYTHONPATH:+:$PYTHONPATH} python - <<'EOF'
import json
import sys

from benchmarks import fig19_e2e

rows = fig19_e2e.main(quick=True)
out = {}
for line in rows:
    name, _, derived = line.split(",", 2)
    key = name.split("/", 1)[1]
    fields = dict(kv.split("=", 1) for kv in derived.split(";") if "=" in kv)
    if key.startswith("fused_q"):
        out[key] = {k: fields[k] for k in
                    ("fused", "materialized", "sel", "chunks", "launches",
                     "traffic", "prefuse_traffic", "never_materialized")
                    if k in fields}
    elif key.startswith("q"):
        out[key] = {k: fields[k] for k in
                    ("Z_run", "Zc_run", "planned", "measured",
                     "plan_fifo", "plan_johnson", "auto_chunk_kib",
                     "chunk_cols", "launches", "gp_cols", "gp_chunk_cols",
                     "fused", "materialized", "fused_sel", "fused_cols")
                    if k in fields}
    elif key == "gp_columns":
        out["gp_columns"] = {k: fields[k] for k in
                             ("Zc_run", "gp_cols", "gp_chunk_cols")
                             if k in fields}
failures = []
for key, fields in out.items():
    if not key.startswith("q") or key.startswith("fused_"):
        continue
    planned = float(fields["planned"].rstrip("s"))
    fifo = float(fields["plan_fifo"].rstrip("s"))
    if planned > fifo * (1 + 1e-6):
        failures.append(f"{key}: planned {planned:.6f}s > FIFO {fifo:.6f}s")
if "gp_columns" not in out:
    failures.append("missing GP-column Zc_run row")
if "fused_q6" not in out:
    failures.append("missing decode-fused Q6 row")
else:
    fused = float(out["fused_q6"]["fused"].rstrip("s"))
    mat = float(out["fused_q6"]["materialized"].rstrip("s"))
    if fused > mat:
        failures.append(
            f"fused Q6 {fused:.4f}s slower than materialized {mat:.4f}s")
    traffic = int(out["fused_q6"]["traffic"])
    pre = int(out["fused_q6"]["prefuse_traffic"])
    if traffic >= pre:
        failures.append(
            f"fused Q6 traffic {traffic} not below pre-fusion {pre}")
with open("BENCH_fig19.json", "w") as f:
    json.dump(out, f, indent=2, sort_keys=True)
    f.write("\n")
print(f"bench-smoke: wrote BENCH_fig19.json ({len(out)} rows)")
if failures:
    print("bench-smoke: GUARD FAILED:\n  " + "\n  ".join(failures),
          file=sys.stderr)
    sys.exit(1)
print("bench-smoke: planned <= FIFO on every row; GP Zc_run recorded; "
      "fused Q6 beats materialize-then-query")
EOF
