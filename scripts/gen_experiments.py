"""Generate EXPERIMENTS.md from the dry-run record directories."""
import glob, json, os, sys

def load(d):
    recs = {}
    for f in sorted(glob.glob(os.path.join(d, "*.json"))):
        r = json.load(open(f))
        recs[(r["arch"], r["shape"], "mp" if "multi" in str(r.get("mesh","")) else "sp")] = r
    return recs

base = load("experiments/baseline")
opt = load("experiments/optimized")

rows = []
for key in sorted(base):
    b = base[key]
    tag = f"| {key[0]} | {key[1]} | {key[2]} "
    if b.get("status") != "ok":
        rows.append(tag + f"| — | — | — | *{str(b.get('status'))[:58]}* | — | — | — |")
        continue
    r = b["roofline"]
    rows.append(tag + f"| {r['t_compute']*1e3:,.0f} | {r['t_memory']*1e3:,.0f} | {r['t_collective']*1e3:,.0f} "
                f"| {r['bottleneck']} | {r['useful_flops_frac']*100:.0f}% | {r['roofline_frac']*100:.2f}% "
                f"| {b['memory']['per_device_live']/2**30:.1f} {'OK' if b['memory']['fits_16g_hbm'] else 'OVER'} |")
table = ("| arch | shape | mesh | t_compute (ms) | t_memory (ms) | t_collective (ms) | bottleneck "
         "| MODEL/HLO flops | roofline frac | mem GiB/dev |\n|---|---|---|---|---|---|---|---|---|---|\n"
         + "\n".join(rows))
open("/tmp/roofline_table.md","w").write(table)
print("baseline cells:", len(base), "ok:", sum(1 for r in base.values() if r.get('status')=='ok'))

# optimized deltas for the hillclimbed cells
print("\n== optimized vs baseline (available so far) ==")
for key in sorted(opt):
    if key not in base: continue
    b, o = base[key], opt[key]
    if b.get("status") != "ok" or o.get("status") != "ok": continue
    rb, ro = b["roofline"], o["roofline"]
    d_step = rb["step_time"]/max(ro["step_time"],1e-12)
    if abs(d_step-1) > 0.03:
        print(f"{key}: step {rb['step_time']:.2f}->{ro['step_time']:.2f}s ({d_step:.2f}x) "
              f"frac {rb['roofline_frac']*100:.2f}->{ro['roofline_frac']*100:.2f}% "
              f"mem {b['memory']['per_device_live']/2**30:.1f}->{o['memory']['per_device_live']/2**30:.1f}G")
