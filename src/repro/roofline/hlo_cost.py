"""Trip-count-aware HLO cost analysis.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE, so any lax.scan
(layer stacks, flash-attention blocks, microbatch accumulation) under-reports FLOPs,
bytes and collective traffic by the trip count.  This walker parses the compiled HLO
text, reconstructs the computation graph, detects loop trip counts from the loop
condition's comparison constant, and accumulates costs with multipliers:

  flops        -- 2 * prod(output dims) * prod(contracting dims) per dot
                  (convolutions approximated the same way; elementwise flops ignored:
                  every model here is matmul-dominated, documented in EXPERIMENTS.md);
  bytes        -- operands + output of every *executable* instruction (fusion
                  internals excluded: they stay in registers/VMEM);
  collectives  -- wire bytes per kind with ring-cost factors (see analysis.py),
                  multiplied by the enclosing loops' trip counts.

Validated against closed-form counts in tests/test_hlo_cost.py (matmul exact, scan
trip multiplication, flash-attention within 2%).
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s64": 8, "s32": 4, "u64": 8, "u32": 4, "s16": 2,
                "u16": 2, "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
                "token": 0, "s4": 1, "u4": 1}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\((.*)\)\s*->\s*.+\{\s*$")
_TRIP_RE = re.compile(r"constant\((\d+)\)")


def _balanced(s: str, start: int) -> int:
    """Index of the char closing the paren opened at ``start``."""
    depth = 0
    for i in range(start, len(s)):
        if s[i] == "(":
            depth += 1
        elif s[i] == ")":
            depth -= 1
            if depth == 0:
                return i
    return len(s) - 1


def _parse_instr_line(line: str):
    """Parse '%name = TYPE op(args), attrs' with balanced-paren tuple types."""
    s = line.strip()
    if s.startswith("ROOT "):
        s = s[5:]
    if not s.startswith("%"):
        return None
    name, sep, rest = s.partition(" = ")
    if not sep:
        return None
    name = name.strip().lstrip("%")
    rest = rest.strip()
    if rest.startswith("("):                 # (possibly nested) tuple type
        close = _balanced(rest, 0)
        type_str, rest2 = rest[: close + 1], rest[close + 1:].strip()
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        type_str, rest2 = rest[:sp], rest[sp + 1:].strip()
    m = re.match(r"([\w\-]+)\(", rest2)
    if not m:
        return None
    op = m.group(1)
    astart = rest2.find("(")
    aend = _balanced(rest2, astart)
    args = rest2[astart + 1: aend]
    attrs = rest2[aend + 1:]
    return name, type_str, op, args, attrs


def _type_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",")] if m.group(2) else []


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    op: str
    operands: list[str]
    attrs: str
    # per-operand type text parsed from the operand expression itself (newer HLO
    # prints "f32[2,3]{1,0} %name"); "" when the reference carries no type
    operand_types: list[str] = dataclasses.field(default_factory=list)


def _operand_ref(arg: str) -> str:
    """Instruction reference inside an operand expression.

    HLO operand spellings drift across XLA versions: "%name", "name",
    "f32[256,512]{1,0} %name".  Match structurally -- the reference is the last
    %-token (or last whitespace token) -- instead of assuming any one format.
    """
    if "%" in arg:
        return arg[arg.rfind("%") + 1:].strip()
    toks = arg.split()
    return toks[-1] if toks else arg


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: dict = dataclasses.field(default_factory=dict)

    def __iadd__(self, other: "Cost"):
        self.flops += other.flops
        self.bytes += other.bytes
        for k, v in other.coll.items():
            self.coll[k] = self.coll.get(k, 0.0) + v
        return self

    def scaled(self, k: float) -> "Cost":
        return Cost(self.flops * k, self.bytes * k,
                    {kk: v * k for kk, v in self.coll.items()})


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.comps: dict[str, list[Instr]] = {}
        self.entry: str | None = None
        self._parse(hlo_text)
        self._types: dict[tuple[str, str], str] = {}
        for cname, instrs in self.comps.items():
            for ins in instrs:
                self._types[(cname, ins.name)] = ins.type_str
        self._memo: dict[str, Cost] = {}

    # ------------------------------------------------------------------ parsing
    def _parse(self, text: str):
        cur: str | None = None
        params: dict[str, list[tuple[str, str]]] = defaultdict(list)
        for line in text.splitlines():
            if line.startswith("HloModule"):
                continue
            hdr = _COMP_HDR.match(line)
            if hdr and ("->" in line):
                cur = hdr.group(1)
                self.comps[cur] = []
                if line.startswith("ENTRY"):
                    self.entry = cur
                # parse parameter decls from the header for type lookup
                pdecl = re.findall(r"%?([\w\.\-]+)\s*:\s*"
                                   r"((?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\]\S*))",
                                   line)
                params[cur] = pdecl
                continue
            if cur is None:
                continue
            if line.strip() == "}":
                cur = None
                continue
            parsed = _parse_instr_line(line)
            if parsed is None:
                continue
            name, type_str, op, args, attrs = parsed
            raw_args = _split_args(args)
            operands = [_operand_ref(a) for a in raw_args]
            # keep any inline operand type: authoritative when the ref table has
            # no entry (e.g. cross-computation refs)
            op_types = ["" if _SHAPE_RE.search(a) is None
                        else a[:a.rfind("%")].strip() if "%" in a else a
                        for a in raw_args]
            self.comps[cur].append(Instr(name, type_str, op, operands, attrs,
                                         op_types))
        # register parameter types as pseudo-instructions
        for cname, decls in params.items():
            for pname, ptype in decls:
                self.comps[cname].insert(0, Instr(pname, ptype, "parameter", [],
                                                  ""))

    def _operand_type(self, comp: str, ref: str, inline: str = "") -> str:
        # refs look like "name" or "name.1"; the operand expression may carry
        # the type inline, which wins when the ref table has no entry
        t = self._types.get((comp, ref))
        return t or inline or ""

    def _operand_type_at(self, comp: str, ins: Instr, i: int) -> str:
        inline = ins.operand_types[i] if i < len(ins.operand_types) else ""
        return self._operand_type(comp, ins.operands[i], inline)

    # ------------------------------------------------------------------- costs
    def _dot_flops(self, comp: str, ins: Instr) -> float:
        out_dims = _shape_dims(ins.type_str)
        n_out = 1
        for d in out_dims:
            n_out *= d
        lhs_type = self._operand_type_at(comp, ins, 0) if ins.operands else ""
        lhs_dims = _shape_dims(lhs_type)
        cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.attrs)
        k = 1
        if cm and cm.group(1):
            for idx in cm.group(1).split(","):
                i = int(idx)
                if i < len(lhs_dims):
                    k *= lhs_dims[i]
        return 2.0 * n_out * k

    def _trip_count(self, cond_comp: str) -> int:
        instrs = self.comps.get(cond_comp, [])
        consts = []
        for ins in instrs:
            consts += [int(c) for c in _TRIP_RE.findall(
                f"{ins.op}({','.join(ins.operands)}){ins.attrs}")]
            if ins.op == "constant":
                cm = re.search(r"constant\((\d+)\)", ins.attrs)
        # also scan the raw lines we kept: constants appear as operands to compare
        text = " ".join(f"{i.op} {i.attrs}" for i in instrs)
        consts += [int(c) for c in _TRIP_RE.findall(text)]
        return max(consts) if consts else 1

    def comp_cost(self, comp: str) -> Cost:
        if comp in self._memo:
            return self._memo[comp]
        total = Cost()
        self._memo[comp] = total  # guards recursion
        for ins in self.comps.get(comp, []):
            total += self.instr_cost(comp, ins)
        return total

    def instr_cost(self, comp: str, ins: Instr) -> Cost:
        op = ins.op
        if op in ("parameter", "constant", "get-tuple-element", "tuple",
                  "bitcast", "after-all"):
            return Cost()
        c = Cost()
        if op in ("dot", "convolution"):
            c.flops = self._dot_flops(comp, ins)
        # bytes: operands + output at the executable level
        out_b = _type_bytes(ins.type_str)
        in_b = sum(_type_bytes(self._operand_type_at(comp, ins, i))
                   for i in range(len(ins.operands)))
        if op == "fusion":
            c.bytes = out_b + in_b
            return c
        if op == "while":
            body = re.search(r"body=%?([\w\.\-]+)", ins.attrs)
            cond = re.search(r"condition=%?([\w\.\-]+)", ins.attrs)
            trip = self._trip_count(cond.group(1)) if cond else 1
            inner = Cost()
            if body:
                inner += self.comp_cost(body.group(1))
            if cond:
                inner += self.comp_cost(cond.group(1))
            return inner.scaled(max(trip, 1))
        if op == "conditional":
            branches = re.findall(r"(?:branch_computations=\{([^}]*)\}|"
                                  r"(?:true|false)_computation=%?([\w\.\-]+))",
                                  ins.attrs)
            names = []
            for a, b in branches:
                if a:
                    names += [n.strip().lstrip("%") for n in a.split(",")]
                if b:
                    names.append(b)
            if names:
                costs = [self.comp_cost(n) for n in names]
                best = max(costs, key=lambda x: x.flops + x.bytes)
                return best
            return c
        if op in ("call", "async-start"):
            callee = re.search(r"(?:to_apply|called_computations=\{)=?%?"
                               r"([\w\.\-]+)", ins.attrs)
            if callee:
                return self.comp_cost(callee.group(1))
        if op.startswith(("all-gather", "all-reduce", "reduce-scatter",
                          "all-to-all", "collective-permute")):
            kind = op.replace("-start", "").replace("-done", "")
            if op.endswith("-done"):
                return Cost()
            gm = re.search(r"replica_groups=\{\{([^}]*)\}", ins.attrs)
            if gm:
                n = len(gm.group(1).split(","))
            else:
                gm2 = re.search(r"replica_groups=\[(\d+),(\d+)\]", ins.attrs)
                n = int(gm2.group(2)) if gm2 else 2
            n = max(n, 2)
            ring = (n - 1) / n
            if kind == "all-reduce":
                wire = 2 * in_b * ring
            elif kind == "collective-permute":
                wire = in_b
            elif kind == "all-gather":
                wire = out_b * ring
            else:
                wire = in_b * ring
            c.coll[kind] = c.coll.get(kind, 0.0) + wire
            c.bytes = out_b + in_b
            return c
        c.bytes = out_b + in_b
        return c

    def total(self) -> Cost:
        assert self.entry, "no ENTRY computation found"
        # memoized comp costs: entry body once
        self._memo.pop(self.entry, None)
        return self.comp_cost(self.entry)


def analyze(hlo_text: str) -> dict:
    cost = HloCostModel(hlo_text).total()
    return {"flops": cost.flops, "bytes": cost.bytes,
            "collectives": dict(cost.coll),
            "coll_bytes": float(sum(cost.coll.values()))}


def _split_args(args: str) -> list[str]:
    """Split top-level comma-separated operands (tuples contain commas)."""
    out, depth, cur = [], 0, []
    for ch in args:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        out.append("".join(cur))
    return [a for a in (s.strip() for s in out) if a]
