"""Roofline analysis from compiled dry-run artifacts (no hardware required).

Three terms per (arch x shape x mesh), in seconds:

    compute    = HLO_FLOPs        / (chips * peak_bf16)
    memory     = HLO_bytes        / (chips * hbm_bw)
    collective = collective_bytes / (chips * link_bw)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()``.  XLA's SPMD output is a
per-device program, so the analysis is per-device; we normalize to per-chip terms
directly (chips factor already folded in).  collective_bytes is parsed from the HLO
text: per-device ring-cost approximations
    all-gather: out_bytes * (n-1)/n          reduce-scatter: in_bytes * (n-1)/n
    all-reduce: 2 * bytes * (n-1)/n          all-to-all:     bytes * (n-1)/n
    collective-permute: bytes
where n = replica-group size of that op.

Hardware constants (TPU v5e, per assignment): 197 TFLOP/s bf16, 819 GB/s HBM,
50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import json
import re

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|s64|s32|u64|u32|s16|u16|s8|u8|pred|c64)"
                       r"\[([0-9,]*)\]")
_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "s32": 4,
                "u64": 8, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "c64": 8}
_COLL_RE = re.compile(
    r"^\s*(?:\S+\s*=\s*)?(\([^)]*\)|\S+)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.MULTILINE)
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Per-device collective wire bytes by op kind, parsed from HLO text."""
    out: dict[str, float] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.match(line)
        if not m:
            continue
        type_str, kind = m.group(1), m.group(2)
        if "-done(" in line:   # started op already counted at -start
            continue
        nbytes = _shape_bytes(type_str)
        gm = _GROUPS_RE.search(line)
        if gm:
            n = len(gm.group(1).split(","))
        else:
            gm2 = _GROUPS_V2_RE.search(line)
            n = int(gm2.group(2)) if gm2 else 2
        n = max(n, 2)
        ring = (n - 1) / n
        if kind == "all-reduce":
            wire = 2 * nbytes * ring
        elif kind == "collective-permute":
            wire = nbytes
        else:  # all-gather out / reduce-scatter in / all-to-all
            wire = nbytes * ring
        out[kind] = out.get(kind, 0.0) + wire
    return out


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops_per_chip: float
    hlo_bytes_per_chip: float
    coll_bytes_per_chip: float
    coll_breakdown: dict
    model_flops_total: float
    per_device_bytes: int
    useful_bytes_per_chip: float = 0.0  # argument+output buffers: a read-once/
                                        # write-once lower bound on HBM traffic

    @property
    def t_compute(self) -> float:
        return self.hlo_flops_per_chip / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes_per_chip / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_chip / LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def step_time(self) -> float:
        """Lower-bound step time = max of the three terms (perfect overlap)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def bw_frac(self) -> float:
        """Useful-traffic fraction of modeled HBM bytes (decode cells live here:
        the roofline for one-token steps is bandwidth, not FLOPs)."""
        return min(1.0, self.useful_bytes_per_chip / max(self.hlo_bytes_per_chip,
                                                         1.0))

    @property
    def useful_flops_frac(self) -> float:
        """MODEL_FLOPS / total HLO FLOPs -- catches remat/dispatch/mask waste."""
        total_hlo = self.hlo_flops_per_chip * self.chips
        return self.model_flops_total / max(total_hlo, 1.0)

    @property
    def roofline_frac(self) -> float:
        """Fraction of the compute roofline achieved at the modeled step time:
        (useful FLOPs / chips / step_time) / peak."""
        useful_per_chip_rate = (self.model_flops_total / self.chips) \
            / max(self.step_time, 1e-12)
        return useful_per_chip_rate / PEAK_FLOPS

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d.update(t_compute=self.t_compute, t_memory=self.t_memory,
                 t_collective=self.t_collective, bottleneck=self.bottleneck,
                 step_time=self.step_time,
                 useful_flops_frac=self.useful_flops_frac,
                 bw_frac=self.bw_frac,
                 roofline_frac=self.roofline_frac)
        return d


def model_flops(cfg, shape, kind: str) -> float:
    """Analytic MODEL_FLOPS: 6*N*D train, 2*N*D prefill, 2*N*B decode (active
    params for MoE) + attention term.  Enc-dec: the decoder only sees S/8 tokens
    (repro.models.encdec.SRC_RATIO), so its params are weighted accordingly."""
    n_active = cfg.active_param_count()
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "encdec" and kind in ("train", "prefill"):
        # approximate enc/dec param split by layer counts (enc 2/5 of a dec
        # layer's params: no cross-attn): weight dec params by 1/8 token count
        frac_dec = 0.55
        n_active = n_active * ((1 - frac_dec) + frac_dec / 8)
    if kind == "train":
        tokens = B * S
        base = 6 * n_active * tokens
        attn = 12 * cfg.n_layers * cfg.n_heads * cfg.hd * S * S * B \
            if cfg.family not in ("ssm",) else 0
    elif kind == "prefill":
        tokens = B * S
        base = 2 * n_active * tokens
        attn = 4 * cfg.n_layers * cfg.n_heads * cfg.hd * S * S * B \
            if cfg.family not in ("ssm",) else 0
    else:  # decode: one token per sequence
        base = 2 * n_active * B
        attn = 4 * cfg.n_layers * cfg.n_heads * cfg.hd * S * B \
            if cfg.family not in ("ssm",) else 0
    if cfg.family == "hybrid":
        attn = attn / max(1, cfg.attn_every)  # shared block applied 1/k as often
    return float(base + attn)


def summarize(records: list[dict]) -> str:
    """Markdown table for EXPERIMENTS.md."""
    hdr = ("| arch | shape | mesh | t_compute | t_memory | t_collective | "
           "bottleneck | useful/HLO | roofline frac |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    rows = []
    for r in records:
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['t_compute'] * 1e3:.2f} ms | {r['t_memory'] * 1e3:.2f} ms "
            f"| {r['t_collective'] * 1e3:.2f} ms | {r['bottleneck']} "
            f"| {r['useful_flops_frac'] * 100:.1f}% "
            f"| {r['roofline_frac'] * 100:.1f}% |")
    return hdr + "\n".join(rows)
