"""Cross-pod gradient compression with error feedback (beyond-paper integration).

The paper's thesis -- compress where the link is slow, decompress where compute is
cheap -- applied to the slowest link in a multi-pod training system: the DCN ("pod")
axis.  Gradients are int8-quantized per-tensor (symmetric max-scale), summed across
pods in integer space, dequantized, and the quantization residual is fed back into the
next step (error feedback keeps SGD unbiased in the long run; tested for convergence
in tests/test_grad_compress.py).

``compressed_psum`` is a shard_map building block: inside a shard_map over the "pod"
axis it replaces a bf16/f32 psum with an int8 wire format -- a 4x/2x reduction of
cross-DCN bytes, mirroring the paper's PCIe saving.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compressed_psum(grad: jnp.ndarray, err: jnp.ndarray, axis: str
                    ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Error-feedback int8 psum over a named axis (use inside shard_map).

    Two-phase: (1) agree on a global scale with a scalar pmax, (2) integer-sum the
    int8 payload.  The reconstruction Σ q_i * s is then exact w.r.t. what was sent,
    and each member's quantization residual goes into its error-feedback buffer.
    Wire bytes: 1 per element + one scalar, vs 4 for f32 psum."""
    g = grad.astype(jnp.float32) + err
    scale = jax.lax.pmax(jnp.max(jnp.abs(g)), axis) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    new_err = g - q.astype(jnp.float32) * scale
    qsum = jax.lax.psum(q.astype(jnp.int32), axis)
    return qsum.astype(jnp.float32) * scale, new_err


def compress_tree(grads, errs, axis: str):
    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(errs)
    outs = [compressed_psum(g, e, axis) for g, e in zip(flat_g, flat_e)]
    return tdef.unflatten([o[0] for o in outs]), tdef.unflatten([o[1] for o in outs])


def init_error_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)


def wire_bytes(tree, compressed: bool) -> int:
    """Cross-pod bytes per sync for the benchmark harness."""
    import numpy as np

    n = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))
    return n * (1 if compressed else 4)
