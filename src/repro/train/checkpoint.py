"""Fault-tolerant checkpointing with ZipFlow-compressed shards.

Layout:  <dir>/step_<N>/
            manifest.json        -- tree structure, shapes, dtypes, codec, hashes
            <leaf_id>.npz        -- compressed buffers for that leaf
         <dir>/LATEST            -- atomic pointer (tmp + rename)

Compression: float params are byte-planed (bf16/f32 split into per-byte streams) and
the high/exponent bytes -- heavily skewed in trained nets -- go through the ZipFlow ANS
codec; integer leaves go through bitpack.  This is the paper's "compress where the
link is slow" applied to checkpoint I/O, and restore decodes through the same pattern
stages that serve the data pipeline (on-device on a real TPU).

Durability: every file is written to a tmp name and os.rename'd (atomic on POSIX);
the LATEST pointer flips only after the full step directory is fsync'd, so a crash
mid-write can never corrupt the restore path.  Content hashes are verified on load.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
from typing import Any

import jax
import numpy as np

from repro.core import plan as plan_mod

_FLOAT_PLAN = plan_mod.make_plan("ans")          # applied to the exponent byte plane
_INT_PLAN = plan_mod.make_plan("bitpack")


def _leaf_paths(tree) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((name, leaf))
    return out


def _json_meta(meta: dict) -> bytes:
    """Codec meta as JSON, minus ndarray-valued host planning data (per-group
    offsets etc.) -- decode_np only reads the scalar structural fields."""
    return json.dumps({k: v for k, v in meta.items()
                       if not isinstance(v, np.ndarray)}).encode()


def _encode_leaf(arr: np.ndarray) -> dict[str, np.ndarray | bytes | str]:
    """Byte-plane + ZipFlow-encode one array; returns npz-ready dict."""
    raw = np.ascontiguousarray(arr)
    if raw.dtype.kind == "f":
        b = raw.view(np.uint8).reshape(-1, raw.dtype.itemsize)
        planes = {}
        # high byte (exponent-heavy) -> ANS; other planes stored raw
        hi = b[:, -1].copy()
        enc = plan_mod.encode(_FLOAT_PLAN, hi)
        if enc.compressed_nbytes < hi.nbytes:
            planes["hi_codec"] = "ans"
            for k, v in plan_mod.flat_buffers(enc).items():
                planes[f"hi.{k}"] = v
            planes["hi_meta"] = _json_meta(enc.meta)
        else:
            planes["hi_codec"] = "raw"
            planes["hi.raw"] = hi
        planes["rest"] = b[:, :-1].copy()
        return planes
    if raw.dtype.kind in "iu" and raw.size:
        enc = plan_mod.encode(_INT_PLAN, raw.reshape(-1))
        if enc.compressed_nbytes < raw.nbytes:
            out = {f"bp.{k}": v for k, v in plan_mod.flat_buffers(enc).items()}
            out["hi_codec"] = "bitpack"
            out["bp_meta"] = _json_meta(enc.meta)
            return out
    return {"hi_codec": "raw2", "raw": raw}


def _decode_leaf(files: dict, shape, dtype) -> np.ndarray:
    codec = str(files["hi_codec"])
    dtype = np.dtype(dtype)
    if codec == "raw2":
        return np.asarray(files["raw"]).reshape(shape).astype(dtype)
    if codec == "bitpack":
        meta = json.loads(bytes(files["bp_meta"]))
        from repro.core.registry import get as get_codec

        n = int(np.prod(shape)) if shape else 1
        bufs = {k[len("bp.root."):]: np.asarray(v) for k, v in files.items()
                if k.startswith("bp.root.")}
        vals = get_codec("bitpack").decode_np(bufs, meta, n, dtype)
        return vals.reshape(shape)
    # float byte-plane path
    rest = np.asarray(files["rest"])
    n = rest.shape[0]
    if codec == "ans":
        meta = json.loads(bytes(files["hi_meta"]))
        from repro.core.registry import get as get_codec

        bufs = {k[len("hi.root."):]: np.asarray(v) for k, v in files.items()
                if k.startswith("hi.root.")}
        hi = get_codec("ans").decode_np(bufs, meta, n, np.uint8)
    else:
        hi = np.asarray(files["hi.raw"])
    b = np.concatenate([rest, hi[:, None]], axis=1)
    return b.reshape(-1).view(dtype).reshape(shape)


def _atomic_write(path: str, write_fn):
    tmp = path + ".tmp"
    write_fn(tmp)
    os.replace(tmp, path)


def save(ckpt_dir: str, step: int, tree, extra: dict | None = None) -> str:
    """Save a pytree checkpoint; returns the step directory."""
    step_dir = os.path.join(ckpt_dir, f"step_{step:08d}")
    os.makedirs(step_dir + ".tmp", exist_ok=True)
    manifest = {"step": step, "leaves": {}, "extra": extra or {}}
    for i, (name, leaf) in enumerate(_leaf_paths(tree)):
        arr = np.asarray(leaf)
        enc = _encode_leaf(arr)
        fname = f"leaf_{i:05d}.npz"
        fpath = os.path.join(step_dir + ".tmp", fname)
        _atomic_write(fpath, lambda t: np.savez(open(t, "wb"), **enc))
        h = hashlib.sha256(open(fpath, "rb").read()).hexdigest()[:16]
        manifest["leaves"][name] = {
            "file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype),
            "sha": h,
            "raw_bytes": int(arr.nbytes),
            "stored_bytes": int(os.path.getsize(fpath))}
    _atomic_write(os.path.join(step_dir + ".tmp", "manifest.json"),
                  lambda t: open(t, "w").write(json.dumps(manifest, indent=1)))
    if os.path.isdir(step_dir):
        shutil.rmtree(step_dir)
    os.replace(step_dir + ".tmp", step_dir)
    _atomic_write(os.path.join(ckpt_dir, "LATEST"),
                  lambda t: open(t, "w").write(f"step_{step:08d}"))
    return step_dir


def latest_step(ckpt_dir: str) -> int | None:
    try:
        name = open(os.path.join(ckpt_dir, "LATEST")).read().strip()
        return int(name.split("_")[1])
    except (FileNotFoundError, IndexError, ValueError):
        return None


def restore(ckpt_dir: str, tree_like, step: int | None = None):
    """Restore into the structure of ``tree_like`` (shapes/dtypes verified).
    -> (tree, step, extra)."""
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    step_dir = os.path.join(ckpt_dir, f"step_{step:08d}")
    manifest = json.load(open(os.path.join(step_dir, "manifest.json")))
    leaves = []
    for name, leaf in _leaf_paths(tree_like):
        info = manifest["leaves"][name]
        fpath = os.path.join(step_dir, info["file"])
        blob = open(fpath, "rb").read()
        h = hashlib.sha256(blob).hexdigest()[:16]
        if h != info["sha"]:
            raise IOError(f"checkpoint corruption in {fpath}: hash mismatch")
        files = dict(np.load(fpath, allow_pickle=False))
        arr = _decode_leaf(files, tuple(info["shape"]), info["dtype"])
        leaves.append(arr)
    _, tdef = jax.tree_util.tree_flatten(tree_like)
    return tdef.unflatten(leaves), step, manifest.get("extra", {})


def compression_report(ckpt_dir: str, step: int | None = None) -> dict:
    step = latest_step(ckpt_dir) if step is None else step
    man = json.load(open(os.path.join(
        ckpt_dir, f"step_{step:08d}", "manifest.json")))
    raw = sum(v["raw_bytes"] for v in man["leaves"].values())
    stored = sum(v["stored_bytes"] for v in man["leaves"].values())
    return {"raw_bytes": raw, "stored_bytes": stored,
            "ratio": raw / max(stored, 1)}
