"""Training substrate: optimizer, step builders, remat, checkpointing, FT loop."""
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import make_dp_compressed_step, make_train_step

__all__ = ["AdamWConfig", "make_dp_compressed_step", "make_train_step"]
