"""Fault-tolerant training loop.

Production posture (1000+ nodes):
  * restart-from-latest semantics: the loop always resumes from the newest intact
    checkpoint (atomic LATEST pointer), so any crash/restart converges.
  * periodic + terminal checkpointing with compressed shards (checkpoint.py).
  * straggler mitigation: per-step wall-time EMA; steps slower than
    ``straggler_factor`` x EMA are logged and counted -- on a real cluster the
    launcher uses this signal to cordon a host and trigger elastic re-mesh
    (launch/elastic.py); data order is deterministic in step number, so a replacement
    host recomputes exactly the same batch.
  * failure injection hook for tests (``fail_at_step``) proves restartability.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import numpy as np

from repro.train import checkpoint as ckpt


@dataclasses.dataclass
class LoopConfig:
    total_steps: int
    ckpt_dir: str
    ckpt_every: int = 50
    log_every: int = 10
    straggler_factor: float = 3.0
    fail_at_step: int | None = None  # test hook: simulated crash


class SimulatedFailure(RuntimeError):
    pass


def run(loop_cfg: LoopConfig, step_fn: Callable, params, opt_state,
        batch_fn: Callable[[int], Any], log: Callable[[str], None] = print):
    """Run (or resume) training.  ``batch_fn(step)`` must be deterministic in step.

    Returns (params, opt_state, history)."""
    start_step = 0
    latest = ckpt.latest_step(loop_cfg.ckpt_dir)
    if latest is not None:
        (params, opt_state), start_step, _ = ckpt.restore(
            loop_cfg.ckpt_dir, (params, opt_state))
        log(f"[loop] resumed from checkpoint step {start_step}")
    history: list[dict] = []
    ema = None
    stragglers = 0
    for step in range(start_step, loop_cfg.total_steps):
        if loop_cfg.fail_at_step is not None and step == loop_cfg.fail_at_step:
            raise SimulatedFailure(f"injected failure at step {step}")
        t0 = time.perf_counter()
        batch = batch_fn(step)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        jax.block_until_ready(metrics["loss"])
        dt = time.perf_counter() - t0
        ema = dt if ema is None else 0.9 * ema + 0.1 * dt
        if dt > loop_cfg.straggler_factor * ema and step > start_step + 3:
            stragglers += 1
            log(f"[loop] straggler step {step}: {dt * 1e3:.1f}ms vs EMA "
                f"{ema * 1e3:.1f}ms (count={stragglers})")
        rec = {"step": step, "loss": float(metrics["loss"]),
               "grad_norm": float(metrics.get("grad_norm", np.nan)),
               "time_s": dt}
        history.append(rec)
        if step % loop_cfg.log_every == 0:
            log(f"[loop] step {step} loss {rec['loss']:.4f} "
                f"gnorm {rec['grad_norm']:.3f} {dt * 1e3:.0f}ms")
        if (step + 1) % loop_cfg.ckpt_every == 0:
            ckpt.save(loop_cfg.ckpt_dir, step + 1, (params, opt_state))
    ckpt.save(loop_cfg.ckpt_dir, loop_cfg.total_steps, (params, opt_state))
    return params, opt_state, history
