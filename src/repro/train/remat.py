"""Activation-checkpoint policies, selectable per architecture / perf iteration."""
from __future__ import annotations

import jax

POLICIES = {
    # save nothing: recompute the whole layer in the backward pass (min memory)
    "full": jax.checkpoint_policies.nothing_saveable,
    # save only matmul outputs that feed reductions (good default on TPU)
    "dots": jax.checkpoint_policies.dots_saveable,
    # save everything (no remat; max memory, min recompute)
    "none": jax.checkpoint_policies.everything_saveable,
    # save outputs of expensive contractions but not element-wise ops
    "dots_no_batch": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
}


def get_policy(name: str | None):
    if name is None or name == "none":
        return None if name is None else POLICIES["none"]
    return POLICIES[name]
