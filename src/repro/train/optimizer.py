"""AdamW in raw JAX (no optax in this environment -- built as substrate)."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup -> cosine decay."""
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def init(params) -> dict:
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return {"mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def update(cfg: AdamWConfig, params, opt_state, grads):
    """-> (new_params, new_opt_state, diagnostics)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip else 1.0
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * jnp.square(g)
        mhat = mu / bc1
        nhat = nu / bc2
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps)
        if p.ndim >= 2 and cfg.weight_decay:  # no decay on norms/biases
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(opt_state["mu"])
    flat_nu = jax.tree.leaves(opt_state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_mu = tdef.unflatten([o[1] for o in out])
    new_nu = tdef.unflatten([o[2] for o in out])
    return new_p, {"mu": new_mu, "nu": new_nu, "step": step}, \
        {"grad_norm": gnorm, "lr": lr}


def opt_specs(param_specs):
    """Optimizer state shards exactly like its parameters."""
    return {"mu": param_specs, "nu": param_specs, "step": None}
