"""Train-step builders.

``make_train_step`` -- the production pjit path: loss -> grads -> AdamW, with
per-layer remat and optional microbatch gradient accumulation (lax.scan).  XLA SPMD
inserts all collectives from the in/out shardings (FSDP all-gathers, TP reduces, DP
grad all-reduce); compute/communication overlap is delegated to the latency-hiding
scheduler (flags in launch/mesh.py).

``make_dp_compressed_step`` -- a shard_map data-parallel variant whose cross-"pod"
gradient sync uses the int8 error-feedback wire format of grad_compress.py (the
paper's compress-the-slow-link thesis applied to the DCN axis).
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import get_model
from repro.train import grad_compress, optimizer
from repro.train.optimizer import AdamWConfig
from repro.train.remat import get_policy


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                    remat: str | None = "dots",
                    microbatch: int = 1) -> Callable:
    """-> step(params, opt_state, batch) -> (params, opt_state, metrics)."""
    model = get_model(cfg)
    policy = get_policy(remat)

    def loss_fn(params, batch):
        return model.train_loss(params, batch, policy)

    def step(params, opt_state, batch):
        if microbatch > 1:
            def split(x):
                b = x.shape[0]
                return x.reshape(microbatch, b // microbatch, *x.shape[1:])

            mbatch = jax.tree.map(split, batch)

            def acc_body(carry, mb):
                loss_acc, grad_acc = carry
                loss, grads = jax.value_and_grad(loss_fn)(params, mb)
                return (loss_acc + loss,
                        jax.tree.map(jnp.add, grad_acc, grads)), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(acc_body, (jnp.float32(0), zeros),
                                            mbatch)
            loss = loss / microbatch
            grads = jax.tree.map(lambda g: g / microbatch, grads)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        new_params, new_opt, diag = optimizer.update(opt_cfg, params, opt_state,
                                                     grads)
        metrics = {"loss": loss, **diag}
        return new_params, new_opt, metrics

    return step


def make_eval_step(cfg: ModelConfig) -> Callable:
    model = get_model(cfg)
    return lambda params, batch: model.train_loss(params, batch, None)


# ------------------------------------------------------- compressed-DP variant

def make_dp_compressed_step(cfg: ModelConfig, opt_cfg: AdamWConfig, mesh,
                            pod_axis: str = "pod") -> Callable:
    """Pure data-parallel train step under shard_map with int8 cross-pod grad sync.

    Params/opt replicated; batch sharded over all mesh axes; per-member grads are
    psum'ed over intra-pod axes uncompressed (fast ICI) and over the pod axis with
    the int8 error-feedback wire format (slow DCN).  Use for models that fit one
    chip (examples/train_lm.py --grad-compress)."""
    model = get_model(cfg)
    data_axes = tuple(n for n in mesh.axis_names if n != pod_axis)

    def local_step(params, opt_state, err, batch):
        loss, grads = jax.value_and_grad(
            lambda p: model.train_loss(p, batch, None))(params)
        # fast intra-pod reduction, full precision
        for ax in data_axes:
            grads = jax.tree.map(lambda g: jax.lax.pmean(g, ax), grads)
            loss = jax.lax.pmean(loss, ax)
        # slow cross-pod reduction, int8 + error feedback
        if pod_axis in mesh.axis_names:
            n_pods = jax.lax.psum(jnp.float32(1), pod_axis)
            grads, err = grad_compress.compress_tree(grads, err, pod_axis)
            grads = jax.tree.map(lambda g: g / n_pods, grads)
            loss = jax.lax.pmean(loss, pod_axis)
        new_params, new_opt, diag = optimizer.update(opt_cfg, params, opt_state,
                                                     grads)
        return new_params, new_opt, err, {"loss": loss, **diag}

    replicated = P()
    batch_spec = P(mesh.axis_names)
    return jax.jit(jax.shard_map(
        local_step, mesh=mesh,
        in_specs=(replicated, replicated, replicated, batch_spec),
        out_specs=(replicated, replicated, replicated, replicated),
        check_vma=False))
