"""Shared model building blocks (raw JAX, functional pytrees).

Conventions:
  * params are nested dicts of f32 arrays; compute casts to cfg.dtype (bf16).
  * init fns return (params, specs) where specs is a matching pytree of
    PartitionSpecs expressed with logical axis names "fsdp" (-> ("pod","data") /
    ("data",)) and "tp" (-> "model"); resolution happens in launch/mesh.py.
  * a dimension is sharded only if divisible by the mesh axis size -- otherwise the
    spec builder falls back to replication (small archs on a big mesh).
  * attention is chunked flash-style (online softmax) so 32k-token prefill never
    materializes an (S, S) score tensor.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.sharding_ctx import get_mesh, shard, tp_divides

# --------------------------------------------------------------------- init helpers

Spec = tuple  # logical spec: tuple of None | "fsdp" | "tp"


def ninit(key, shape, scale=None, dtype=jnp.float32):
    scale = 1.0 / math.sqrt(shape[0]) if scale is None else scale
    return jax.random.normal(key, shape, dtype) * scale


def zinit(_key, shape, dtype=jnp.float32):
    return jnp.zeros(shape, dtype)


def oinit(_key, shape, dtype=jnp.float32):
    return jnp.ones(shape, dtype)


# ------------------------------------------------------------------------ norms

def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * scale.astype(jnp.float32)).astype(dt)


# ------------------------------------------------------------------------- RoPE

def rope_freqs(hd: int, theta: float) -> np.ndarray:
    return 1.0 / theta ** (np.arange(0, hd, 2, dtype=np.float32) / hd)


def apply_rope(x: jnp.ndarray, pos: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, hd), pos: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta))                 # (hd/2,)
    ang = pos[..., None].astype(jnp.float32) * freqs           # (..., S, hd/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., : hd // 2], x[..., hd // 2:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jnp.ndarray, pos3: jnp.ndarray, theta: float,
                sections: tuple[int, int, int]) -> jnp.ndarray:
    """Multimodal RoPE (Qwen2-VL): pos3 (..., 3, S) are (t, h, w) position ids;
    the hd/2 frequency bands are split into |sections| groups, each rotated by its
    own position stream."""
    hd = x.shape[-1]
    assert sum(sections) == hd // 2, (sections, hd)
    freqs = jnp.asarray(rope_freqs(hd, theta))                 # (hd/2,)
    # angle per band: pick the position stream for each band
    band_src = np.concatenate([np.full(s, i) for i, s in enumerate(sections)])
    pos_sel = jnp.take(pos3, jnp.asarray(band_src), axis=-2)   # (..., hd/2, S)
    ang = jnp.moveaxis(pos_sel, -2, -1).astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., : hd // 2], x[..., hd // 2:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# -------------------------------------------------------------------- attention

def _repeat_kv(k: jnp.ndarray, groups: int) -> jnp.ndarray:
    if groups == 1:
        return k
    b, s, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, h, groups, d)) \
        .reshape(b, s, h * groups, d)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True, q_chunk: int = 1024,
                    kv_chunk: int = 1024,
                    kv_offset: int = 0) -> jnp.ndarray:
    """Chunked online-softmax attention; never materializes (Sq, Sk) scores.

    q: (B, Sq, H, hd); k/v: (B, Sk, Hkv, hd).  GQA handled by head repetition.
    kv_offset: absolute position of k[0] relative to q[0] (for cross-chunk decode).
    """
    B, Sq, H, hd = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    k = _repeat_kv(k, H // Hkv)
    v = _repeat_kv(v, H // Hkv)
    # head padding: when H does not divide the TP axis, pad with zero heads so the
    # attention shards instead of replicating 16x per TP rank.  Padded outputs are
    # sliced off, so the math is exact and padded projections get zero gradients
    # (6.7% extra compute for smollm's 15 heads vs 1600% replication -- §Perf).
    H_orig = H
    mesh = get_mesh()
    if mesh is not None and H % mesh.shape.get("model", 1):
        tp_size = mesh.shape["model"]
        H_pad = -(-H // tp_size) * tp_size
        zeros = jnp.zeros((B, Sq, H_pad - H, hd), q.dtype)
        q = jnp.concatenate([q, zeros], axis=2)
        zk = jnp.zeros((B, Sk, H_pad - H, hd), k.dtype)
        k = jnp.concatenate([k, zk], axis=2)
        v = jnp.concatenate([v, zk], axis=2)
        H = H_pad
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Sk)
    nq, nk = Sq // q_chunk, Sk // kv_chunk
    assert Sq % q_chunk == 0 and Sk % kv_chunk == 0, (Sq, q_chunk, Sk, kv_chunk)
    scale = 1.0 / math.sqrt(hd)

    qb = q.reshape(B, nq, q_chunk, H, hd).astype(jnp.float32)
    kb = k.reshape(B, nk, kv_chunk, H, hd).astype(jnp.float32)
    vb = v.reshape(B, nk, kv_chunk, H, hd).astype(jnp.float32)
    qb = shard(qb, "fsdp", None, None, "tp", None)
    kb = shard(kb, "fsdp", None, None, "tp", None)
    vb = shard(vb, "fsdp", None, None, "tp", None)

    def per_qblock(qi, qblk):
        q_pos = qi * q_chunk + jnp.arange(q_chunk) + kv_offset

        @jax.checkpoint  # recompute p-blocks in the backward: never materialize
        def body(carry, inp):  # the (nq, nk, qc, kc) residual stacks (= S^2)
            acc, m, l = carry
            ki, kblk, vblk = inp
            s = jnp.einsum("bqhd,bkhd->bhqk", qblk, kblk) * scale
            if causal:
                k_pos = ki * kv_chunk + jnp.arange(kv_chunk)
                mask = q_pos[:, None] >= k_pos[None, :]
                s = jnp.where(mask[None, None], s, -1e30)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l = l * alpha + p.sum(-1)
            acc = acc * alpha[..., None] + jnp.einsum("bhqk,bkhd->bhqd", p, vblk)
            return (acc, m_new, l), None

        acc0 = jnp.zeros((B, H, q_chunk, hd), jnp.float32)
        m0 = jnp.full((B, H, q_chunk), -1e30, jnp.float32)
        l0 = jnp.zeros((B, H, q_chunk), jnp.float32)
        (acc, _, l), _ = jax.lax.scan(
            body, (acc0, m0, l0),
            (jnp.arange(nk), jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0)))
        return acc / jnp.maximum(l[..., None], 1e-30)

    out = jax.lax.map(lambda args: per_qblock(*args),
                      (jnp.arange(nq), jnp.moveaxis(qb, 1, 0)))
    out = jnp.moveaxis(out, 0, 1)                      # (B, nq, H, q_chunk, hd)
    out = jnp.moveaxis(out, 2, 3).reshape(B, Sq, H, hd)
    if H != H_orig:
        out = out[:, :, :H_orig]
    return out.astype(q.dtype)


def attention_decode(q: jnp.ndarray, k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                     cache_len: jnp.ndarray) -> jnp.ndarray:
    """Single-token attention against a cache.

    q: (B, 1, H, hd); caches: (B, S, Hkv, hd); cache_len: () or (B,) valid length."""
    B, _, H, hd = q.shape
    S, Hkv = k_cache.shape[1], k_cache.shape[2]
    g = H // Hkv
    # caches stay in their storage dtype; accumulate in f32 via the MXU --
    # casting a 32k-500k cache to f32 would double decode HBM (measured in the
    # dry-run before this change)
    qg = q.reshape(B, Hkv, g, hd)
    s = jnp.einsum("bhgd,bshd->bhgs", qg, k_cache,
                   preferred_element_type=jnp.float32) / math.sqrt(hd)
    pos = jnp.arange(S)
    valid = pos[None, :] < jnp.reshape(cache_len, (-1, 1))
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1).astype(k_cache.dtype)
    o = jnp.einsum("bhgs,bshd->bhgd", p, v_cache,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, 1, H, hd).astype(q.dtype)


def attention_init(key, cfg: ModelConfig, d_model: int | None = None):
    D = d_model or cfg.d_model
    hd, H, Hkv = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    params = {
        "wq": ninit(ks[0], (D, H * hd)),
        "wk": ninit(ks[1], (D, Hkv * hd)),
        "wv": ninit(ks[2], (D, Hkv * hd)),
        "wo": ninit(ks[3], (H * hd, D), scale=1.0 / math.sqrt(H * hd)),
    }
    specs = {
        "wq": ("fsdp", ("tp", H * hd)),
        "wk": ("fsdp", ("tp", Hkv * hd)),
        "wv": ("fsdp", ("tp", Hkv * hd)),
        "wo": (("tp", H * hd), "fsdp"),
    }
    if cfg.qkv_bias:
        params |= {"bq": zinit(None, (H * hd,)), "bk": zinit(None, (Hkv * hd,)),
                   "bv": zinit(None, (Hkv * hd,))}
        specs |= {"bq": (("tp", H * hd),), "bk": (("tp", Hkv * hd),),
                  "bv": (("tp", Hkv * hd),)}
    return params, specs


def attention_qkv(p, x, cfg: ModelConfig):
    """Project to (q, k, v) with head reshape; x (B, S, D)."""
    B, S, _ = x.shape
    hd, H, Hkv = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    dt = x.dtype
    q = x @ wcast(p["wq"], dt, "fsdp", "tp")
    k = x @ wcast(p["wk"], dt, "fsdp", "tp")
    v = x @ wcast(p["wv"], dt, "fsdp", "tp")
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    return (shard(q.reshape(B, S, H, hd), "fsdp", None, "tp", None),
            shard(k.reshape(B, S, Hkv, hd), "fsdp", None, "tp", None),
            shard(v.reshape(B, S, Hkv, hd), "fsdp", None, "tp", None))


# ------------------------------------------------------------------------- MLPs

def mlp_init(key, cfg: ModelConfig, d_ff: int | None = None):
    D, F = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.mlp == "swiglu":
        params = {"w_gate": ninit(ks[0], (D, F)), "w_up": ninit(ks[1], (D, F)),
                  "w_down": ninit(ks[2], (F, D), scale=1.0 / math.sqrt(F))}
        specs = {"w_gate": ("fsdp", ("tp", F)), "w_up": ("fsdp", ("tp", F)),
                 "w_down": (("tp", F), "fsdp")}
    else:
        params = {"w_up": ninit(ks[0], (D, F)),
                  "w_down": ninit(ks[1], (F, D), scale=1.0 / math.sqrt(F))}
        specs = {"w_up": ("fsdp", ("tp", F)), "w_down": (("tp", F), "fsdp")}
    return params, specs


def wcast(w, dt, *entries):
    """Cast a stored-f32 weight to compute dtype *keeping its sharding*, so any
    FSDP all-gather at the use site moves bf16 wire bytes, not f32 (measured 2x
    collective reduction on dbrx, EXPERIMENTS.md §Perf)."""
    return shard(w.astype(dt), *entries)


def mlp_apply(p, x, cfg: ModelConfig):
    dt = x.dtype
    if cfg.mlp == "swiglu":
        g = jax.nn.silu(shard(x @ wcast(p["w_gate"], dt, "fsdp", "tp"),
                              "fsdp", None, "tp"))
        return (g * (x @ wcast(p["w_up"], dt, "fsdp", "tp"))) \
            @ wcast(p["w_down"], dt, "tp", "fsdp")
    h = shard(x @ wcast(p["w_up"], dt, "fsdp", "tp"), "fsdp", None, "tp")
    h = jnp.square(jax.nn.relu(h)) if cfg.mlp == "relu2" else jax.nn.gelu(h)
    return h @ wcast(p["w_down"], dt, "tp", "fsdp")


# -------------------------------------------------------------------------- MoE

def moe_init(key, cfg: ModelConfig):
    D, F, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    params = {
        "router": ninit(ks[0], (D, E)),
        "experts_gate": ninit(ks[1], (E, D, F)),
        "experts_up": ninit(ks[2], (E, D, F)),
        "experts_down": ninit(ks[3], (E, F, D), scale=1.0 / math.sqrt(F)),
    }
    specs = {
        "router": ("fsdp", None),
        "experts_gate": (("tp", E), "fsdp", None),
        "experts_up": (("tp", E), "fsdp", None),
        "experts_down": (("tp", E), None, "fsdp"),
    }
    return params, specs


def moe_apply(p, x, cfg: ModelConfig):
    """GShard-style top-k dispatch with per-group capacity (paper-standard einsum
    formulation; XLA SPMD turns the expert dim sharding into all-to-alls)."""
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    dt = x.dtype
    n = B * S
    g = min(cfg.moe_group_size, n)
    assert n % g == 0, (n, g)
    G = n // g
    cap = max(1, int(math.ceil(g * k * cfg.capacity_factor / E)))
    xg = shard(x.reshape(G, g, D), "fsdp", None, None)
    logits = (xg @ p["router"].astype(dt)).astype(jnp.float32)    # (G, g, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_v, gate_i = jax.lax.top_k(probs, k)                      # (G, g, k)
    gate_v = gate_v / jnp.maximum(gate_v.sum(-1, keepdims=True), 1e-9)
    # position of each (token, slot) within its expert queue
    onehot = jax.nn.one_hot(gate_i, E, dtype=jnp.float32)         # (G, g, k, E)
    slot_flat = onehot.reshape(G, g * k, E)
    pos = jnp.cumsum(slot_flat, axis=1) - slot_flat               # (G, g*k, E)
    pos = pos.reshape(G, g, k, E)
    keep = (pos < cap) & (onehot > 0)
    pos_c = jnp.clip(pos.astype(jnp.int32), 0, cap - 1)
    cap_oh = jax.nn.one_hot(pos_c, cap, dtype=jnp.float32) * keep[..., None]
    # dispatch (G,g,E,cap) / combine weighted by gate
    dispatch = cap_oh.sum(2)                                      # (G, g, E, cap)
    combine = (cap_oh * gate_v[..., None, None]).sum(2)           # (G, g, E, cap)
    xe = jnp.einsum("Ggec,Ggd->eGcd", dispatch.astype(dt), xg)    # (E, G, cap, D)
    xe = shard(xe, "tp", "fsdp", None, None)
    h = jax.nn.silu(jnp.einsum("eGcd,edf->eGcf", xe,
                               wcast(p["experts_gate"], dt, "tp", "fsdp", None)))
    h = h * jnp.einsum("eGcd,edf->eGcf", xe,
                       wcast(p["experts_up"], dt, "tp", "fsdp", None))
    h = shard(h, "tp", "fsdp", None, None)
    ye = jnp.einsum("eGcf,efd->eGcd", h,
                    wcast(p["experts_down"], dt, "tp", None, "fsdp"))
    y = jnp.einsum("Ggec,eGcd->Ggd", combine.astype(dt), ye)
    aux = _load_balance_loss(probs, onehot)
    return y.reshape(B, S, D), aux


def _load_balance_loss(probs: jnp.ndarray, onehot: jnp.ndarray) -> jnp.ndarray:
    """Switch-style auxiliary load-balancing loss."""
    E = probs.shape[-1]
    frac_tokens = onehot.sum(2).mean(axis=(0, 1))    # (E,)
    frac_probs = probs.mean(axis=(0, 1))
    return E * jnp.sum(frac_tokens * frac_probs)


# -------------------------------------------------------------------- embedding

VOCAB_PAD = 16  # pad the embedding vocab dim to a TP multiple (odd vocabs would
                # otherwise replicate the logits -- 16x memory on seamless-m4t)


def padded_vocab(v: int) -> int:
    return -(-v // VOCAB_PAD) * VOCAB_PAD


def embed_init(key, cfg: ModelConfig):
    V, D = padded_vocab(cfg.vocab), cfg.d_model
    params = {"embedding": ninit(key, (V, D), scale=1.0)}
    specs = {"embedding": (("tp", V), "fsdp")}
    if not cfg.tie_embeddings:
        params["lm_head"] = ninit(jax.random.fold_in(key, 1), (D, V))
        specs["lm_head"] = ("fsdp", ("tp", V))
    return params, specs


def embed_lookup(p, tokens: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    x = p["embedding"].astype(cfg.dtype)[tokens]
    return shard(x, *("fsdp",) + (None,) * (x.ndim - 1))


def lm_logits(p, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    w = p["embedding"].T if cfg.tie_embeddings else p["lm_head"]
    logits = shard(x @ w.astype(x.dtype), "fsdp", None, "tp")
    if logits.shape[-1] != cfg.vocab:  # mask the vocab padding
        pad_id = jnp.arange(logits.shape[-1]) >= cfg.vocab
        logits = jnp.where(pad_id, jnp.asarray(-1e30, logits.dtype), logits)
    return logits


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  z_loss: float = 1e-4) -> jnp.ndarray:
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    loss = lse - ll
    if z_loss:
        loss = loss + z_loss * jnp.square(lse)
    return loss.mean()


# -------------------------------------------------------- logical spec resolution

def resolve_specs(spec_tree, axes: dict[str, int], fsdp: tuple[str, ...],
                  tp: str, extra_leading: int = 0):
    """Turn logical spec tuples into PartitionSpecs.

    Logical entries: None | "fsdp" | "tp" | ("tp"|"fsdp", dim_size) -- the sized form
    shards only if dim_size divides the axis size (small archs replicate instead).
    Subtrees wrapped as ("stacked", subtree) / ("stacked2", subtree) get one / two
    leading None dims (lax.scan-stacked layer parameters).
    """
    fsdp_size = int(np.prod([axes[a] for a in fsdp])) if fsdp else 1
    tp_size = axes[tp]
    fsdp_name = fsdp if len(fsdp) > 1 else fsdp[0]

    def one(entry):
        if entry is None:
            return None
        if entry == "fsdp":
            return fsdp_name
        if entry == "tp":
            return tp
        kind, dim = entry
        size = fsdp_size if kind == "fsdp" else tp_size
        axis = fsdp_name if kind == "fsdp" else tp
        return axis if dim % size == 0 else None

    def walk(t, lead):
        if (isinstance(t, tuple) and len(t) == 2
                and t[0] in ("stacked", "stacked2") and isinstance(t[1], dict)):
            return walk(t[1], lead + (1 if t[0] == "stacked" else 2))
        if isinstance(t, dict):
            return {k: walk(v, lead) for k, v in t.items()}
        if isinstance(t, tuple):
            return P(*(None,) * lead, *(one(e) for e in t))
        raise TypeError(f"bad spec entry {t!r}")

    return walk(spec_tree, extra_leading)
