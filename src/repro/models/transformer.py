"""Decoder-only transformer LM covering the dense, MoE and VLM families.

Layers are scanned (`lax.scan` over stacked params) so full-size configs compile fast;
remat policy is applied per-layer by the training substrate.  Serving uses an explicit
KV cache threaded through the same scan.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.sharding_ctx import shard


# ------------------------------------------------------------------------ params

def layer_init(key, cfg: ModelConfig):
    ka, km = jax.random.split(key)
    attn_p, attn_s = L.attention_init(ka, cfg)
    if cfg.family == "moe":
        mlp_p, mlp_s = L.moe_init(km, cfg)
    else:
        mlp_p, mlp_s = L.mlp_init(km, cfg)
    params = {"attn": attn_p, "mlp": mlp_p,
              "norm1": L.oinit(None, (cfg.d_model,)),
              "norm2": L.oinit(None, (cfg.d_model,))}
    specs = {"attn": attn_s, "mlp": mlp_s, "norm1": (None,), "norm2": (None,)}
    return params, specs


def init(cfg: ModelConfig, key) -> tuple[Any, Any]:
    ke, kl = jax.random.split(key)
    emb_p, emb_s = L.embed_init(ke, cfg)
    layer_keys = jax.random.split(kl, cfg.n_layers)
    lp = jax.vmap(lambda k: layer_init(k, cfg)[0])(layer_keys)
    _, ls = layer_init(kl, cfg)
    params = {"embed": emb_p, "layers": lp,
              "final_norm": L.oinit(None, (cfg.d_model,))}
    specs = {"embed": emb_s, "layers": ("stacked", ls), "final_norm": (None,)}
    return params, specs


# ----------------------------------------------------------------------- forward

def _layer_fwd(cfg: ModelConfig, x, lp, positions, pos3=None):
    x = shard(x, "fsdp", None, None)
    h = L.rms_norm(x, lp["norm1"], cfg.norm_eps)
    q, k, v = L.attention_qkv(lp["attn"], h, cfg)
    if cfg.mrope:
        q = L.apply_mrope(q, pos3, cfg.rope_theta, cfg.mrope_sections)
        k = L.apply_mrope(k, pos3, cfg.rope_theta, cfg.mrope_sections)
    else:
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
    attn = L.flash_attention(q, k, v, causal=True)
    B, S, _, _ = attn.shape
    x = x + attn.reshape(B, S, -1) @ lp["attn"]["wo"].astype(x.dtype)
    h = L.rms_norm(x, lp["norm2"], cfg.norm_eps)
    if cfg.family == "moe":
        y, aux = L.moe_apply(lp["mlp"], h, cfg)
    else:
        y, aux = L.mlp_apply(lp["mlp"], h, cfg), 0.0
    return x + y, aux


def forward(params, cfg: ModelConfig, tokens, positions=None, pos3=None,
            prefix_embeds=None, remat_policy=None):
    """-> (hidden (B, S, D), aux_loss).  prefix_embeds (VLM): (B, Sp, D) patch
    embeddings prepended to the token embeddings."""
    x = L.embed_lookup(params["embed"], tokens, cfg)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    if cfg.mrope and pos3 is None:
        pos3 = jnp.broadcast_to(positions[:, None, :], (B, 3, S))

    def body(carry, lp):
        x, aux = carry
        x, a = _layer_fwd(cfg, x, lp, positions, pos3)
        return (x, aux + a), None

    body_fn = body if remat_policy is None else jax.checkpoint(
        body, policy=remat_policy)
    (x, aux), _ = jax.lax.scan(body_fn, (x, jnp.float32(0.0)), params["layers"])
    return L.rms_norm(x, params["final_norm"], cfg.norm_eps), aux


def train_loss(params, cfg: ModelConfig, batch, remat_policy=None):
    tokens = batch["tokens"]
    labels = batch["labels"]
    x, aux = forward(params, cfg, tokens,
                     pos3=batch.get("pos3"),
                     prefix_embeds=batch.get("patch_embeds"),
                     remat_policy=remat_policy)
    if batch.get("patch_embeds") is not None:
        x = x[:, batch["patch_embeds"].shape[1]:]  # loss over text positions
    logits = L.lm_logits(params["embed"], x, cfg)
    return L.cross_entropy(logits, labels) + 0.01 * aux


# ----------------------------------------------------------------------- serving

def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None):
    dtype = dtype or cfg.dtype
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
            "len": jnp.zeros((), jnp.int32)}


def cache_specs(cfg: ModelConfig, tp_size: int = 16):
    """Logical partition specs for the KV cache.

    Heads shard over tp when divisible; otherwise the *sequence* dim does --
    decode attention contracts over S, so XLA reduces partial sums instead of
    replicating a multi-GB cache per chip."""
    if cfg.n_kv_heads % tp_size == 0:
        kv = (None, "fsdp", None, "tp", None)
    else:
        kv = (None, "fsdp", "tp", None, None)
    return {"k": kv, "v": kv, "len": ()}


def prefill(params, cfg: ModelConfig, tokens, cache, positions=None, pos3=None,
            prefix_embeds=None):
    """Run the full prompt, fill the cache, return logits of the last position."""
    x = L.embed_lookup(params["embed"], tokens, cfg)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    if cfg.mrope and pos3 is None:
        pos3 = jnp.broadcast_to(positions[:, None, :], (B, 3, S))

    def body(x, inp):
        lp, = inp
        h = L.rms_norm(x, lp["norm1"], cfg.norm_eps)
        q, k, v = L.attention_qkv(lp["attn"], h, cfg)
        if cfg.mrope:
            q = L.apply_mrope(q, pos3, cfg.rope_theta, cfg.mrope_sections)
            k = L.apply_mrope(k, pos3, cfg.rope_theta, cfg.mrope_sections)
        else:
            q = L.apply_rope(q, positions, cfg.rope_theta)
            k = L.apply_rope(k, positions, cfg.rope_theta)
        attn = L.flash_attention(q, k, v, causal=True)
        x = x + attn.reshape(B, S, -1) @ lp["attn"]["wo"].astype(x.dtype)
        h = L.rms_norm(x, lp["norm2"], cfg.norm_eps)
        if cfg.family == "moe":
            y, _ = L.moe_apply(lp["mlp"], h, cfg)
        else:
            y = L.mlp_apply(lp["mlp"], h, cfg)
        return x + y, (k.astype(cache["k"].dtype), v.astype(cache["v"].dtype))

    x, (ks, vs) = jax.lax.scan(body, x, (params["layers"],))
    k_new = jax.lax.dynamic_update_slice(cache["k"], ks, (0, 0, 0, 0, 0))
    v_new = jax.lax.dynamic_update_slice(cache["v"], vs, (0, 0, 0, 0, 0))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.lm_logits(params["embed"], x[:, -1:], cfg)
    return logits, {"k": k_new, "v": v_new, "len": jnp.int32(S)}


def decode_step(params, cfg: ModelConfig, token, cache, pos3=None):
    """One new token against the cache.  token: (B, 1) int32."""
    B = token.shape[0]
    pos = cache["len"]
    positions = jnp.full((B, 1), pos, jnp.int32)
    x = L.embed_lookup(params["embed"], token, cfg)
    if cfg.mrope and pos3 is None:
        pos3 = jnp.broadcast_to(positions[:, None, :], (B, 3, 1))

    def body(x, inp):
        lp, kc, vc = inp
        h = L.rms_norm(x, lp["norm1"], cfg.norm_eps)
        q, k, v = L.attention_qkv(lp["attn"], h, cfg)
        if cfg.mrope:
            q = L.apply_mrope(q, pos3, cfg.rope_theta, cfg.mrope_sections)
            k = L.apply_mrope(k, pos3, cfg.rope_theta, cfg.mrope_sections)
        else:
            q = L.apply_rope(q, positions, cfg.rope_theta)
            k = L.apply_rope(k, positions, cfg.rope_theta)
        k = k.astype(kc.dtype)
        v = v.astype(vc.dtype)
        kc = jax.lax.dynamic_update_slice(kc, k, (0, pos, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v, (0, pos, 0, 0))
        attn = L.attention_decode(q, kc, vc, pos + 1)
        x = x + attn.reshape(B, 1, -1) @ lp["attn"]["wo"].astype(x.dtype)
        h = L.rms_norm(x, lp["norm2"], cfg.norm_eps)
        if cfg.family == "moe":
            y, _ = L.moe_apply(lp["mlp"], h, cfg)
        else:
            y = L.mlp_apply(lp["mlp"], h, cfg)
        # ys carry only the new (B,1,Hkv,hd) slice -- streaming the full cache
        # through scan stacking costs an extra cache-sized buffer per step
        return x + y, (k, v)

    x, (ks, vs) = jax.lax.scan(body, x,
                               (params["layers"], cache["k"], cache["v"]))
    k_new = jax.lax.dynamic_update_slice(cache["k"], ks, (0, 0, pos, 0, 0))
    v_new = jax.lax.dynamic_update_slice(cache["v"], vs, (0, 0, pos, 0, 0))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.lm_logits(params["embed"], x, cfg)
    new_cache = {"k": k_new, "v": v_new, "len": pos + 1}
    return logits, new_cache
