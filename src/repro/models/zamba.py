"""Zamba2-style hybrid: Mamba2 backbone + one *shared* (weight-tied) attention+MLP
block interposed every ``attn_every`` inner layers.

Layer layout for n_layers=81, attn_every=6: 13 super-blocks of (6 mamba layers +
shared attention), then 3 tail mamba layers.  The shared block's KV cache therefore
has 13 entries (one per application) -- attention cost at decode is O(S) per token
while the mamba state is O(1), so 500k-context serving remains deployable
(DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.ssm import mamba_layer_fwd, mamba_layer_init


def _split(cfg: ModelConfig) -> tuple[int, int, int]:
    k = cfg.attn_every
    n_super = cfg.n_layers // k
    tail = cfg.n_layers - n_super * k
    return n_super, k, tail


def shared_block_init(key, cfg: ModelConfig):
    ka, km = jax.random.split(key)
    attn_p, attn_s = L.attention_init(ka, cfg)
    mlp_p, mlp_s = L.mlp_init(km, cfg)
    params = {"attn": attn_p, "mlp": mlp_p,
              "norm1": L.oinit(None, (cfg.d_model,)),
              "norm2": L.oinit(None, (cfg.d_model,))}
    specs = {"attn": attn_s, "mlp": mlp_s, "norm1": (None,), "norm2": (None,)}
    return params, specs


def init(cfg: ModelConfig, key):
    n_super, k, tail = _split(cfg)
    ke, km, kt, ks = jax.random.split(key, 4)
    emb_p, emb_s = L.embed_init(ke, cfg)
    main = jax.vmap(lambda kk: jax.vmap(
        lambda k2: mamba_layer_init(k2, cfg)[0])(jax.random.split(kk, k)))(
        jax.random.split(km, n_super))
    tail_p = jax.vmap(lambda k2: mamba_layer_init(k2, cfg)[0])(
        jax.random.split(kt, max(tail, 1)))
    _, mspec = mamba_layer_init(km, cfg)
    sh_p, sh_s = shared_block_init(ks, cfg)
    params = {"embed": emb_p, "mamba_main": main, "mamba_tail": tail_p,
              "shared": sh_p, "final_norm": L.oinit(None, (cfg.d_model,))}
    specs = {"embed": emb_s, "mamba_main": ("stacked2", mspec),
             "mamba_tail": ("stacked", mspec), "shared": sh_s,
             "final_norm": (None,)}
    return params, specs


def init_state(cfg: ModelConfig, batch: int, max_len: int, dtype=None):
    """Mamba states for all layers + shared-attention KV cache (n_super entries)."""
    dtype = dtype or cfg.dtype
    n_super, k, tail = _split(cfg)
    d_in = 2 * cfg.d_model
    H, N = cfg.ssm_heads, cfg.ssm_state
    P = d_in // H
    nl = cfg.n_layers
    return {
        "conv": jnp.zeros((nl, batch, 3, d_in), dtype),
        "ssd": jnp.zeros((nl, batch, H, P, N), jnp.float32),
        "k": jnp.zeros((n_super, batch, max_len, cfg.n_kv_heads, cfg.hd), dtype),
        "v": jnp.zeros((n_super, batch, max_len, cfg.n_kv_heads, cfg.hd), dtype),
        "len": jnp.zeros((), jnp.int32),
    }


def state_specs(cfg: ModelConfig, tp_size: int = 16, batch: int | None = None,
                fsdp_size: int = 16):
    heads_ok = cfg.n_kv_heads % tp_size == 0
    batch_ok = batch is None or batch % fsdp_size == 0
    if heads_ok and batch_ok:
        kv = (None, "fsdp", None, "tp", None)
    elif heads_ok:
        # tiny batch (long-context decode): the data axis is idle -- shard the
        # cache sequence over it instead of replicating GBs per chip (§Perf)
        kv = (None, None, "fsdp", "tp", None)
    else:
        kv = (None, "fsdp", "tp", None, None)
    return {"conv": (None, "fsdp", None, ("tp", 2 * cfg.d_model)),
            "ssd": (None, "fsdp", ("tp", cfg.ssm_heads), None, None),
            "k": kv, "v": kv, "len": ()}


def _shared_attn_train(cfg, sp, x, positions):
    h = L.rms_norm(x, sp["norm1"], cfg.norm_eps)
    q, k, v = L.attention_qkv(sp["attn"], h, cfg)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    attn = L.flash_attention(q, k, v, causal=True)
    B, S = x.shape[:2]
    x = x + attn.reshape(B, S, -1) @ sp["attn"]["wo"].astype(x.dtype)
    h = L.rms_norm(x, sp["norm2"], cfg.norm_eps)
    return x + L.mlp_apply(sp["mlp"], h, cfg), (k, v)


def _forward(params, cfg, tokens, state, mode: str, remat_policy=None):
    n_super, k, tail = _split(cfg)
    x = L.embed_lookup(params["embed"], tokens, cfg)
    B, S, _ = x.shape
    base = state["len"] if state is not None else jnp.int32(0)
    positions = base + jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    st = state or init_state(cfg, B, S)
    conv = st["conv"].astype(x.dtype)
    ssd = st["ssd"]
    conv_main = conv[: n_super * k].reshape(n_super, k, B, 3, conv.shape[-1])
    ssd_main = ssd[: n_super * k].reshape(n_super, k, *ssd.shape[1:])
    sp = params["shared"]

    def inner(x, inp):
        lp, cv, sd = inp
        x, ns = mamba_layer_fwd(cfg, lp, x, {"conv": cv, "ssd": sd})
        return x, (ns["conv"], ns["ssd"])

    def super_body(carry, inp):
        x = carry
        lp6, cv6, sd6, kc, vc = inp
        x, (cv6n, sd6n) = jax.lax.scan(inner, x, (lp6, cv6, sd6))
        if mode == "train":
            x, (kn, vn) = _shared_attn_train(cfg, sp, x, positions)
        else:
            # extend the cache with this segment's K/V, attend against it; ys
            # carry only the new (B,S,Hkv,hd) slice (never the full cache)
            h = L.rms_norm(x, sp["norm1"], cfg.norm_eps)
            q, kq, vq = L.attention_qkv(sp["attn"], h, cfg)
            q = L.apply_rope(q, positions, cfg.rope_theta)
            kq = L.apply_rope(kq, positions, cfg.rope_theta)
            kn, vn = kq.astype(kc.dtype), vq.astype(vc.dtype)
            if S == 1:
                kc2 = jax.lax.dynamic_update_slice(kc, kn, (0, base, 0, 0))
                vc2 = jax.lax.dynamic_update_slice(vc, vn, (0, base, 0, 0))
                attn = L.attention_decode(q, kc2, vc2, base + 1)
            else:  # prefill from scratch: the segment IS the cache prefix
                attn = L.flash_attention(q, kn, vn, causal=True)
            x = x + attn.reshape(B, S, -1) @ sp["attn"]["wo"].astype(x.dtype)
            h = L.rms_norm(x, sp["norm2"], cfg.norm_eps)
            x = x + L.mlp_apply(sp["mlp"], h, cfg)
        return x, (cv6n, sd6n, kn, vn)

    body = super_body if remat_policy is None else jax.checkpoint(
        super_body, policy=remat_policy)
    kc = st["k"].astype(x.dtype) if mode != "train" else \
        jnp.zeros((n_super, B, S, cfg.n_kv_heads, cfg.hd), x.dtype)
    vc = st["v"].astype(x.dtype) if mode != "train" else kc
    x, (cv_m, sd_m, k_sl, v_sl) = jax.lax.scan(
        body, x, (params["mamba_main"], conv_main, ssd_main, kc, vc))
    if mode == "train":
        k_new, v_new = k_sl, v_sl
    else:  # one post-scan write of the stacked new slices into the donated cache
        k_new = jax.lax.dynamic_update_slice(st["k"], k_sl, (0, 0, base, 0, 0))
        v_new = jax.lax.dynamic_update_slice(st["v"], v_sl, (0, 0, base, 0, 0))

    if tail:
        x, (cv_t, sd_t) = jax.lax.scan(
            inner, x, (params["mamba_tail"], conv[n_super * k:],
                       ssd[n_super * k:]))
        conv_new = jnp.concatenate([cv_m.reshape(-1, B, 3, conv.shape[-1]), cv_t])
        ssd_new = jnp.concatenate([sd_m.reshape(-1, *ssd.shape[1:]), sd_t])
    else:
        conv_new = cv_m.reshape(-1, B, 3, conv.shape[-1])
        ssd_new = sd_m.reshape(-1, *ssd.shape[1:])
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    new_state = {"conv": conv_new, "ssd": ssd_new, "k": k_new, "v": v_new,
                 "len": base + S}
    return x, new_state


def train_loss(params, cfg: ModelConfig, batch, remat_policy=None):
    x, _ = _forward(params, cfg, batch["tokens"], None, "train",
                    remat_policy=remat_policy)
    logits = L.lm_logits(params["embed"], x, cfg)
    return L.cross_entropy(logits, batch["labels"])


def prefill(params, cfg: ModelConfig, tokens, state):
    x, ns = _forward(params, cfg, tokens, state, "prefill")
    logits = L.lm_logits(params["embed"], x[:, -1:], cfg)
    return logits, ns


def decode_step(params, cfg: ModelConfig, token, state):
    x, ns = _forward(params, cfg, token, state, "decode")
    logits = L.lm_logits(params["embed"], x, cfg)
    return logits, ns
