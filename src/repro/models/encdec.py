"""Seamless-M4T-style encoder-decoder backbone (audio family).

The audio frontend is a STUB per the assignment: ``input_specs`` provides precomputed
frame embeddings (B, S_src, D).  Shape conventions (recorded in DESIGN.md):
  train_4k / prefill_32k -- encoder consumes seq_len frames; decoder runs seq_len // 8
  target tokens (speech-to-text length ratio).
  decode shapes -- one decoder token against a self-KV cache of seq_len and a cross
  memory of seq_len // 8 encoder states.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L

SRC_RATIO = 8  # decoder length = encoder length // SRC_RATIO for train/prefill


def enc_layer_init(key, cfg: ModelConfig):
    ka, km = jax.random.split(key)
    attn_p, attn_s = L.attention_init(ka, cfg)
    mlp_p, mlp_s = L.mlp_init(km, cfg)
    return ({"attn": attn_p, "mlp": mlp_p, "norm1": L.oinit(None, (cfg.d_model,)),
             "norm2": L.oinit(None, (cfg.d_model,))},
            {"attn": attn_s, "mlp": mlp_s, "norm1": (None,), "norm2": (None,)})


def dec_layer_init(key, cfg: ModelConfig):
    ks, kc, km = jax.random.split(key, 3)
    self_p, self_s = L.attention_init(ks, cfg)
    cross_p, cross_s = L.attention_init(kc, cfg)
    mlp_p, mlp_s = L.mlp_init(km, cfg)
    return ({"self": self_p, "cross": cross_p, "mlp": mlp_p,
             "norm1": L.oinit(None, (cfg.d_model,)),
             "norm2": L.oinit(None, (cfg.d_model,)),
             "norm3": L.oinit(None, (cfg.d_model,))},
            {"self": self_s, "cross": cross_s, "mlp": mlp_s,
             "norm1": (None,), "norm2": (None,), "norm3": (None,)})


def init(cfg: ModelConfig, key):
    ke, k1, k2 = jax.random.split(key, 3)
    emb_p, emb_s = L.embed_init(ke, cfg)
    enc = jax.vmap(lambda k: enc_layer_init(k, cfg)[0])(
        jax.random.split(k1, cfg.enc_layers))
    dec = jax.vmap(lambda k: dec_layer_init(k, cfg)[0])(
        jax.random.split(k2, cfg.dec_layers))
    _, enc_s = enc_layer_init(k1, cfg)
    _, dec_s = dec_layer_init(k2, cfg)
    params = {"embed": emb_p, "enc": enc, "dec": dec,
              "enc_norm": L.oinit(None, (cfg.d_model,)),
              "final_norm": L.oinit(None, (cfg.d_model,))}
    specs = {"embed": emb_s, "enc": ("stacked", enc_s), "dec": ("stacked", dec_s),
             "enc_norm": (None,), "final_norm": (None,)}
    return params, specs


def encode(params, cfg: ModelConfig, frames, remat_policy=None):
    """frames: (B, S_src, D) stub frontend embeddings -> encoder memory."""
    x = frames.astype(cfg.dtype)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    def body(x, lp):
        h = L.rms_norm(x, lp["norm1"], cfg.norm_eps)
        q, k, v = L.attention_qkv(lp["attn"], h, cfg)
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
        attn = L.flash_attention(q, k, v, causal=False)
        x = x + attn.reshape(B, S, -1) @ lp["attn"]["wo"].astype(x.dtype)
        h = L.rms_norm(x, lp["norm2"], cfg.norm_eps)
        return x + L.mlp_apply(lp["mlp"], h, cfg), None

    body_fn = body if remat_policy is None else jax.checkpoint(
        body, policy=remat_policy)
    x, _ = jax.lax.scan(body_fn, x, params["enc"])
    return L.rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _cross_attn(cfg, lp, x, memory):
    B, S, _ = x.shape
    h = L.rms_norm(x, lp["norm2"], cfg.norm_eps)
    hd, H, Hkv = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    dt = x.dtype
    q = (h @ lp["cross"]["wq"].astype(dt)).reshape(B, S, H, hd)
    k = (memory @ lp["cross"]["wk"].astype(dt)).reshape(B, -1, Hkv, hd)
    v = (memory @ lp["cross"]["wv"].astype(dt)).reshape(B, -1, Hkv, hd)
    attn = L.flash_attention(q, k, v, causal=False)
    return x + attn.reshape(B, S, -1) @ lp["cross"]["wo"].astype(dt)


def decode_train(params, cfg: ModelConfig, tokens, memory, remat_policy=None):
    x = L.embed_lookup(params["embed"], tokens, cfg)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    def body(x, lp):
        h = L.rms_norm(x, lp["norm1"], cfg.norm_eps)
        q, k, v = L.attention_qkv(lp["self"], h, cfg)
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
        attn = L.flash_attention(q, k, v, causal=True)
        x = x + attn.reshape(B, S, -1) @ lp["self"]["wo"].astype(x.dtype)
        x = _cross_attn(cfg, lp, x, memory)
        h = L.rms_norm(x, lp["norm3"], cfg.norm_eps)
        return x + L.mlp_apply(lp["mlp"], h, cfg), None

    body_fn = body if remat_policy is None else jax.checkpoint(
        body, policy=remat_policy)
    x, _ = jax.lax.scan(body_fn, x, params["dec"])
    return L.rms_norm(x, params["final_norm"], cfg.norm_eps)


def train_loss(params, cfg: ModelConfig, batch, remat_policy=None):
    memory = encode(params, cfg, batch["frames"], remat_policy)
    x = decode_train(params, cfg, batch["tokens"], memory, remat_policy)
    logits = L.lm_logits(params["embed"], x, cfg)
    return L.cross_entropy(logits, batch["labels"])


# ----------------------------------------------------------------------- serving

def init_cache(cfg: ModelConfig, batch: int, max_len: int, src_len: int,
               dtype=None):
    dtype = dtype or cfg.dtype
    Lyr = cfg.dec_layers
    kv = (Lyr, batch, max_len, cfg.n_kv_heads, cfg.hd)
    ckv = (Lyr, batch, src_len, cfg.n_kv_heads, cfg.hd)
    return {"k": jnp.zeros(kv, dtype), "v": jnp.zeros(kv, dtype),
            "ck": jnp.zeros(ckv, dtype), "cv": jnp.zeros(ckv, dtype),
            "len": jnp.zeros((), jnp.int32)}


def cache_specs(cfg: ModelConfig, tp_size: int = 16):
    if cfg.n_kv_heads % tp_size == 0:
        kv = (None, "fsdp", None, "tp", None)
    else:
        kv = (None, "fsdp", "tp", None, None)
    return {"k": kv, "v": kv, "ck": kv, "cv": kv, "len": ()}


def prefill(params, cfg: ModelConfig, frames, tokens, cache):
    """Encode source frames, project cross-KV per layer, prefill decoder prompt."""
    memory = encode(params, cfg, frames)
    B, S = tokens.shape
    x = L.embed_lookup(params["embed"], tokens, cfg)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    hd, Hkv = cfg.hd, cfg.n_kv_heads
    dt = x.dtype

    def body(x, lp):
        h = L.rms_norm(x, lp["norm1"], cfg.norm_eps)
        q, k, v = L.attention_qkv(lp["self"], h, cfg)
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
        attn = L.flash_attention(q, k, v, causal=True)
        x = x + attn.reshape(B, S, -1) @ lp["self"]["wo"].astype(dt)
        ck = (memory @ lp["cross"]["wk"].astype(dt)).reshape(B, -1, Hkv, hd)
        cv = (memory @ lp["cross"]["wv"].astype(dt)).reshape(B, -1, Hkv, hd)
        x = _cross_attn(cfg, lp, x, memory)
        h = L.rms_norm(x, lp["norm3"], cfg.norm_eps)
        return x + L.mlp_apply(lp["mlp"], h, cfg), (k, v, ck, cv)

    x, (ks, vs, cks, cvs) = jax.lax.scan(body, x, params["dec"])
    cache = {"k": jax.lax.dynamic_update_slice(cache["k"], ks.astype(dt),
                                               (0, 0, 0, 0, 0)),
             "v": jax.lax.dynamic_update_slice(cache["v"], vs.astype(dt),
                                               (0, 0, 0, 0, 0)),
             "ck": cks.astype(dt), "cv": cvs.astype(dt), "len": jnp.int32(S)}
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return L.lm_logits(params["embed"], x[:, -1:], cfg), cache


def decode_step(params, cfg: ModelConfig, token, cache):
    B = token.shape[0]
    pos = cache["len"]
    positions = jnp.full((B, 1), pos, jnp.int32)
    x = L.embed_lookup(params["embed"], token, cfg)
    src_len = cache["ck"].shape[3 - 1]

    def body(x, inp):
        lp, kc, vc, ck, cv = inp
        h = L.rms_norm(x, lp["norm1"], cfg.norm_eps)
        q, k, v = L.attention_qkv(lp["self"], h, cfg)
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
        k = k.astype(kc.dtype)
        v = v.astype(vc.dtype)
        kc = jax.lax.dynamic_update_slice(kc, k, (0, pos, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v, (0, pos, 0, 0))
        attn = L.attention_decode(q, kc, vc, pos + 1)
        x = x + attn.reshape(B, 1, -1) @ lp["self"]["wo"].astype(x.dtype)
        h = L.rms_norm(x, lp["norm2"], cfg.norm_eps)
        hd, H = cfg.hd, cfg.n_heads
        qc = (h @ lp["cross"]["wq"].astype(x.dtype)).reshape(B, 1, H, hd)
        cattn = L.attention_decode(qc, ck, cv, jnp.int32(src_len))
        x = x + cattn.reshape(B, 1, -1) @ lp["cross"]["wo"].astype(x.dtype)
        h = L.rms_norm(x, lp["norm3"], cfg.norm_eps)
        return x + L.mlp_apply(lp["mlp"], h, cfg), (k, v)

    x, (ks, vs) = jax.lax.scan(
        body, x, (params["dec"], cache["k"], cache["v"], cache["ck"], cache["cv"]))
    k_new = jax.lax.dynamic_update_slice(cache["k"], ks, (0, 0, pos, 0, 0))
    v_new = jax.lax.dynamic_update_slice(cache["v"], vs, (0, 0, pos, 0, 0))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    new_cache = dict(cache, k=k_new, v=v_new, len=pos + 1)
    return L.lm_logits(params["embed"], x, cfg), new_cache
