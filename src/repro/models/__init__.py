"""Model substrate: layers + family programs + the uniform Model API."""
from repro.models.model import Model, cell_status, get_model

__all__ = ["Model", "cell_status", "get_model"]
