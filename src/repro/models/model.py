"""Uniform model API over all 10 assigned architectures.

``get_model(cfg)`` returns a ``Model`` whose members close over the config:
  init(key) -> (params, logical_specs)
  train_loss(params, batch, remat_policy)       -- next-token loss
  prefill(params, batch, state) -> (logits, state)
  decode_step(params, token_batch, state) -> (logits, state)
  make_state(batch, max_len) / state_specs()    -- KV cache or recurrent state
  input_specs(shape) -> (tree of ShapeDtypeStruct, tree of logical specs)

``input_specs`` provides the assignment-mandated ShapeDtypeStruct stand-ins: tokens
for LMs, stub frame embeddings for [audio], stub patch embeddings + M-RoPE ids for
[vlm] -- shardable, weak-type-correct, no allocation.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import encdec, rwkv, transformer, zamba
from repro.models.encdec import SRC_RATIO


@dataclasses.dataclass
class Model:
    cfg: ModelConfig
    init: Callable
    train_loss: Callable
    prefill: Callable
    decode_step: Callable
    make_state: Callable        # (batch, max_len) -> cache/recurrent state
    state_specs: Callable       # (batch=None) -> logical specs for the state
    input_specs: Callable       # (ShapeConfig) -> (shapes, logical specs)


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _lm_inputs(cfg: ModelConfig, shape: ShapeConfig):
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        shapes = {"token": _sds((B, 1), jnp.int32)}
        specs = {"token": ("fsdp", None)}
        return shapes, specs
    shapes = {"tokens": _sds((B, S), jnp.int32),
              "labels": _sds((B, S), jnp.int32)}
    specs = {"tokens": ("fsdp", None), "labels": ("fsdp", None)}
    return shapes, specs


def _vlm_inputs(cfg: ModelConfig, shape: ShapeConfig):
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        return ({"token": _sds((B, 1), jnp.int32)}, {"token": ("fsdp", None)})
    s_img = int(S * cfg.image_frac) // 256 * 256
    s_txt = S - s_img
    shapes = {"tokens": _sds((B, s_txt), jnp.int32),
              "labels": _sds((B, s_txt), jnp.int32),
              "patch_embeds": _sds((B, s_img, cfg.d_model), cfg.dtype),
              "pos3": _sds((B, 3, S), jnp.int32)}
    specs = {"tokens": ("fsdp", None), "labels": ("fsdp", None),
             "patch_embeds": ("fsdp", None, None), "pos3": ("fsdp", None, None)}
    return shapes, specs


def _encdec_inputs(cfg: ModelConfig, shape: ShapeConfig):
    B, S = shape.global_batch, shape.seq_len
    s_tgt = max(S // SRC_RATIO, 128)
    if shape.kind == "decode":
        return ({"token": _sds((B, 1), jnp.int32)}, {"token": ("fsdp", None)})
    shapes = {"frames": _sds((B, S, cfg.d_model), cfg.dtype),
              "tokens": _sds((B, s_tgt), jnp.int32),
              "labels": _sds((B, s_tgt), jnp.int32)}
    specs = {"frames": ("fsdp", None, None), "tokens": ("fsdp", None),
             "labels": ("fsdp", None)}
    return shapes, specs


def get_model(cfg: ModelConfig) -> Model:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        def prefill_fn(params, batch, state):
            return transformer.prefill(
                params, cfg, batch["tokens"], state,
                pos3=batch.get("pos3"), prefix_embeds=batch.get("patch_embeds"))

        return Model(
            cfg=cfg,
            init=lambda key: transformer.init(cfg, key),
            train_loss=lambda p, b, rp=None: transformer.train_loss(p, cfg, b, rp),
            prefill=prefill_fn,
            decode_step=lambda p, t, st: transformer.decode_step(p, cfg, t, st),
            make_state=lambda b, m: transformer.init_cache(cfg, b, m),
            state_specs=lambda b=None: transformer.cache_specs(cfg),
            input_specs=(lambda s: _vlm_inputs(cfg, s)) if fam == "vlm"
            else (lambda s: _lm_inputs(cfg, s)),
        )
    if fam == "ssm":
        return Model(
            cfg=cfg,
            init=lambda key: rwkv.init(cfg, key),
            train_loss=lambda p, b, rp=None: rwkv.train_loss(p, cfg, b, rp),
            prefill=lambda p, b, st: rwkv.prefill(p, cfg, b["tokens"], st),
            decode_step=lambda p, t, st: rwkv.decode_step(p, cfg, t, st),
            make_state=lambda b, m: rwkv.init_state(cfg, b),
            state_specs=lambda b=None: rwkv.state_specs(cfg),
            input_specs=lambda s: _lm_inputs(cfg, s),
        )
    if fam == "hybrid":
        return Model(
            cfg=cfg,
            init=lambda key: zamba.init(cfg, key),
            train_loss=lambda p, b, rp=None: zamba.train_loss(p, cfg, b, rp),
            prefill=lambda p, b, st: zamba.prefill(p, cfg, b["tokens"], st),
            decode_step=lambda p, t, st: zamba.decode_step(p, cfg, t, st),
            make_state=lambda b, m: zamba.init_state(cfg, b, m),
            state_specs=lambda b=None: zamba.state_specs(cfg, batch=b),
            input_specs=lambda s: _lm_inputs(cfg, s),
        )
    if fam == "encdec":
        def prefill_fn(params, batch, state):
            return encdec.prefill(params, cfg, batch["frames"], batch["tokens"],
                                  state)

        def make_state(b, m):
            return encdec.init_cache(cfg, b, m, max(m // SRC_RATIO, 128))

        return Model(
            cfg=cfg,
            init=lambda key: encdec.init(cfg, key),
            train_loss=lambda p, b, rp=None: encdec.train_loss(p, cfg, b, rp),
            prefill=prefill_fn,
            decode_step=lambda p, t, st: encdec.decode_step(p, cfg, t, st),
            make_state=make_state,
            state_specs=lambda b=None: encdec.cache_specs(cfg),
            input_specs=lambda s: _encdec_inputs(cfg, s),
        )
    raise ValueError(f"unknown family {fam}")


# --------------------------------------------------------------- shape skip rules

def cell_status(cfg: ModelConfig, shape: ShapeConfig) -> str:
    """'run' or a recorded skip reason (DESIGN.md shape-applicability)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return ("skip: pure full-attention arch -- O(S^2) prefill and a >TB KV cache "
                "at 524k tokens are not deployable (DESIGN.md)")
    return "run"
