"""Attention-free / hybrid families: RWKV6 ("Finch") and Mamba2 (for Zamba2).

Both use a *chunked* linear-recurrence formulation for train/prefill -- quadratic only
within a chunk (ssm_chunk), with an inter-chunk state scan -- and an O(1) recurrent
step for decode.  All recurrence math runs in f32.

Numerical scheme for the decay products (both models): factor the pairwise decay
exp(cum_t - cum_s) into exp(cum_t) * exp(-cum_s).  cum is non-increasing, so the first
factor only underflows (to a correct 0); the second factor's exponent is clamped at 60,
which only perturbs terms whose first factor already vanished.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.sharding_ctx import shard

_CLAMP = 60.0


def _chunk(x, c):  # (B, S, ...) -> (B, nc, c, ...)
    B, S = x.shape[:2]
    return x.reshape(B, S // c, c, *x.shape[2:])


# =============================================================== RWKV6 (Finch)

def rwkv_layer_init(key, cfg: ModelConfig):
    D, F = cfg.d_model, cfg.d_ff
    H, hd = cfg.n_heads, cfg.d_model // cfg.n_heads
    r = 64  # decay-LoRA rank
    ks = jax.random.split(key, 10)
    params = {
        "wr": L.ninit(ks[0], (D, D)), "wk": L.ninit(ks[1], (D, D)),
        "wv": L.ninit(ks[2], (D, D)), "wg": L.ninit(ks[3], (D, D)),
        "wo": L.ninit(ks[4], (D, D)),
        "w0": jnp.full((D,), -1.0, jnp.float32),          # base decay
        "w_lora_a": L.ninit(ks[5], (D, r)),
        "w_lora_b": L.zinit(None, (r, D)),
        "u": L.ninit(ks[6], (H, hd), scale=0.5),           # bonus
        "mix": jnp.full((5, D), 0.5, jnp.float32),         # token-shift mixes r/k/v/w/g
        "ln_x": L.oinit(None, (D,)),
        "cm_wk": L.ninit(ks[7], (D, F)), "cm_wv": L.ninit(ks[8], (F, D),
                                                          scale=1 / math.sqrt(F)),
        "cm_wr": L.ninit(ks[9], (D, D)),
        "cm_mix": jnp.full((2, D), 0.5, jnp.float32),
        "norm1": L.oinit(None, (D,)), "norm2": L.oinit(None, (D,)),
    }
    specs = {
        "wr": ("fsdp", ("tp", D)), "wk": ("fsdp", ("tp", D)),
        "wv": ("fsdp", ("tp", D)), "wg": ("fsdp", ("tp", D)),
        "wo": (("tp", D), "fsdp"),
        "w0": (("tp", D),), "w_lora_a": ("fsdp", None), "w_lora_b": (None, ("tp", D)),
        "u": (("tp", H), None), "mix": (None, None), "ln_x": (None,),
        "cm_wk": ("fsdp", ("tp", F)), "cm_wv": (("tp", F), "fsdp"),
        "cm_wr": ("fsdp", ("tp", D)), "cm_mix": (None, None),
        "norm1": (None,), "norm2": (None,),
    }
    return params, specs


def _token_shift(x, x_last):
    """x: (B, S, D); x_last: (B, D) hidden from the previous segment."""
    prev = jnp.concatenate([x_last[:, None], x[:, :-1]], axis=1)
    return prev


def _wkv_chunked(r, k, v, logw, u, s0, chunk: int):
    """r/k/v/logw: (B, S, H, hd) f32 (logw <= 0); u: (H, hd); s0: (B, H, hd, hd).
    Returns (y (B,S,H,hd), s_end)."""
    B, S, H, hd = r.shape
    c = min(chunk, S)
    assert S % c == 0
    rc, kc, vc, wc = (shard(jnp.moveaxis(_chunk(t, c), 3, 2),
                            "fsdp", None, "tp", None, None)
                      for t in (r, k, v, logw))
    # shapes now (B, nc, H, c, hd)

    @jax.checkpoint  # intra-chunk score blocks recompute in the backward
    def body(s, inp):
        rb, kb, vb, wb = inp                     # (B, H, c, hd)
        cum = jnp.cumsum(wb, axis=2)             # inclusive
        cum_ex = cum - wb                        # exclusive
        a = rb * jnp.exp(cum_ex)
        b = kb * jnp.exp(jnp.minimum(-cum, _CLAMP))
        scores = jnp.einsum("bhti,bhsi->bhts", a, b)
        t_idx = jnp.arange(c)
        mask = (t_idx[:, None] > t_idx[None, :]).astype(scores.dtype)
        y = jnp.einsum("bhts,bhsj->bhtj", scores * mask, vb)
        diag = jnp.sum(rb * u[None, :, None, :] * kb, axis=-1, keepdims=True)
        y = y + diag * vb
        y = y + jnp.einsum("bhti,bhij->bhtj", a, s)
        decay_all = jnp.exp(cum[:, :, -1:, :])   # (B, H, 1, hd)
        bs = b * decay_all
        s_new = jnp.exp(cum[:, :, -1, :])[..., None] * s \
            + jnp.einsum("bhsi,bhsj->bhij", bs, vb)
        return s_new, y

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (rc, kc, vc, wc))
    s_end, ys = jax.lax.scan(body, s0, xs)
    y = jnp.moveaxis(ys, 0, 1)                   # (B, nc, H, c, hd)
    y = jnp.moveaxis(y, 2, 3).reshape(B, S, H, hd)
    return y, s_end


def rwkv_layer_fwd(cfg: ModelConfig, lp, x, state=None):
    """x: (B, S, D).  state (decode/stream): dict with tm_last, cm_last, wkv."""
    B, S, D = x.shape
    H, hd = cfg.n_heads, D // cfg.n_heads
    dt = x.dtype
    tm_last = state["tm_last"] if state else jnp.zeros((B, D), dt)
    cm_last = state["cm_last"] if state else jnp.zeros((B, D), dt)
    s0 = state["wkv"] if state else jnp.zeros((B, H, hd, hd), jnp.float32)

    # ---- time mix ----
    x = shard(x, "fsdp", None, None)
    h = L.rms_norm(x, lp["norm1"], cfg.norm_eps)
    prev = _token_shift(h, tm_last)
    mix = lp["mix"].astype(dt)
    def mx(i):
        return h * mix[i] + prev * (1 - mix[i])
    r = (mx(0) @ lp["wr"].astype(dt)).reshape(B, S, H, hd)
    k = (mx(1) @ lp["wk"].astype(dt)).reshape(B, S, H, hd)
    v = (mx(2) @ lp["wv"].astype(dt)).reshape(B, S, H, hd)
    g = mx(4) @ lp["wg"].astype(dt)
    # data-dependent decay (the Finch contribution)
    lora = jnp.tanh(mx(3) @ lp["w_lora_a"].astype(dt)) @ lp["w_lora_b"].astype(dt)
    logw = -jnp.exp(lp["w0"].astype(jnp.float32) + lora.astype(jnp.float32))
    logw = logw.reshape(B, S, H, hd)
    y, s_end = _wkv_chunked(r.astype(jnp.float32), k.astype(jnp.float32),
                            v.astype(jnp.float32), logw,
                            lp["u"].astype(jnp.float32), s0, cfg.ssm_chunk)
    y = y.reshape(B, S, D).astype(dt)
    y = L.rms_norm(y, lp["ln_x"], cfg.norm_eps) * jax.nn.silu(g)
    x = x + y @ lp["wo"].astype(dt)
    tm_last_new = h[:, -1]

    # ---- channel mix ----
    h2 = L.rms_norm(x, lp["norm2"], cfg.norm_eps)
    prev2 = _token_shift(h2, cm_last)
    cmix = lp["cm_mix"].astype(dt)
    xk = h2 * cmix[0] + prev2 * (1 - cmix[0])
    xr = h2 * cmix[1] + prev2 * (1 - cmix[1])
    kk = jnp.square(jax.nn.relu(xk @ lp["cm_wk"].astype(dt)))
    out = jax.nn.sigmoid(xr @ lp["cm_wr"].astype(dt)) * (kk @ lp["cm_wv"].astype(dt))
    x = x + out
    new_state = {"tm_last": tm_last_new, "cm_last": h2[:, -1], "wkv": s_end}
    return x, new_state


# ============================================================== Mamba2 (SSD)

def mamba_layer_init(key, cfg: ModelConfig):
    D = cfg.d_model
    d_in = 2 * D
    H, N = cfg.ssm_heads, cfg.ssm_state
    P = d_in // H
    ks = jax.random.split(key, 7)
    params = {
        "w_z": L.ninit(ks[0], (D, d_in)), "w_x": L.ninit(ks[1], (D, d_in)),
        "w_B": L.ninit(ks[2], (D, N)), "w_C": L.ninit(ks[3], (D, N)),
        "w_dt": L.ninit(ks[4], (D, H)),
        "conv_w": L.ninit(ks[5], (4, d_in), scale=0.5),
        "A_log": jnp.zeros((H,), jnp.float32),
        "D_skip": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "ssm_norm": L.oinit(None, (d_in,)),
        "w_out": L.ninit(ks[6], (d_in, D), scale=1 / math.sqrt(d_in)),
        "norm": L.oinit(None, (D,)),
    }
    specs = {
        "w_z": ("fsdp", ("tp", d_in)), "w_x": ("fsdp", ("tp", d_in)),
        "w_B": ("fsdp", None), "w_C": ("fsdp", None),
        "w_dt": ("fsdp", ("tp", H)),
        "conv_w": (None, ("tp", d_in)),
        "A_log": (("tp", H),), "D_skip": (("tp", H),), "dt_bias": (("tp", H),),
        "ssm_norm": (None,), "w_out": (("tp", d_in), "fsdp"),
        "norm": (None,),
    }
    return params, specs


def _ssd_chunked(x, Bm, Cm, la, h0, chunk: int):
    """x: (B,S,H,P); Bm/Cm: (B,S,N); la: (B,S,H) log-decay*dt (<=0, already includes
    dt); x is already dt-scaled.  h0: (B,H,P,N).  Returns (y, h_end)."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    c = min(chunk, S)
    assert S % c == 0
    xc = jnp.moveaxis(_chunk(x, c), 3, 2)        # (B,nc,H,c,P)
    Bc = _chunk(Bm, c)                           # (B,nc,c,N)
    Cc = _chunk(Cm, c)
    lc = jnp.moveaxis(_chunk(la, c), 3, 2)       # (B,nc,H,c)

    @jax.checkpoint  # intra-chunk score blocks recompute in the backward
    def body(h, inp):
        xb, Bb, Cb, lb = inp                     # (B,H,c,P), (B,c,N), (B,c,N), (B,H,c)
        cum = jnp.cumsum(lb, axis=2)             # inclusive
        dplus = jnp.exp(cum)                     # (B,H,c)
        dminus = jnp.exp(jnp.minimum(-cum, _CLAMP))
        cb = jnp.einsum("btn,bsn->bts", Cb, Bb)  # (B,c,c)
        t_idx = jnp.arange(c)
        mask = (t_idx[:, None] >= t_idx[None, :])
        scores = cb[:, None] * dplus[..., :, None] * dminus[..., None, :]
        scores = jnp.where(mask[None, None], scores, 0.0)
        y = jnp.einsum("bhts,bhsp->bhtp", scores, xb)
        # contribution of the carried state
        y = y + jnp.einsum("btn,bhpn->bhtp", Cb, h) * dplus[..., None]
        # new state
        xb_dec = xb * (dminus * jnp.exp(cum[:, :, -1:]))[..., None]
        h_new = jnp.exp(cum[:, :, -1])[..., None, None] * h \
            + jnp.einsum("bhsp,bsn->bhpn", xb_dec, Bb)
        return h_new, y

    xs = (jnp.moveaxis(xc, 1, 0), jnp.moveaxis(Bc, 1, 0),
          jnp.moveaxis(Cc, 1, 0), jnp.moveaxis(lc, 1, 0))
    h_end, ys = jax.lax.scan(body, h0, xs)
    y = jnp.moveaxis(ys, 0, 1)                   # (B,nc,H,c,P)
    y = jnp.moveaxis(y, 2, 3).reshape(B, S, H, P)
    return y, h_end


def mamba_layer_fwd(cfg: ModelConfig, lp, x, state=None):
    """Mamba2 block.  state: {"conv": (B,3,d_in), "ssd": (B,H,P,N)}."""
    B, S, D = x.shape
    d_in = 2 * D
    H, N = cfg.ssm_heads, cfg.ssm_state
    P = d_in // H
    dt_ = x.dtype
    x = shard(x, "fsdp", None, None)
    h = L.rms_norm(x, lp["norm"], cfg.norm_eps)
    z = shard(h @ lp["w_z"].astype(dt_), "fsdp", None, "tp")
    xi = shard(h @ lp["w_x"].astype(dt_), "fsdp", None, "tp")
    conv_state = state["conv"] if state else jnp.zeros((B, 3, d_in), dt_)
    xi_pad = jnp.concatenate([conv_state, xi], axis=1)
    # depthwise causal conv, kernel 4
    conv_w = lp["conv_w"].astype(dt_)
    xi = sum(xi_pad[:, 3 - j:3 - j + S] * conv_w[3 - j] for j in range(4))
    xi = jax.nn.silu(xi)
    new_conv = xi_pad[:, S:S + 3]  # last 3 pre-activation inputs
    Bm = (h @ lp["w_B"].astype(dt_)).astype(jnp.float32)
    Cm = (h @ lp["w_C"].astype(dt_)).astype(jnp.float32)
    dtr = (h @ lp["w_dt"].astype(dt_)).astype(jnp.float32)
    dt_act = jax.nn.softplus(dtr + lp["dt_bias"])            # (B,S,H)
    la = -jnp.exp(lp["A_log"]) * dt_act                      # (B,S,H) log decay
    xh = xi.reshape(B, S, H, P).astype(jnp.float32)
    x_scaled = xh * dt_act[..., None]
    h0 = state["ssd"] if state else jnp.zeros((B, H, P, N), jnp.float32)
    y, h_end = _ssd_chunked(x_scaled, Bm, Cm, la, h0, cfg.ssm_chunk)
    y = y + lp["D_skip"][None, None, :, None] * xh
    y = y.reshape(B, S, d_in).astype(dt_)
    y = L.rms_norm(y * jax.nn.silu(z), lp["ssm_norm"], cfg.norm_eps)
    out = y @ lp["w_out"].astype(dt_)
    new_state = {"conv": new_conv, "ssd": h_end}
    return x + out, new_state
