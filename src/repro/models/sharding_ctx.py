"""Activation-sharding context.

XLA's sharding propagation loses batch/TP shardings inside while loops (lax.scan layer
stacks), silently replicating interior activations -- at 256 chips that turns a 100 MB
tensor into 25 GB/device.  Production JAX frameworks pin interior activations with
``with_sharding_constraint``; models here call ``shard(x, *logical_entries)`` which
resolves against a process-global mesh context set by the launcher/dry-run.  Without a
context (CPU smoke tests) it is an identity -- model code stays mesh-agnostic.

Logical entries per dim: None | "fsdp" | "tp" (divisibility-checked against the actual
dim, replicating when it does not divide -- e.g. 15 heads on a 16-way TP axis).
"""
from __future__ import annotations

import contextlib
import threading

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

_STATE = threading.local()


def set_mesh_context(mesh, fsdp: tuple[str, ...] | None = None,
                     tp: str = "model") -> None:
    if mesh is not None and fsdp is None:
        fsdp = tuple(n for n in mesh.axis_names if n != tp)
    _STATE.mesh = mesh
    _STATE.fsdp = fsdp
    _STATE.tp = tp


def get_mesh():
    return getattr(_STATE, "mesh", None)


@contextlib.contextmanager
def mesh_context(mesh, fsdp=None, tp="model"):
    prev = (getattr(_STATE, "mesh", None), getattr(_STATE, "fsdp", None),
            getattr(_STATE, "tp", "model"))
    set_mesh_context(mesh, fsdp, tp)
    try:
        yield
    finally:
        set_mesh_context(*prev)


def _axis_size(mesh, names) -> int:
    if isinstance(names, str):
        names = (names,)
    return int(np.prod([mesh.shape[n] for n in names]))


def shard(x, *entries):
    """Constrain activation sharding; identity when no mesh context is active.

    Entries: None | "fsdp" | "tp" | "dp_max".  "dp_max" spreads the dim over the
    LARGEST divisible combination of data axes -- (fsdp..., tp) if it divides, else
    fsdp, else replicate.  Used to batch-parallelize attention when the head count
    does not divide the TP axis (§Perf: the smollm head-replication fix)."""
    mesh = getattr(_STATE, "mesh", None)
    if mesh is None:
        return x
    fsdp, tp = _STATE.fsdp, _STATE.tp
    fsdp_name = fsdp if len(fsdp) > 1 else fsdp[0]
    assert len(entries) == x.ndim, (entries, x.shape)
    resolved = []
    for e, d in zip(entries, x.shape):
        if e is None:
            resolved.append(None)
        elif e == "fsdp":
            resolved.append(fsdp_name if d % _axis_size(mesh, fsdp) == 0 else None)
        elif e == "tp":
            resolved.append(tp if d % _axis_size(mesh, tp) == 0 else None)
        elif e == "dp_max":
            alln = tuple(fsdp) + (tp,)
            if d % _axis_size(mesh, alln) == 0:
                resolved.append(alln)
            elif d % _axis_size(mesh, fsdp) == 0:
                resolved.append(fsdp_name)
            else:
                resolved.append(None)
        else:
            raise ValueError(e)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*resolved)))


def tp_divides(dim: int) -> bool:
    """Would a "tp" entry actually shard this dim under the active context?"""
    mesh = getattr(_STATE, "mesh", None)
    if mesh is None:
        return True
    return dim % _axis_size(mesh, _STATE.tp) == 0
