"""RWKV6 (Finch) full model program: attention-free LM, O(1)-state decode."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.ssm import rwkv_layer_fwd, rwkv_layer_init


def init(cfg: ModelConfig, key):
    ke, kl = jax.random.split(key)
    emb_p, emb_s = L.embed_init(ke, cfg)
    lp = jax.vmap(lambda k: rwkv_layer_init(k, cfg)[0])(
        jax.random.split(kl, cfg.n_layers))
    _, ls = rwkv_layer_init(kl, cfg)
    params = {"embed": emb_p, "layers": lp,
              "final_norm": L.oinit(None, (cfg.d_model,))}
    specs = {"embed": emb_s, "layers": ("stacked", ls), "final_norm": (None,)}
    return params, specs


def init_state(cfg: ModelConfig, batch: int, dtype=None):
    dtype = dtype or cfg.dtype
    D, H = cfg.d_model, cfg.n_heads
    hd = D // H
    Lyr = cfg.n_layers
    return {"tm_last": jnp.zeros((Lyr, batch, D), dtype),
            "cm_last": jnp.zeros((Lyr, batch, D), dtype),
            "wkv": jnp.zeros((Lyr, batch, H, hd, hd), jnp.float32),
            "len": jnp.zeros((), jnp.int32)}


def state_specs(cfg: ModelConfig):
    return {"tm_last": (None, "fsdp", None), "cm_last": (None, "fsdp", None),
            "wkv": (None, "fsdp", ("tp", cfg.n_heads), None, None), "len": ()}


def forward(params, cfg: ModelConfig, tokens, state=None, remat_policy=None):
    x = L.embed_lookup(params["embed"], tokens, cfg)
    B = x.shape[0]
    st = state or init_state(cfg, B)

    def body(x, inp):
        lp, tm, cm, wkv = inp
        x, ns = rwkv_layer_fwd(cfg, lp, x,
                               {"tm_last": tm, "cm_last": cm, "wkv": wkv})
        return x, (ns["tm_last"], ns["cm_last"], ns["wkv"])

    body_fn = body if remat_policy is None else jax.checkpoint(
        body, policy=remat_policy)
    x, (tm, cm, wkv) = jax.lax.scan(
        body_fn, x, (params["layers"], st["tm_last"].astype(x.dtype),
                     st["cm_last"].astype(x.dtype), st["wkv"]))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    new_state = {"tm_last": tm, "cm_last": cm, "wkv": wkv,
                 "len": st["len"] + tokens.shape[1]}
    return x, new_state


def train_loss(params, cfg: ModelConfig, batch, remat_policy=None):
    x, _ = forward(params, cfg, batch["tokens"], remat_policy=remat_policy)
    logits = L.lm_logits(params["embed"], x, cfg)
    return L.cross_entropy(logits, batch["labels"])


def prefill(params, cfg: ModelConfig, tokens, state):
    x, new_state = forward(params, cfg, tokens, state)
    logits = L.lm_logits(params["embed"], x[:, -1:], cfg)
    return logits, new_state


def decode_step(params, cfg: ModelConfig, token, state):
    """token (B, 1).  The recurrent state is the whole 'cache' -- its size is
    independent of context length, which is why long_500k decode is deployable."""
    x, new_state = forward(params, cfg, token, state)
    logits = L.lm_logits(params["embed"], x, cfg)
    return logits, new_state
