"""Pure-jnp oracles for every Pallas kernel.

Each kernel's reference is the stage's ``run_jnp`` (identical closures, whole-array
execution) -- one semantic definition shared by both backends.  The named helpers below
exist so kernel tests can sweep shapes/dtypes directly without building plan trees.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.patterns import Stage


def ref_stage(stage: Stage, bufs: dict[str, jnp.ndarray]) -> jnp.ndarray:
    """The oracle: run the stage with the pure-jnp executor."""
    return stage.run_jnp(bufs)


def unpack_bits_ref(packed: jnp.ndarray, n: int, bit_width: int,
                    base: int = 0) -> jnp.ndarray:
    """Standalone bit-unpack oracle (mirrors repro.algos.bitpack)."""
    i = jnp.arange(n, dtype=jnp.int32)
    frac = (i & 31) * bit_width
    w = (i >> 5) * bit_width + (frac >> 5)
    off = (frac & 31).astype(jnp.uint32)
    mask = jnp.uint32((1 << bit_width) - 1) if bit_width < 32 \
        else jnp.uint32(0xFFFFFFFF)
    last = packed.shape[0] - 1
    lo = packed[w] >> off
    hi = jnp.where(off == 0, jnp.uint32(0),
                   packed[jnp.minimum(w + 1, last)] << ((32 - off) & 31))
    return ((lo | hi) & mask).astype(jnp.int32) + base


def expand_ref(presum: jnp.ndarray, values: jnp.ndarray, n: int) -> jnp.ndarray:
    """Standalone Group-Parallel expansion oracle (RLE semantics)."""
    i = jnp.arange(n, dtype=jnp.int32)
    g = jnp.searchsorted(presum, i, side="right").astype(jnp.int32) - 1
    return values[g]


def ans_ref(streams, states, sym, freq, cum, chunk_size: int) -> jnp.ndarray:
    from repro.algos.ans import decode_chunks_jnp

    return decode_chunks_jnp(streams, states, sym, freq, cum, chunk_size)
