"""Pallas API compatibility shims across jax versions.

The Group-Parallel kernel windows its presum/value inputs with *element-indexed*
BlockSpecs (the index map returns element offsets, not block indices).  Newer jax
spells that with per-dimension ``pl.Element`` block dims; jax 0.4.x (this container
ships 0.4.37) removed/lacks that class and instead takes a per-spec
``indexing_mode=pl.Unblocked()``.  ``element_block_spec`` papers over the drift so
kernel code stays version-agnostic.
"""
from __future__ import annotations

from typing import Callable

import jax.experimental.pallas as pl


def element_block_spec(n_elems: int, index_map: Callable) -> pl.BlockSpec:
    """1-D BlockSpec of ``n_elems`` elements whose ``index_map`` returns ELEMENT
    offsets (element-indexed window), on any supported jax version."""
    if hasattr(pl, "Element"):          # jax >= 0.5 per-dim block classes
        return pl.BlockSpec((pl.Element(n_elems),), index_map)
    return pl.BlockSpec((n_elems,), index_map, indexing_mode=pl.Unblocked())
