"""Group-Parallel Pallas TPU kernel (paper §4, Fig. 10).

The paper balances skewed group sizes by letting multiple GPU blocks co-process one
group and one block span many groups.  The TPU-native equivalent implemented here is
*output-centric balanced decomposition*: every grid step produces a fixed (L*S, C)
output tile -- equal work regardless of the group-size distribution -- and locates each
element's owning group with an in-VMEM branchless binary search over the presum.

Data-dependent blocking: a tile starting at output offset o touches groups starting at
``fg = searchsorted(presum, o, 'right') - 1``.  ``fg`` per tile is precomputed with one
cheap scan (the paper's one-time data scan) and fed through *scalar prefetch*, so the
BlockSpec index maps DMA exactly the presum/value window each tile needs
(element-indexed windows via ``repro.kernels.compat``).  A tile of T outputs
intersects at most T+1 groups (counts are >= 1), bounding the window statically.

Value inputs whose tile ratio is a runtime meta operand (bitpack's ``bit_width``
after fusion rule 2) cannot drive a static DMA window, so they stay whole-resident
in VMEM instead of windowed.

Absorbed Fully-Parallel producers (fusion rule 2) run on the gathered group values
inside this same kernel -- e.g. bit-packed RLE values never materialize, the paper's
Fig. 7(c).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import jax.experimental.pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.geometry import Geometry
from repro.core.patterns import Ctx, GroupParallel
from repro.kernels.compat import element_block_spec
from repro.kernels.fully_parallel import _out_index_grid


def _upper_bound(presum_blk: jnp.ndarray, q: jnp.ndarray, length: int) -> jnp.ndarray:
    """Branchless binary search: first index j with presum_blk[j] > q."""
    lo = jnp.zeros_like(q)
    hi = jnp.full_like(q, length)
    for _ in range(max(1, math.ceil(math.log2(length + 1)))):
        mid = (lo + hi) >> 1
        go_right = presum_blk[mid] <= q
        lo = jnp.where(go_right, mid + 1, lo)
        hi = jnp.where(go_right, hi, mid)
    return lo


def group_parallel_call(stage: GroupParallel, bufs: dict[str, jnp.ndarray],
                        geom: Geometry, interpret: bool = False,
                        group_cap: int | None = None) -> jnp.ndarray:
    n = stage.n_out
    rows, cols = geom.L * geom.S, geom.C
    tile = rows * cols
    n_tiles = max(1, math.ceil(n / tile))
    # max groups a tile can intersect; a host-derived hint may tighten this
    gcap = min(stage.n_groups, tile + 1) if group_cap is None \
        else min(group_cap, stage.n_groups)
    gcap = max(gcap, 1)

    presum = bufs[stage.presum].astype(jnp.int32)
    # pad so Element-windows never run off the end; sentinel keeps the search valid
    presum_p = jnp.concatenate(
        [presum, jnp.full((gcap + 2,), jnp.int32(2**31 - 1))])
    # one-time scan: first group per tile (scalar prefetch)
    tile_starts = jnp.arange(n_tiles, dtype=jnp.int32) * tile
    fg = (jnp.searchsorted(presum, tile_starts, side="right") - 1).astype(jnp.int32)
    fg = jnp.maximum(fg, 0)

    value_arrays = []
    value_specs = []
    value_units: list[tuple[int, int]] = []  # (num, den) per value input
    for spec, name in zip(stage.value_specs, stage.value_inputs):
        arr = bufs[name]
        if spec.kind == "full" or spec.num_op:
            # whole-resident: small metadata, or a tile whose ratio is a runtime
            # operand (no static window size exists for it)
            value_specs.append(pl.BlockSpec(arr.shape,
                                            lambda i, s, _nd=arr.ndim: (0,) * _nd))
            value_units.append((0, 1))  # start derived as None
            value_arrays.append(arr)
            continue
        num, den = spec.num, spec.den
        blen = (gcap * num) // den + (2 if den > 1 else 1)
        pad = jnp.zeros((blen + 2,), arr.dtype)
        value_arrays.append(jnp.concatenate([arr.reshape(-1), pad]))
        value_specs.append(element_block_spec(
            blen, lambda i, s, _n=num, _d=den: ((s[i] * _n) // _d,)))
        value_units.append((num, den))
    extra_arrays = [bufs[k] for k in stage.extra_inputs]
    extra_specs = [pl.BlockSpec(a.shape, lambda i, s, _nd=a.ndim: (0,) * _nd)
                   for a in extra_arrays]

    def kernel(sref, presum_ref, *refs):
        value_refs = refs[: len(value_arrays)]
        extra_refs = refs[len(value_arrays):-1]
        o_ref = refs[-1]
        i = pl.program_id(0)
        fg_i = sref[i]
        out_idx = _out_index_grid(i, rows, cols)
        pblk = presum_ref[...]
        g_local = _upper_bound(pblk, jnp.minimum(out_idx, n - 1), gcap + 1) - 1
        g_local = jnp.clip(g_local, 0, gcap)
        g = g_local + fg_i
        pos = jnp.minimum(out_idx, n - 1) - pblk[g_local]
        starts = tuple(None if (nu, de) == (0, 1) else (fg_i * nu) // de
                       for nu, de in value_units)
        ctx = Ctx(out_idx=out_idx, starts=starts)
        gval = stage.value_fn(Ctx(out_idx=g, starts=starts), g,
                              *[r[...] for r in value_refs])
        vals = stage.map_fn(ctx, gval, pos, g, *[r[...] for r in extra_refs])
        o_ref[...] = jnp.where(out_idx < n, vals, 0).astype(o_ref.dtype)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_tiles,),
        in_specs=[element_block_spec(gcap + 2, lambda i, s: (s[i],))]
        + value_specs + extra_specs,
        out_specs=pl.BlockSpec((rows, cols), lambda i, s: (i, 0)),
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_tiles * rows, cols), stage.out_dtype),
        interpret=interpret,
    )(fg, presum_p, *value_arrays, *extra_arrays)
    return out.reshape(-1)[:n]
