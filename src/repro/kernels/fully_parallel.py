"""Fully-Parallel Pallas TPU kernel (paper §4, Fig. 9).

One ``pallas_call`` executes an arbitrary fused chain of Fully-Parallel map closures.
Geometry <L,S,C> picks the VMEM tile: each grid step owns an (L*S, C) block of the
output; ``L`` amortizes grid overhead (the paper's thread main loop), ``S``/``C`` align
the tile to the VPU's (8, 128) register shape.

Input blocks follow the stage's BufSpecs:
  * "tile"  -- a proportional slice (num/den elements per output element); bit-packing
               fetches exactly tile*bw/32 words because tiles are multiples of 32.
  * "full"  -- whole buffer resident in VMEM (dictionaries, scale scalars).

The map closure receives a Ctx with the *global* output indices of the tile and the
block origins, so the same closure runs unchanged under the pure-jnp executor -- one
definition, two backends, zero divergence (tested).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import jax.experimental.pallas as pl

from repro.core.geometry import Geometry
from repro.core.patterns import BufSpec, Ctx, FullyParallel


def _out_index_grid(i, rows: int, cols: int) -> jnp.ndarray:
    r = jax.lax.broadcasted_iota(jnp.int32, (rows, cols), 0)
    c = jax.lax.broadcasted_iota(jnp.int32, (rows, cols), 1)
    return (i * rows + r) * cols + c


def fully_parallel_call(stage: FullyParallel, bufs: dict[str, jnp.ndarray],
                        geom: Geometry, interpret: bool = False) -> jnp.ndarray:
    n = stage.n_out
    rows, cols = geom.L * geom.S, geom.C
    tile = rows * cols
    n_tiles = max(1, math.ceil(n / tile))
    arrays = [bufs[k] for k in stage.inputs]

    in_specs = []
    tile_sizes: list[int | None] = []
    for spec, arr in zip(stage.specs, arrays):
        if spec.kind == "full" or spec.num_op:
            # whole-resident: small metadata, or a tile ratio supplied by a runtime
            # meta operand (bitpack bit_width) -- no static window size exists, so
            # the closure indexes the buffer globally (start=None -> 0)
            in_specs.append(pl.BlockSpec(arr.shape,
                                         lambda i, _nd=arr.ndim: (0,) * _nd))
            tile_sizes.append(None)
        else:
            assert (tile * spec.num) % spec.den == 0, (tile, spec)
            bin_ = tile * spec.num // spec.den
            in_specs.append(pl.BlockSpec((bin_,), lambda i: (i,)))
            tile_sizes.append(bin_)

    def kernel(*refs):
        o_ref = refs[-1]
        i = pl.program_id(0)
        out_idx = _out_index_grid(i, rows, cols)
        starts = tuple(None if b is None else i * b for b in tile_sizes)
        blocks = [r[...] for r in refs[:-1]]
        vals = stage.fn(Ctx(out_idx=out_idx, starts=starts), *blocks)
        o_ref[...] = jnp.where(out_idx < n, vals, 0).astype(o_ref.dtype)

    out = pl.pallas_call(
        kernel,
        grid=(n_tiles,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((rows, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_tiles * rows, cols), stage.out_dtype),
        interpret=interpret,
    )(*arrays)
    return out.reshape(-1)[:n]
