"""Jitted dispatch from pattern stages to the Pallas TPU kernels.

``run_stage`` is the "pallas" backend of ``repro.core.compiler``: it routes each stage
kind to its kernel with the geometry chosen for the pattern (native config of the
target chip, or an explicit override from the autotuner / perf loop).  Aux stages have
no kernel -- they are whole-array XLA ops by design (paper Fig. 7's PyTorch auxiliaries).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.geometry import Geometry
from repro.core.patterns import Aux, FullyParallel, GroupParallel, NonParallel, Stage
from repro.kernels.fully_parallel import fully_parallel_call
from repro.kernels.group_parallel import group_parallel_call
from repro.kernels.non_parallel import non_parallel_call


def run_stage(stage: Stage, bufs: dict[str, jnp.ndarray],
              geoms: dict[str, Geometry], interpret: bool = True) -> jnp.ndarray:
    if isinstance(stage, FullyParallel):
        return fully_parallel_call(stage, bufs, geoms["fp"], interpret=interpret)
    if isinstance(stage, GroupParallel):
        return group_parallel_call(stage, bufs, geoms["gp"], interpret=interpret)
    if isinstance(stage, NonParallel):
        return non_parallel_call(stage, bufs, geoms["np"], interpret=interpret)
    if isinstance(stage, Aux):
        return stage.run_jnp(bufs)
    raise TypeError(f"unknown stage type {type(stage)}")
