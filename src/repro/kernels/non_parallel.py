"""Non-Parallel Pallas TPU kernel: lane-lockstep interleaved rANS decode
(paper §4, Fig. 11).

On a GPU the paper assigns one chunk per thread and relies on warp lockstep.  The TPU
VPU *is* a lockstep machine: a (S, C) register of decoder states advances S*C chunks
per step under a single program counter; ``lax.fori_loop`` is the shared instruction
stream.  The <L,S,C> geometry means: S*C chunks in flight per grid step, L grid steps'
worth of chunk batches... i.e. each kernel invocation decodes G = S*C chunks, and the
grid covers ceil(n_chunks / G) batches.

Streams are chunk-transposed ("striped"): ``streams[t, c]`` is word t of chunk c, so a
renormalization step gathers one VMEM row -- the paper's "consistency of I/O and cache
accesses across chunks".  The <=1-word-per-symbol renorm bound (see repro.algos.ans)
makes the loop body branch-free: every lane executes identical selects.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import jax.experimental.pallas as pl

from repro.core.geometry import Geometry
from repro.core.patterns import Ctx, NonParallel
from repro.algos.ans import L as ANS_L, M as ANS_M, SCALE_BITS


def non_parallel_call(stage: NonParallel, bufs: dict[str, jnp.ndarray],
                      geom: Geometry, interpret: bool = False) -> jnp.ndarray:
    cs = stage.chunk_size
    n_chunks = stage.n_chunks
    G = geom.S * geom.C  # chunks in lockstep per grid step
    n_batches = max(1, math.ceil(n_chunks / G))
    pad_chunks = n_batches * G

    streams = bufs[stage.streams]
    states = bufs[stage.states].astype(jnp.uint32)
    max_words = streams.shape[0]
    if pad_chunks != n_chunks:
        streams = jnp.pad(streams, ((0, 0), (0, pad_chunks - n_chunks)))
        states = jnp.pad(states, (0, pad_chunks - n_chunks),
                         constant_values=jnp.uint32(ANS_L))
    sym = bufs[stage.sym_tab].astype(jnp.int32)
    freq = bufs[stage.freq_tab].astype(jnp.uint32)
    cum = bufs[stage.cum_tab].astype(jnp.uint32)

    # if an elementwise consumer was fused in (rule 4), it runs inside the kernel
    out_dtype = stage.out_dtype if stage.out_map is not None else jnp.uint8

    def kernel(stream_ref, state_ref, sym_ref, freq_ref, cum_ref, o_ref):
        i = pl.program_id(0)
        lanes = jax.lax.broadcasted_iota(jnp.int32, (1, G), 1)
        sym_t = sym_ref[...]
        freq_t = freq_ref[...]
        cum_t = cum_ref[...]
        x0 = state_ref[...].reshape(1, G)
        cur0 = jnp.zeros((1, G), jnp.int32)
        cap = max_words - 1

        def body(t, carry):
            x, cur = carry
            slot = (x & jnp.uint32(ANS_M - 1)).astype(jnp.int32)
            s = sym_t[slot]
            x = freq_t[s] * (x >> SCALE_BITS) + slot.astype(jnp.uint32) - cum_t[s]
            need = x < jnp.uint32(ANS_L)
            w = stream_ref[jnp.clip(cur, 0, cap), lanes].astype(jnp.uint32)
            x = jnp.where(need, (x << 16) | w, x)
            cur = cur + need.astype(jnp.int32)
            vals = s
            if stage.out_map is not None:
                out_idx = ((i * G + lanes) * cs + t)
                vals = stage.out_map(Ctx(out_idx=out_idx, starts=(None,)), s)
            o_ref[:, pl.ds(t, 1)] = vals.astype(o_ref.dtype).reshape(G, 1)
            return (x, cur)

        jax.lax.fori_loop(0, cs, body, (x0, cur0))

    out = pl.pallas_call(
        kernel,
        grid=(n_batches,),
        in_specs=[
            pl.BlockSpec((max_words, G), lambda i: (0, i)),
            pl.BlockSpec((G,), lambda i: (i,)),
            pl.BlockSpec(sym.shape, lambda i: (0,)),
            pl.BlockSpec(freq.shape, lambda i: (0,)),
            pl.BlockSpec(cum.shape, lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((G, cs), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((pad_chunks, cs), out_dtype),
        interpret=interpret,
    )(streams, states, sym, freq, cum)

    return out.reshape(-1)[: stage.n_out].astype(stage.out_dtype)
