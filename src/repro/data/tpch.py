"""Synthetic TPC-H-style data generator (dbgen distributions, scaled-down).

Generates the columns of the three largest tables (LINEITEM, ORDERS, PARTSUPP) the
paper compresses (Table 2).  Value distributions follow the TPC-H spec shapes:
sparse monotone order keys, 1-7 lineitems per order, ~2500 distinct dates, 2-decimal
prices, skewed flag frequencies, comment text from a finite word pool.

Representation notes (recorded for honesty):
  * low-cardinality *string* categoricals (shipinstruct, shipmode, linestatus) are
    stored as int32 dictionary codes -- the paper's Table 2 bit-packs them directly,
    which implies the same pre-dictionarized representation;
  * RETURNFLAG is the raw uint8 character stream (ANS target);
  * COMMENT columns are uint8 text streams (String-dictionary target).
"""
from __future__ import annotations

import numpy as np

WORDS = [w.encode() for w in (
    "the quick silver fox express packages deposits accounts regular carefully "
    "slyly furiously ironic requests theodolites pending asymptotes foxes bold "
    "final platelets blithely daring instructions unusual even special about "
    "above according across after against along among around beside between "
    "customer order ship deliver economy machine metal steel brass copper tin "
    "nickel small large medium jumbo wrap bag box pack case carton").split()]


def _comment_text(rng, n_rows: int, avg_words: int = 8) -> np.ndarray:
    n_words = n_rows * avg_words
    idx = rng.integers(0, len(WORDS), n_words)
    # zipf-ish skew: first words far more common
    skew = rng.zipf(1.6, n_words) % len(WORDS)
    idx = np.where(rng.random(n_words) < 0.7, skew, idx)
    parts = []
    for i in range(n_rows):
        ws = [WORDS[j] for j in idx[i * avg_words:(i + 1) * avg_words]]
        parts.append(b" ".join(ws) + b". ")
    return np.frombuffer(b"".join(parts), dtype=np.uint8).copy()


def generate(scale: float = 0.01, seed: int = 0) -> dict[str, np.ndarray]:
    """-> column name -> np.ndarray.  scale=1.0 ~ 6M lineitems (dbgen SF=1)."""
    rng = np.random.default_rng(seed)
    n_orders = max(int(1_500_000 * scale), 64)
    per_order = rng.integers(1, 8, n_orders)              # 1..7 lineitems/order
    n_li = int(per_order.sum())
    n_ps = max(int(800_000 * scale), 64)

    # sparse monotone order keys (dbgen leaves gaps)
    o_orderkey = np.cumsum(rng.integers(1, 4, n_orders)).astype(np.int32)
    l_orderkey = np.repeat(o_orderkey, per_order).astype(np.int32)

    dates = rng.integers(8035, 10591, n_orders)           # ~2556 distinct days
    date_li = np.repeat(dates, per_order) + rng.integers(0, 90, n_li)

    def money(lo, hi, n):
        return (rng.integers(lo * 100, hi * 100, n) / 100.0).astype(np.float32)

    cols = {
        # --- LINEITEM ---
        "L_ORDERKEY": l_orderkey,
        "L_PARTKEY": rng.integers(1, max(int(200_000 * scale), 1000), n_li)
        .astype(np.int32),
        "L_SUPPKEY": rng.integers(1, max(int(10_000 * scale), 100), n_li)
        .astype(np.int32),
        "L_QUANTITY": rng.integers(1, 51, n_li).astype(np.int32),
        "L_EXTENDEDPRICE": money(900, 105_000, n_li),
        "L_DISCOUNT": (rng.integers(0, 11, n_li) / 100.0).astype(np.float32),
        "L_TAX": (rng.integers(0, 9, n_li) / 100.0).astype(np.float32),
        "L_RETURNFLAG": rng.choice(
            np.frombuffer(b"NAR", dtype=np.uint8), n_li,
            p=[0.5, 0.25, 0.25]).astype(np.uint8),
        "L_LINESTATUS": rng.integers(0, 2, n_li).astype(np.int32),
        "L_SHIPDATE": (date_li + rng.integers(1, 122, n_li)).astype(np.int32),
        "L_COMMITDATE": (date_li + rng.integers(30, 91, n_li)).astype(np.int32),
        "L_RECEIPTDATE": (date_li + rng.integers(1, 31, n_li)).astype(np.int32),
        "L_SHIPINSTRUCT": rng.integers(0, 4, n_li).astype(np.int32),
        "L_SHIPMODE": rng.integers(0, 7, n_li).astype(np.int32),
        # --- ORDERS ---
        "O_ORDERKEY": o_orderkey,
        "O_CUSTKEY": rng.integers(1, max(int(150_000 * scale), 1000), n_orders)
        .astype(np.int32),
        "O_TOTALPRICE": money(850, 550_000, n_orders),
        "O_ORDERDATE": dates.astype(np.int32),
        "O_SHIPPRIORITY": np.zeros(n_orders, np.int32),
        "O_COMMENT": _comment_text(rng, n_orders),
        # --- PARTSUPP ---
        "PS_PARTKEY": np.repeat(np.arange(1, n_ps // 4 + 2, dtype=np.int32), 4)
        [:n_ps],
        "PS_SUPPKEY": (np.tile(np.arange(4, dtype=np.int32), n_ps // 4 + 1)[:n_ps]
                       * max(int(2_500 * scale), 25)
                       + rng.integers(1, max(int(2_500 * scale), 25), n_ps))
        .astype(np.int32),
        "PS_AVAILQTY": rng.integers(1, 10_000, n_ps).astype(np.int32),
        "PS_SUPPLYCOST": money(1, 1_000, n_ps),
    }
    return cols


# monotone integer key columns: tiling must add a per-tile offset so the keys
# keep growing (delta/delta-stride codecs see realistic small deltas, not one
# huge negative jump per tile)
_MONOTONE_KEYS = {"L_ORDERKEY", "O_ORDERKEY"}


def scale_columns(cols: dict[str, np.ndarray], factor: int,
                  names: list[str] | None = None) -> dict[str, np.ndarray]:
    """Tile generated columns ``factor``x toward SF>=1 row counts.

    Value distributions are preserved exactly (each tile is the original
    data); monotone key columns get a cumulative per-tile offset so they stay
    sorted-monotone and keep their delta structure.  Columns not in ``names``
    pass through untouched, so a benchmark can scale only the lineitem columns
    a query reads without exploding unrelated text columns."""
    factor = max(1, int(factor))
    out: dict[str, np.ndarray] = {}
    for name, arr in cols.items():
        if factor == 1 or (names is not None and name not in names):
            out[name] = arr
            continue
        if name in _MONOTONE_KEYS and arr.size:
            span = int(arr[-1]) - int(arr[0]) + 1
            tiles = [arr + np.asarray(t * span, dtype=arr.dtype)
                     for t in range(factor)]
            out[name] = np.concatenate(tiles)
        else:
            out[name] = np.tile(arr, factor)
    return out


# Columns touched by each TPC-H query (L/O/PS tables only -- the paper's scope).
QUERY_COLUMNS: dict[int, list[str]] = {
    1: ["L_RETURNFLAG", "L_LINESTATUS", "L_QUANTITY", "L_EXTENDEDPRICE",
        "L_DISCOUNT", "L_TAX", "L_SHIPDATE"],
    2: ["PS_PARTKEY", "PS_SUPPKEY", "PS_SUPPLYCOST"],
    3: ["L_ORDERKEY", "L_EXTENDEDPRICE", "L_DISCOUNT", "L_SHIPDATE",
        "O_ORDERKEY", "O_CUSTKEY", "O_ORDERDATE", "O_SHIPPRIORITY"],
    4: ["L_ORDERKEY", "L_COMMITDATE", "L_RECEIPTDATE", "O_ORDERKEY",
        "O_ORDERDATE"],
    5: ["L_ORDERKEY", "L_SUPPKEY", "L_EXTENDEDPRICE", "L_DISCOUNT",
        "O_ORDERKEY", "O_CUSTKEY", "O_ORDERDATE"],
    6: ["L_EXTENDEDPRICE", "L_DISCOUNT", "L_QUANTITY", "L_SHIPDATE"],
    7: ["L_ORDERKEY", "L_SUPPKEY", "L_EXTENDEDPRICE", "L_DISCOUNT",
        "L_SHIPDATE", "O_ORDERKEY", "O_CUSTKEY"],
    8: ["L_ORDERKEY", "L_PARTKEY", "L_SUPPKEY", "L_EXTENDEDPRICE",
        "L_DISCOUNT", "O_ORDERKEY", "O_CUSTKEY", "O_ORDERDATE"],
    9: ["L_ORDERKEY", "L_PARTKEY", "L_SUPPKEY", "L_QUANTITY",
        "L_EXTENDEDPRICE", "L_DISCOUNT", "O_ORDERKEY", "O_ORDERDATE",
        "PS_PARTKEY", "PS_SUPPKEY", "PS_SUPPLYCOST"],
    10: ["L_ORDERKEY", "L_EXTENDEDPRICE", "L_DISCOUNT", "L_RETURNFLAG",
         "O_ORDERKEY", "O_CUSTKEY", "O_ORDERDATE"],
    11: ["PS_PARTKEY", "PS_SUPPKEY", "PS_AVAILQTY", "PS_SUPPLYCOST"],
    12: ["L_ORDERKEY", "L_SHIPMODE", "L_COMMITDATE", "L_RECEIPTDATE",
         "L_SHIPDATE", "O_ORDERKEY"],
    13: ["O_ORDERKEY", "O_CUSTKEY", "O_COMMENT"],
    14: ["L_PARTKEY", "L_EXTENDEDPRICE", "L_DISCOUNT", "L_SHIPDATE"],
    15: ["L_SUPPKEY", "L_EXTENDEDPRICE", "L_DISCOUNT", "L_SHIPDATE"],
    16: ["PS_PARTKEY", "PS_SUPPKEY"],
    17: ["L_PARTKEY", "L_QUANTITY", "L_EXTENDEDPRICE"],
    18: ["L_ORDERKEY", "L_QUANTITY", "O_ORDERKEY", "O_CUSTKEY",
         "O_TOTALPRICE", "O_ORDERDATE"],
    19: ["L_PARTKEY", "L_QUANTITY", "L_EXTENDEDPRICE", "L_DISCOUNT",
         "L_SHIPINSTRUCT", "L_SHIPMODE"],
    20: ["L_PARTKEY", "L_SUPPKEY", "L_QUANTITY", "L_SHIPDATE",
         "PS_PARTKEY", "PS_SUPPKEY", "PS_AVAILQTY"],
    21: ["L_ORDERKEY", "L_SUPPKEY", "L_COMMITDATE", "L_RECEIPTDATE",
         "O_ORDERKEY"],
    22: ["O_ORDERKEY", "O_CUSTKEY", "O_TOTALPRICE"],
}
