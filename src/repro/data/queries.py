"""Mini analytical query engine (the TQP role): JAX scan-filter-aggregate
implementations of TPC-H Q1 and Q6 used by the end-to-end benchmarks/examples."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def q1_engine(c):
    """TPC-H Q1: filtered group-by aggregates over lineitem."""
    sel = c["L_SHIPDATE"] <= jnp.int32(10000)
    # RETURNFLAG is the raw character stream ('N'/'A'/'R'); fold to a group code
    flag = (c["L_RETURNFLAG"].astype(jnp.int32) - 65) % 4
    key = flag * 2 + c["L_LINESTATUS"]
    disc_price = c["L_EXTENDEDPRICE"] * (1 - c["L_DISCOUNT"])
    charge = disc_price * (1 + c["L_TAX"])
    w = sel.astype(jnp.float32)
    out = []
    for v in (c["L_QUANTITY"].astype(jnp.float32), c["L_EXTENDEDPRICE"],
              disc_price, charge, w):
        out.append(jax.ops.segment_sum(v * w, key, num_segments=8))
    return jnp.stack(out)


def q6_engine(c):
    """TPC-H Q6: predicated revenue sum."""
    sel = ((c["L_SHIPDATE"] >= 8766) & (c["L_SHIPDATE"] < 9131)
           & (c["L_DISCOUNT"] >= 0.05) & (c["L_DISCOUNT"] <= 0.07)
           & (c["L_QUANTITY"] < 24))
    return jnp.sum(jnp.where(sel, c["L_EXTENDEDPRICE"] * c["L_DISCOUNT"], 0.0))


ENGINES = {1: q1_engine, 6: q6_engine}
