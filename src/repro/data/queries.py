"""Mini analytical query engine (the TQP role): JAX scan-filter-aggregate
implementations of TPC-H Q1 and Q6 used by the end-to-end benchmarks/examples.

``Q1_PLAN`` / ``Q6_PLAN`` are the same queries as declarative ``QueryPlan`` IR
(``core.query``): ``lower_query`` grafts them onto the columns' decode graphs
so scan-filter-aggregate runs inside the per-chunk decode launch and only
partial aggregates ever reach HBM (late materialization)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.query import Bin, Col, Const, Pred, QueryPlan


def q1_engine(c):
    """TPC-H Q1: filtered group-by aggregates over lineitem."""
    sel = c["L_SHIPDATE"] <= jnp.int32(10000)
    # RETURNFLAG is the raw character stream ('N'/'A'/'R'); fold to a group code
    flag = (c["L_RETURNFLAG"].astype(jnp.int32) - 65) % 4
    key = flag * 2 + c["L_LINESTATUS"]
    disc_price = c["L_EXTENDEDPRICE"] * (1 - c["L_DISCOUNT"])
    charge = disc_price * (1 + c["L_TAX"])
    w = sel.astype(jnp.float32)
    out = []
    for v in (c["L_QUANTITY"].astype(jnp.float32), c["L_EXTENDEDPRICE"],
              disc_price, charge, w):
        out.append(jax.ops.segment_sum(v * w, key, num_segments=8))
    return jnp.stack(out)


def q6_engine(c):
    """TPC-H Q6: predicated revenue sum."""
    sel = ((c["L_SHIPDATE"] >= 8766) & (c["L_SHIPDATE"] < 9131)
           & (c["L_DISCOUNT"] >= 0.05) & (c["L_DISCOUNT"] <= 0.07)
           & (c["L_QUANTITY"] < 24))
    return jnp.sum(jnp.where(sel, c["L_EXTENDEDPRICE"] * c["L_DISCOUNT"], 0.0))


ENGINES = {1: q1_engine, 6: q6_engine}


# --------------------------------------------------- declarative QueryPlan IR

_DISC_PRICE = Bin("*", Col("L_EXTENDEDPRICE"),
                  Bin("-", Const(1), Col("L_DISCOUNT")))

# lane order matches q1_engine: quantity, extendedprice, disc_price, charge,
# and the always-computed count lane doubles as the engine's ``w`` lane
Q1_PLAN = QueryPlan(
    name="q1",
    predicates=(Pred("L_SHIPDATE", "<=", 10000),),
    aggregates=(
        ("sum_qty", Col("L_QUANTITY", "float32")),
        ("sum_base_price", Col("L_EXTENDEDPRICE")),
        ("sum_disc_price", _DISC_PRICE),
        ("sum_charge", Bin("*", _DISC_PRICE,
                           Bin("+", Const(1), Col("L_TAX")))),
    ),
    group_key=Bin("+", Bin("*", Bin("%", Bin("-", Col("L_RETURNFLAG", "int32"),
                                             Const(65)),
                                   Const(4)),
                           Const(2)),
                  Col("L_LINESTATUS")),
    n_segments=8,
    keep_count_lane=True)

Q6_PLAN = QueryPlan(
    name="q6",
    predicates=(Pred("L_SHIPDATE", ">=", 8766),
                Pred("L_SHIPDATE", "<", 9131),
                Pred("L_DISCOUNT", "between", 0.05, 0.07),
                Pred("L_QUANTITY", "<", 24)),
    aggregates=(("revenue", Bin("*", Col("L_EXTENDEDPRICE"),
                                Col("L_DISCOUNT"))),))

QUERY_PLANS = {1: Q1_PLAN, 6: Q6_PLAN}
