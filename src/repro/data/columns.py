"""Per-column nested compression plans (paper Table 2) + BtrBlocks-style auto chooser.

``TABLE2_PLANS`` transcribes the paper's custom nesting per TPC-H column into the
Plan IR.  ``auto_plan`` searches a candidate pool by measured ratio (the BtrBlocks
role), used for columns outside Table 2 and for the data-pipeline integration.
"""
from __future__ import annotations

import numpy as np

from repro.core.plan import Plan, encode, make_plan

_bp = lambda: make_plan("bitpack")


def _dict_bp() -> Plan:
    return Plan("dictionary", children={"index": _bp()})


def _f2i_bp() -> Plan:
    return Plan("float2int", children={"ints": _bp()})


def _delta_bp() -> Plan:
    return Plan("delta", children={"deltas": _bp()})


def _deltastride_full() -> Plan:
    # paper: DeltaStride[Delta encoding|RLE[bp, bp], bp]
    return Plan("deltastride", children={
        "starts": _delta_bp(),
        "strides": _bp(),
        "counts": _bp()})


TABLE2_PLANS: dict[str, Plan] = {
    # --- plain bit-packing ---
    "L_SHIPINSTRUCT": _bp(), "L_SHIPMODE": _bp(), "L_SUPPKEY": _bp(),
    "L_PARTKEY": _bp(), "L_LINESTATUS": _bp(), "O_CUSTKEY": _bp(),
    "PS_AVAILQTY": _bp(), "L_QUANTITY": _bp(),
    # --- dictionary | bit-packing (dates) ---
    "L_COMMITDATE": _dict_bp(), "L_RECEIPTDATE": _dict_bp(),
    "L_SHIPDATE": _dict_bp(), "O_ORDERDATE": _dict_bp(),
    # --- Float2Int | bit-packing (decimals) ---
    "L_DISCOUNT": _f2i_bp(), "L_EXTENDEDPRICE": _f2i_bp(), "L_TAX": _f2i_bp(),
    "O_TOTALPRICE": _f2i_bp(), "PS_SUPPLYCOST": _f2i_bp(),
    # --- key columns (RLE / DeltaStride cascades) ---
    "L_ORDERKEY": Plan("rle", children={
        "values": _deltastride_full(), "counts": _bp()}),
    "O_ORDERKEY": _deltastride_full(),
    "PS_PARTKEY": Plan("rle", children={
        "values": _deltastride_full(), "counts": _bp()}),
    "PS_SUPPKEY": Plan("delta", children={
        "deltas": Plan("dictionary", children={"index": _bp()})}),
    "O_SHIPPRIORITY": Plan("rle", children={"counts": _bp(), "values": _bp()}),
    # --- entropy / strings ---
    "L_RETURNFLAG": make_plan("ans"),
    "O_COMMENT": Plan("stringdict", children={
        "index": Plan("bitpack", children={"packed": make_plan("ans")})}),
}


def candidate_plans(arr: np.ndarray) -> list[Plan]:
    """Candidate pool by dtype, cheapest-first (BtrBlocks-style)."""
    if arr.dtype.kind == "f":
        return [_f2i_bp(), make_plan("ans"),
                Plan("float2int", children={"ints": _dict_bp()})]
    if arr.dtype == np.uint8:
        return [make_plan("ans"),
                Plan("stringdict", children={"index": _bp()}),
                TABLE2_PLANS["O_COMMENT"]]
    cands = [_bp(), _dict_bp(), _delta_bp(),
             Plan("rle", children={"counts": _bp(), "values": _bp()})]
    d = np.diff(arr.reshape(-1).astype(np.int64))
    if d.size and (d >= 0).mean() > 0.9:  # near-monotone: stride cascades apply
        cands += [_deltastride_full(),
                  Plan("rle", children={"values": _deltastride_full(),
                                        "counts": _bp()})]
    return cands


def auto_plan(arr: np.ndarray, sample: int = 1 << 16) -> tuple[Plan, float]:
    """Pick the best-ratio plan on a sample (returns (plan, full ratio estimate))."""
    flat = np.asarray(arr).reshape(-1)
    probe = flat[:sample]
    best, best_ratio = None, -1.0
    for p in candidate_plans(flat):
        try:
            enc = encode(p, probe)
        except (TypeError, ValueError):
            continue
        if enc.ratio > best_ratio:
            best, best_ratio = p, enc.ratio
    return best, best_ratio


def plan_for(name: str, arr: np.ndarray) -> Plan:
    if name in TABLE2_PLANS:
        return TABLE2_PLANS[name]
    return auto_plan(arr)[0]
