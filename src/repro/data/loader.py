"""Compressed host->device data pipeline (the paper's end-to-end workflow, Fig. 3,
integrated into LM training).

``CompressedTokenLoader`` stores/ships token batches bit-packed to ceil(log2 vocab)
bits with a *fixed* bit width, so every step's compressed buffers have identical
shapes -- the decode prologue jits once and the decompression fuses into the train
step (overlapping the previous step's compute, the Pipelining Layer's role inside one
program).

``ColumnPipeline`` is the analytics-shaped pipeline: arbitrary per-column plans,
Johnson's-rule issue ordering across columns (paper §3.3), async ``device_put`` so
transfer of column k+1 overlaps decode of column k.
"""
from __future__ import annotations

import math
import time
from typing import Any, Callable, Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compiler, plan as plan_mod
from repro.core.executor import ColumnExec, StreamingExecutor
from repro.core.plan import Plan, make_plan


# ------------------------------------------------------------- training loader

class CompressedTokenLoader:
    """Wraps a token source with fixed-width bit-packed transfer."""

    def __init__(self, vocab: int, batch: int, seq_len: int,
                 source: Callable[[int], np.ndarray] | None = None,
                 seed: int = 0):
        self.vocab = vocab
        self.batch = batch
        self.seq = seq_len
        self.bits = max(1, math.ceil(math.log2(max(vocab, 2))))
        self._rng = np.random.default_rng(seed)
        self._source = source or self._synthetic
        self.bytes_plain = 0
        self.bytes_compressed = 0

    def _synthetic(self, step: int) -> np.ndarray:
        rng = np.random.default_rng(step)  # deterministic in step (FT requirement)
        return rng.integers(0, self.vocab, (self.batch, self.seq + 1),
                            dtype=np.int32)

    def encode_host(self, step: int) -> dict[str, np.ndarray]:
        """Host side: tokens -> fixed-shape packed words."""
        from repro.algos.bitpack import pack_np

        toks = self._source(step)
        packed = pack_np(toks.reshape(-1).astype(np.int64), self.bits)
        self.bytes_plain += toks.nbytes
        self.bytes_compressed += packed.nbytes
        return {"packed": packed}

    def decode_fn(self):
        """Jittable device prologue: packed words -> {tokens, labels}."""
        from repro.kernels.ref import unpack_bits_ref

        B, S, bits = self.batch, self.seq, self.bits

        def decode(bufs):
            flat = unpack_bits_ref(bufs["packed"], B * (S + 1), bits)
            toks = flat.reshape(B, S + 1).astype(jnp.int32)
            return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

        return decode

    def batches(self, start_step: int = 0) -> Iterator[dict[str, jnp.ndarray]]:
        step = start_step
        while True:
            yield {k: jax.device_put(v) for k, v in self.encode_host(step).items()}
            step += 1

    @property
    def ratio(self) -> float:
        return self.bytes_plain / max(self.bytes_compressed, 1)


# ------------------------------------------------------------ analytics pipeline

# the executor's per-column record (name/array/transfer_s/decode_s/compressed_bytes/
# plain_bytes + n_chunks/signature/batched_with) IS the pipeline's result type
ColumnResult = ColumnExec


class ColumnPipeline:
    """Transfer + decompress a set of columns through the streaming executor.

    Columns flow Plan -> DecodeGraph -> ProgramCache -> planner ->
    StreamingExecutor: one jit per column *structure* (data-dependent meta rides
    as runtime operands), and every scheduling decision (issue order, per-column
    chunk size, decode mode, in-flight window) comes from an ``ExecutionPlan``
    built by ``core/planner.py`` under the configured ``policy`` ("fifo",
    "johnson", "chunk-johnson", or "adaptive" with ``chunk_bytes="auto"`` for
    per-column sizing).  Same-signature columns decode in one batched launch.
    ``chunk_decode=True`` additionally
    launches one decode per transferred chunk for element-chunkable columns, so
    transfer/decode overlap *within* a column (the measured counterpart of the
    ``Zc`` chunk-level makespan model).  Per-column (transfer_s, decode_s)
    measurements are cached on the instance -- ``run`` and ``modeled_makespan``
    reuse the executor's timings instead of re-transferring and re-decoding every
    column per call.  ``cost_model`` lets a persisted model (``CostModel.load``)
    seed planning from a previous process's calibrated history.
    """

    def __init__(self, plans: dict[str, Plan], backend: str = "jnp",
                 fuse: bool = True, pipeline: bool = True,
                 chunk_bytes: int | None | str = 1 << 20,
                 batch_columns: bool = True, chunk_decode: bool = False,
                 policy: str = "chunk-johnson",
                 executor: StreamingExecutor | None = None,
                 cost_model=None, mesh: int | None = None,
                 async_dispatch: bool = False, placement: str | None = None):
        self.plans = plans
        # mesh=N enables topology-aware multi-device planning: run_sharded()
        # partitions columns (and group-span shards) over N devices;
        # placement="sharded" pins each shard's FINAL device so the planner
        # may land bytes elsewhere and rebalance over the D2D fabric tier
        self.mesh = mesh
        self.placement = placement
        # async_dispatch=True moves host->device puts onto a per-link transfer
        # worker thread (core.executor.DispatchEngine) so issuance overlaps
        # decode dispatch instead of blocking between launches
        self.executor = executor or StreamingExecutor(
            backend=backend, fuse=fuse, chunk_bytes=chunk_bytes,
            pipeline=pipeline, batch_columns=batch_columns,
            chunk_decode=chunk_decode, policy=policy, cost_model=cost_model,
            async_dispatch=async_dispatch)
        # mirror the *effective* config (an explicitly passed executor wins)
        self.backend = self.executor.backend
        self.fuse = self.executor.fuse
        self.pipeline = self.executor.pipeline
        self.chunk_bytes = self.executor.chunk_bytes
        self.chunk_decode = self.executor.chunk_decode
        self.policy = self.executor.policy
        self.async_dispatch = self.executor.async_dispatch
        self._encoded: dict[str, plan_mod.Encoded] = {}
        self._decoders: dict[str, compiler.Program] = {}
        # lowered fused queries + planned (window, chunk_bytes), keyed by
        # QueryPlan digest (invalidated by compress: new blobs re-lower)
        self._queries: dict[str, tuple] = {}
        self._query_cfg: dict[str, tuple[int, int | None]] = {}

    @property
    def _timings(self) -> dict[str, tuple[float, float]]:
        """Single store for measurements: the executor's timing dict (executor.compile
        invalidates entries when a name is re-registered with new data)."""
        return self.executor.timings

    def compress(self, columns: dict[str, np.ndarray]) -> dict[str, float]:
        ratios = {}
        for name, arr in columns.items():
            enc = plan_mod.encode(self.plans[name], arr)
            self._encoded[name] = enc
            self._decoders[name] = self.executor.compile(name, enc)
            ratios[name] = enc.ratio
        self._queries.clear()
        self._query_cfg.clear()
        return ratios

    @property
    def cache_stats(self) -> dict[str, int]:
        """ProgramCache counters: how many distinct programs served the columns."""
        return self.executor.cache.stats

    def _measure(self, name: str) -> tuple[float, float]:
        """Cached (transfer_s, decode_s) for scheduling: reuses executor timings
        from the latest ``run``; measures at most once otherwise."""
        if name in self._timings:
            return self._timings[name]
        enc = self._encoded[name]
        prog = self._decoders[name]
        t0 = time.perf_counter()
        bufs = compiler.device_buffers(enc)
        jax.block_until_ready(list(bufs.values()))
        transfer_s = time.perf_counter() - t0
        if prog.calls == 0:       # discard the trace+XLA-compile call: cached
            jax.block_until_ready(prog(bufs))   # timings model decode, not jit
        t1 = time.perf_counter()
        out = prog(bufs)
        jax.block_until_ready(out)
        # through observe(), not the raw dict: the measurement must also feed
        # the cost model's EWMA calibration, like the executor's own actuals
        self.executor.cost_model.observe(name, transfer_s,
                                         time.perf_counter() - t1)
        return self._timings[name]

    def plan(self, policy: str | None = None, **kw):
        """Build an ``ExecutionPlan`` over the registered columns (planner layer;
        measured timings when a ``run`` has happened, calibrated chip estimates
        otherwise).  Keyword overrides pass through to ``StreamingExecutor.plan``
        (``chunk_bytes="auto"`` enables per-column chunk sizing)."""
        return self.executor.plan(list(self._encoded), policy=policy, **kw)

    def run(self, order: list[str] | None = None,
            plan=None) -> dict[str, ColumnResult]:
        """Execute the pipeline under an ExecutionPlan (auto-built from the
        configured policy unless given; an explicit ``order`` pins issue order).

        The first run of fresh columns plans from the calibrated chip-model
        estimate (no pre-run profiling pass -- the old behaviour of
        transferring+decoding every column once just to schedule it is exactly
        the double-measurement this replaces); runs after a ``run`` or
        ``_measure`` plan from measured timings.
        """
        return self.executor.run(self._encoded, order=order, plan=plan)

    def mesh_plan(self, n_devices: int | None = None, **kw):
        """Topology-aware ``MeshExecutionPlan`` over the registered columns
        (``planner.plan_mesh_execution``): whole columns -- and group-span
        shards of oversized ones -- assigned to ``n_devices`` links so the
        modeled ``simulate_stream_multi`` makespan is <= round-robin and
        single-device by construction.  Defaults to the constructor's
        ``mesh=`` count (else every visible jax device)."""
        from repro.core import planner as planner_mod

        n = n_devices if n_devices is not None else self.mesh
        if n is None:
            n = len(jax.devices())
        profiles = {name: self.executor.column_profile(name)
                    for name in self._encoded}
        kw.setdefault("chunk_bytes", self.chunk_bytes)
        kw.setdefault("policy", self.policy)
        kw.setdefault("placement", self.placement)
        return planner_mod.plan_mesh_execution(
            profiles, self.executor.cost_model, n_devices=n, **kw)

    def run_sharded(self, n_devices: int | None = None, plan=None):
        """Execute the registered columns over a device mesh (per-device
        in-flight windows, shard-local decode; sharded outputs land
        ``jax.sharding``-annotated).  Returns ``executor.MeshRunResult``."""
        if plan is None:
            plan = self.mesh_plan(n_devices)
        return self.executor.run_sharded(plan, self._encoded)

    def lower_query(self, qplan):
        """Graft a ``core.query.QueryPlan`` onto the registered columns' decode
        graphs (``FusedQuery``); the blobs used are the ones ``compress`` built.
        Lowerings are memoized by query digest (``compress`` invalidates), so
        warm ``run_query`` calls measure execution, not re-lowering."""
        key = qplan.digest()
        hit = self._queries.get(key)
        if hit is None:
            from repro.core.query import lower_query

            encs = {c: self._encoded[c] for c in qplan.columns()}
            hit = (lower_query(qplan, encs), encs)
            self._queries[key] = hit
        return hit

    def query_plan(self, qplan, **kw):
        """ExecutionPlan for a pending query: per column, fused-vs-materialize
        decided by the cost model's selectivity-aware fused estimate
        (``plan.explain()`` shows ``mode=...+fused sel=...`` rows)."""
        fq, encs = self.lower_query(qplan)
        return self.executor.plan(list(encs),
                                  fused_columns={c: None for c in fq.fused_cols},
                                  **kw)

    def run_query(self, qplan, window: int | None = None):
        """Decode-fused query execution (late materialization): stream the
        fused columns through per-chunk scan-filter-aggregate launches; only
        partial aggregates reach HBM.  The in-flight window AND the row-chunk
        count come from the cost model (memoized per query digest): the fused
        columns form ONE shared-schedule job, and the chunk count is chosen by
        ``simulate_stream`` over a small ladder, pricing each extra launch at
        the calibrated overhead — on hosts where launch overhead dominates
        (CPU) this collapses to a single fused launch; where transfer/decode
        overlap pays, it chunks.  An explicitly configured fixed ``chunk_bytes``
        overrides the search, like ``run``.  The fused accumulator costs one
        staging slot, accounted inside ``StreamingExecutor.run_query``."""
        from repro.core import scheduler

        fq, encs = self.lower_query(qplan)
        key = qplan.digest()
        cfg = self._query_cfg.get(key)
        if cfg is None:
            ep = self.query_plan(qplan)     # registers profiles for all cols
            if isinstance(self.chunk_bytes, int):
                cb = self.chunk_bytes       # fixed size: user override
            else:
                from repro.core.costmodel import serial_host

                cm = self.executor.cost_model
                t_tr = d_fused = oh = 0.0
                for c in fq.fused_cols:
                    t_tr += cm.predict(c)[0]
                    d_fused += cm.fused_decode_s(c)
                    oh = max(oh, cm.launch_overhead_s(c))
                best_k, best_t = 1, None
                for k in (1, 2, 4, 8):
                    if serial_host():
                        # one resource: no transfer/decode overlap, chunking
                        # only buys launch overhead
                        mk = t_tr + d_fused + (k - 1) * oh
                    else:
                        mk = scheduler.simulate_stream(
                            [scheduler.Job(qplan.name, t_tr, d_fused)],
                            [scheduler.ChunkInfo(n_chunks=k,
                                                 chunk_decode=k > 1,
                                                 launch_overhead_s=oh)],
                            window=ep.window)
                    if best_t is None or mk < best_t - 1e-12:
                        best_k, best_t = k, mk
                comp = sum(self._encoded[c].compressed_nbytes
                           for c in fq.fused_cols)
                cb = None if best_k == 1 else -(-comp // best_k)
            cfg = (ep.window, cb)
            self._query_cfg[key] = cfg
        win, cb = cfg
        if window is not None:
            win = window
        return self.executor.run_query(fq, encs, chunk_bytes=cb, window=win)

    def modeled_makespan(self, pipeline: bool = True, johnson: bool = True,
                         chunked: bool = False) -> float:
        """Two-machine flow-shop makespan from cached per-column times (chunk-level
        jobs when ``chunked``); measures each column at most once, ever."""
        names = list(self._encoded)
        for n in names:
            self._measure(n)
        return self.executor.modeled_makespan(
            names=names, pipeline=pipeline, johnson=johnson, chunked=chunked)

    def serve_planner(self, policy: str = "shared",
                      max_wave: int | None = None,
                      mesh: int | None = None):
        """Multi-query serving planner sharing this pipeline's executor (and
        therefore its ProgramCache and calibrated CostModel): concurrent
        requests' columns compose into one shared transfer queue, with
        cross-request signature batching and SLO-aware issue ordering
        (``core/serve_planner.py``).  Requests submit their own ``Encoded``
        blobs; ``encode_request`` builds one from this pipeline's plans."""
        from repro.core.serve_planner import ServePlanner

        return ServePlanner(self.executor, policy=policy, max_wave=max_wave,
                            mesh=mesh if mesh is not None else self.mesh)

    def encode_request(self, columns: dict[str, np.ndarray]
                       ) -> dict[str, plan_mod.Encoded]:
        """Encode a request's columns with this pipeline's per-column plans
        (serving-path helper: blobs for ``ServePlanner.submit``)."""
        return {name: plan_mod.encode(self.plans[name], arr)
                for name, arr in columns.items()}
