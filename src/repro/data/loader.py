"""Compressed host->device data pipeline (the paper's end-to-end workflow, Fig. 3,
integrated into LM training).

``CompressedTokenLoader`` stores/ships token batches bit-packed to ceil(log2 vocab)
bits with a *fixed* bit width, so every step's compressed buffers have identical
shapes -- the decode prologue jits once and the decompression fuses into the train
step (overlapping the previous step's compute, the Pipelining Layer's role inside one
program).

``ColumnPipeline`` is the analytics-shaped pipeline: arbitrary per-column plans,
Johnson's-rule issue ordering across columns (paper §3.3), async ``device_put`` so
transfer of column k+1 overlaps decode of column k.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Any, Callable, Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compiler, plan as plan_mod, scheduler
from repro.core.plan import Plan, make_plan


# ------------------------------------------------------------- training loader

class CompressedTokenLoader:
    """Wraps a token source with fixed-width bit-packed transfer."""

    def __init__(self, vocab: int, batch: int, seq_len: int,
                 source: Callable[[int], np.ndarray] | None = None,
                 seed: int = 0):
        self.vocab = vocab
        self.batch = batch
        self.seq = seq_len
        self.bits = max(1, math.ceil(math.log2(max(vocab, 2))))
        self._rng = np.random.default_rng(seed)
        self._source = source or self._synthetic
        self.bytes_plain = 0
        self.bytes_compressed = 0

    def _synthetic(self, step: int) -> np.ndarray:
        rng = np.random.default_rng(step)  # deterministic in step (FT requirement)
        return rng.integers(0, self.vocab, (self.batch, self.seq + 1),
                            dtype=np.int32)

    def encode_host(self, step: int) -> dict[str, np.ndarray]:
        """Host side: tokens -> fixed-shape packed words."""
        from repro.algos.bitpack import pack_np

        toks = self._source(step)
        packed = pack_np(toks.reshape(-1).astype(np.int64), self.bits)
        self.bytes_plain += toks.nbytes
        self.bytes_compressed += packed.nbytes
        return {"packed": packed}

    def decode_fn(self):
        """Jittable device prologue: packed words -> {tokens, labels}."""
        from repro.kernels.ref import unpack_bits_ref

        B, S, bits = self.batch, self.seq, self.bits

        def decode(bufs):
            flat = unpack_bits_ref(bufs["packed"], B * (S + 1), bits)
            toks = flat.reshape(B, S + 1).astype(jnp.int32)
            return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

        return decode

    def batches(self, start_step: int = 0) -> Iterator[dict[str, jnp.ndarray]]:
        step = start_step
        while True:
            yield {k: jax.device_put(v) for k, v in self.encode_host(step).items()}
            step += 1

    @property
    def ratio(self) -> float:
        return self.bytes_plain / max(self.bytes_compressed, 1)


# ------------------------------------------------------------ analytics pipeline

@dataclasses.dataclass
class ColumnResult:
    name: str
    array: jnp.ndarray
    transfer_s: float
    decode_s: float
    compressed_bytes: int
    plain_bytes: int


class ColumnPipeline:
    """Transfer + decompress a set of columns with Johnson-ordered pipelining."""

    def __init__(self, plans: dict[str, Plan], backend: str = "jnp",
                 fuse: bool = True, pipeline: bool = True):
        self.plans = plans
        self.backend = backend
        self.fuse = fuse
        self.pipeline = pipeline
        self._encoded: dict[str, plan_mod.Encoded] = {}
        self._decoders: dict[str, compiler.CompiledDecoder] = {}

    def compress(self, columns: dict[str, np.ndarray]) -> dict[str, float]:
        ratios = {}
        for name, arr in columns.items():
            enc = plan_mod.encode(self.plans[name], arr)
            self._encoded[name] = enc
            self._decoders[name] = compiler.compile_decoder(
                enc, backend=self.backend, fuse=self.fuse)
            ratios[name] = enc.ratio
        return ratios

    def _measure(self, name: str) -> tuple[float, float]:
        """One warm measurement of (transfer_s, decode_s) for scheduling."""
        enc = self._encoded[name]
        t0 = time.perf_counter()
        bufs = compiler.device_buffers(enc)
        jax.block_until_ready(list(bufs.values()))
        t1 = time.perf_counter()
        out = self._decoders[name](bufs)
        jax.block_until_ready(out)
        t2 = time.perf_counter()
        return t1 - t0, t2 - t1

    def run(self, order: list[str] | None = None) -> dict[str, ColumnResult]:
        """Execute the pipeline; Johnson order unless explicitly given."""
        names = list(self._encoded)
        est = {n: self._measure(n) for n in names}      # offline profile (paper §3.3)
        if order is None and self.pipeline:
            order = scheduler.schedule(names, [est[n][0] for n in names],
                                       [est[n][1] for n in names])
        elif order is None:
            order = names
        results: dict[str, ColumnResult] = {}
        pending: list[tuple[str, dict]] = []
        for name in order:  # async transfers issue in order; decode drains
            bufs = {k: jax.device_put(v) for k, v in
                    plan_mod.flat_buffers(self._encoded[name]).items()}
            pending.append((name, bufs))
        for name, bufs in pending:
            out = self._decoders[name](bufs)
            enc = self._encoded[name]
            results[name] = ColumnResult(
                name=name, array=out, transfer_s=est[name][0],
                decode_s=est[name][1], compressed_bytes=enc.compressed_nbytes,
                plain_bytes=enc.plain_nbytes)
        jax.block_until_ready([r.array for r in results.values()])
        return results

    def modeled_makespan(self, pipeline: bool = True,
                         johnson: bool = True) -> float:
        """Two-machine flow-shop makespan from the measured per-column times."""
        names = list(self._encoded)
        est = {n: self._measure(n) for n in names}
        jobs = [scheduler.Job(n, est[n][0], est[n][1]) for n in names]
        if not pipeline:
            return scheduler.serial_time(jobs)
        order = scheduler.johnson_order(jobs) if johnson else list(range(len(jobs)))
        return scheduler.makespan(jobs, order)
