"""Elastic scaling: re-mesh and reshard after node failure (1000+-node posture).

On a real cluster the coordinator detects a dead host (heartbeat timeout or the
straggler signal from train/loop.py), evicts its slice, and restarts the job on the
survivors.  The pieces implemented here:

  * ``plan_remesh`` -- given the old mesh axes and the surviving chip count, pick the
    largest valid (data', model) mesh that preserves the TP axis (model-parallel
    groups must stay intact; only data-parallel replicas are elastic).
  * ``reshard`` -- move a checkpointed pytree onto the new mesh's shardings
    (device_put against newly resolved NamedShardings; on a cluster this is the
    restore path reading the compressed shards of checkpoint.py).
  * ``replan_suffix`` -- decode-path elasticity: when a device joins or leaves
    mid-stream, the not-yet-issued columns of a ``MeshExecutionPlan`` re-plan
    over the surviving links (topology resized), completed work untouched.
  * ``ElasticCoordinator`` -- restart loop glue: on failure, re-mesh, reshard from
    the latest checkpoint, continue at the recorded step with the *same* global
    batch (deterministic batch_fn(step) keeps the data order identical, so the
    replacement run recomputes exactly the lost steps).

Tested by simulation in tests/test_elastic.py (subprocess with forced host devices).
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.launch.mesh import shard_tree


@dataclasses.dataclass
class RemeshPlan:
    data: int
    model: int
    dropped_chips: int

    @property
    def shape(self) -> tuple[int, int]:
        return (self.data, self.model)


def plan_remesh(surviving_chips: int, model_size: int = 16) -> RemeshPlan:
    """Largest (data', model) grid on the survivors, TP groups intact."""
    if surviving_chips < model_size:
        raise RuntimeError(
            f"cannot keep {model_size}-way TP with {surviving_chips} chips")
    data = surviving_chips // model_size
    used = data * model_size
    return RemeshPlan(data=data, model=model_size,
                      dropped_chips=surviving_chips - used)


def make_mesh_from_plan(plan: RemeshPlan, devices=None):
    devices = devices if devices is not None else jax.devices()
    n = plan.data * plan.model
    grid = np.asarray(devices[:n]).reshape(plan.shape)
    return jax.sharding.Mesh(grid, ("data", "model"))


def reshard(tree, logical_specs, new_mesh):
    """Place a (host or old-mesh) pytree onto the new mesh."""
    shapes = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    shardings = shard_tree(shapes, logical_specs, new_mesh)
    flat_x, tdef = jax.tree_util.tree_flatten(tree)
    flat_s = jax.tree_util.tree_flatten(shardings)[0]
    return tdef.unflatten([jax.device_put(np.asarray(x), s)
                           for x, s in zip(flat_x, flat_s)])


def replan_suffix(mesh_plan, done, surviving_device_ids, cost_model, profiles,
                  **plan_kwargs):
    """Re-partition the not-yet-issued suffix of a mesh decode plan after a
    device joins or leaves.

    ``done`` names the columns already decoded (their shards count as done
    when the parent column is done); everything else re-plans from scratch
    over ``surviving_device_ids`` with the cost model's topology resized to
    the new link count -- completed work is never moved or repeated.  The
    original plan's placement constraint (and with it any D2D rebalance
    legs) is re-applied to the suffix, so a redistribution-tier plan keeps
    its landing-vs-placement split across the elasticity event.  Returns
    the new ``MeshExecutionPlan`` over the remaining columns (None when
    nothing is left)."""
    from repro.core import planner as planner_mod

    done = set(done)
    remaining = [c for c in mesh_plan.columns() if c not in done]
    if not remaining:
        return None
    ids = tuple(int(x) for x in surviving_device_ids)
    if not ids:
        raise RuntimeError("cannot re-plan decode onto zero devices")
    topo = mesh_plan.topology.resized(len(ids))
    plan_kwargs.setdefault(
        "placement", getattr(mesh_plan, "placement_policy", None))
    return planner_mod.plan_mesh_execution(
        {c: profiles[c] for c in remaining}, cost_model,
        n_devices=len(ids), device_ids=ids, topology=topo,
        window=mesh_plan.window, **plan_kwargs)


class ElasticCoordinator:
    """Failure -> re-mesh -> reshard -> resume, preserving data order."""

    def __init__(self, model_size: int, ckpt_dir: str):
        self.model_size = model_size
        self.ckpt_dir = ckpt_dir

    def recover(self, tree_like, logical_specs, surviving_devices):
        from repro.train import checkpoint as ckpt

        plan = plan_remesh(len(surviving_devices), self.model_size)
        mesh = make_mesh_from_plan(plan, surviving_devices)
        tree, step, _extra = ckpt.restore(self.ckpt_dir, tree_like)
        placed = reshard(tree, logical_specs, mesh)
        return placed, mesh, step
