import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST precede every other import: jax locks the device count at
# first initialization, and the production meshes below need 512 host placeholders.

"""Multi-pod dry-run: prove the distribution config is coherent without hardware.

For every (architecture x input shape) cell and both production meshes, lower the
appropriate step (train_step / prefill / serve decode_step) with ShapeDtypeStruct
inputs, ``.compile()`` it, and record:
  * memory_analysis()   -- proves the program fits per-device HBM,
  * cost_analysis()     -- per-chip FLOPs / bytes for the roofline,
  * collective wire bytes parsed from the compiled HLO.

Usage:
  python -m repro.launch.dryrun --arch dbrx-132b --shape train_4k --mesh multipod
  python -m repro.launch.dryrun --all --mesh pod --out experiments/dryrun
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
import numpy as np

from repro.configs import ARCHS, SHAPES
from repro.launch.mesh import make_production_mesh, shard_tree
from repro.models import cell_status, get_model
from repro.roofline import analysis, hlo_cost
from repro.train import optimizer
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import make_train_step

# per-arch dry-run training knobs (remat policy, microbatch) -- revisited in §Perf
TRAIN_KNOBS: dict[str, dict] = {
    "nemotron-4-15b": {"microbatch": 8, "remat": "full"},
    "dbrx-132b": {"microbatch": 16, "remat": "full"},
    "phi3.5-moe-42b-a6.6b": {"microbatch": 4, "remat": "full"},
    "phi3-mini-3.8b": {"microbatch": 4, "remat": "full"},
    "zamba2-7b": {"microbatch": 4, "remat": "full"},
    "rwkv6-7b": {"microbatch": 4, "remat": "full"},
    "qwen2-vl-2b": {"microbatch": 2, "remat": "full"},
    "seamless-m4t-medium": {"microbatch": 2, "remat": "full"},
    "qwen1.5-0.5b": {"microbatch": 1, "remat": "full"},
    "smollm-360m": {"microbatch": 4, "remat": "full"},
}


def abstract_init(model, key=None):
    """(param ShapeDtypeStructs, logical specs) without allocating anything."""
    captured = {}

    def initp(k):
        p, s = model.init(k)
        captured["specs"] = s
        return p

    shapes = jax.eval_shape(initp, jax.random.PRNGKey(0))
    return shapes, captured["specs"]


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             knobs: dict | None = None) -> dict:
    cfg = ARCHS[arch]
    shape = SHAPES[shape_name]
    mesh_name = "multipod_2x16x16" if multi_pod else "pod_16x16"
    rec: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name}
    status = cell_status(cfg, shape)
    if status != "run":
        rec["status"] = status
        return rec
    t0 = time.time()
    model = get_model(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    from repro.models.sharding_ctx import set_mesh_context
    set_mesh_context(mesh)  # activation with_sharding_constraints inside the models
    chips = int(np.prod(list(mesh.shape.values())))
    p_shapes, p_logical = abstract_init(model)
    p_sh = shard_tree(p_shapes, p_logical, mesh)
    in_shapes, in_logical = model.input_specs(shape)
    in_sh = shard_tree(in_shapes, in_logical, mesh)

    if shape.kind == "train":
        kn = dict(TRAIN_KNOBS.get(arch, {}))
        kn.update(knobs or {})
        # per-microbatch batch must stay divisible by the fsdp axes or XLA
        # replicates the activations (measured: dbrx multipod mb16 -> 95 GB/dev)
        fsdp_size = int(np.prod([s for n, s in mesh.shape.items()
                                 if n != "model"]))
        mb = kn.get("microbatch", 1)
        while mb > 1 and (shape.global_batch // mb) % fsdp_size:
            mb //= 2
        kn["microbatch"] = mb
        step = make_train_step(cfg, AdamWConfig(),
                               remat=kn.get("remat", "full"),
                               microbatch=kn.get("microbatch", 1))
        o_shapes = jax.eval_shape(optimizer.init, p_shapes)
        o_logical = {"mu": p_logical, "nu": p_logical, "step": None}
        o_sh = shard_tree(o_shapes, o_logical, mesh)
        jitted = jax.jit(step, in_shardings=(p_sh, o_sh, in_sh),
                         donate_argnums=(0, 1))
        lowered = jitted.lower(p_shapes, o_shapes, in_shapes)
        rec["knobs"] = kn
    elif shape.kind == "prefill":
        st_shapes = jax.eval_shape(
            lambda: model.make_state(shape.global_batch, shape.seq_len))
        st_sh = shard_tree(st_shapes, model.state_specs(shape.global_batch), mesh)
        fn = lambda p, b, st: model.prefill(p, b, st)
        jitted = jax.jit(fn, in_shardings=(p_sh, in_sh, st_sh),
                         donate_argnums=(2,))
        lowered = jitted.lower(p_shapes, in_shapes, st_shapes)
    else:  # decode
        st_shapes = jax.eval_shape(
            lambda: model.make_state(shape.global_batch, shape.seq_len))
        st_sh = shard_tree(st_shapes, model.state_specs(shape.global_batch), mesh)
        fn = lambda p, t, st: model.decode_step(p, t, st)
        jitted = jax.jit(fn, in_shardings=(p_sh, in_sh["token"], st_sh),
                         donate_argnums=(2,))
        lowered = jitted.lower(p_shapes, in_shapes["token"], st_shapes)

    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower
    mem = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    # trip-count-aware walk (XLA's cost_analysis counts while bodies once)
    walk = hlo_cost.analyze(hlo)
    per_dev = int(mem.argument_size_in_bytes + mem.output_size_in_bytes
                  + mem.temp_size_in_bytes - mem.alias_size_in_bytes)
    roof = analysis.Roofline(
        arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
        hlo_flops_per_chip=float(walk["flops"]),
        hlo_bytes_per_chip=float(walk["bytes"]),
        coll_bytes_per_chip=float(walk["coll_bytes"]),
        coll_breakdown=walk["collectives"],
        model_flops_total=analysis.model_flops(cfg, shape, shape.kind),
        per_device_bytes=per_dev,
        useful_bytes_per_chip=float(mem.argument_size_in_bytes
                                    + mem.output_size_in_bytes),
    )
    rec.update(status="ok", lower_s=round(t_lower, 1),
               compile_s=round(t_compile, 1),
               memory={"argument": int(mem.argument_size_in_bytes),
                       "output": int(mem.output_size_in_bytes),
                       "temp": int(mem.temp_size_in_bytes),
                       "alias": int(mem.alias_size_in_bytes),
                       "per_device_live": per_dev,
                       "fits_16g_hbm": bool(per_dev < 16 * 2**30)},
               roofline=roof.to_dict(),
               xla_raw_cost={"flops": float(ca.get("flops", 0.0)),
                             "bytes": float(ca.get("bytes accessed", 0.0))},
               hlo_ops={"n_instructions": hlo.count("=")})
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default=None)
    ap.add_argument("--shape", choices=sorted(SHAPES), default=None)
    ap.add_argument("--mesh", choices=["pod", "multipod", "both"], default="pod")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--remat", default=None)
    ap.add_argument("--microbatch", type=int, default=None)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    cells = []
    archs = sorted(ARCHS) if (args.all or args.arch is None) else [args.arch]
    shapes = sorted(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = [False, True] if args.mesh == "both" else [args.mesh == "multipod"]
    knobs = {}
    if args.remat:
        knobs["remat"] = args.remat
    if args.microbatch:
        knobs["microbatch"] = args.microbatch
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}_{shape}_{'mp' if mp else 'sp'}"
                out_path = os.path.join(args.out, tag + ".json")
                if os.path.exists(out_path):
                    print(f"[dryrun] {tag}: cached")
                    continue
                print(f"[dryrun] {tag}: lowering...", flush=True)
                try:
                    rec = run_cell(arch, shape, mp, knobs or None)
                except Exception as e:  # noqa: BLE001 -- record the failure
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "mp" if mp else "sp",
                           "status": f"FAIL: {type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-2000:]}
                json.dump(rec, open(out_path, "w"), indent=1)
                status = rec.get("status", "?")
                extra = ""
                if status == "ok":
                    r = rec["roofline"]
                    extra = (f" bottleneck={r['bottleneck']}"
                             f" mem/dev={rec['memory']['per_device_live'] / 2**30:.2f}G"
                             f" compile={rec['compile_s']}s")
                print(f"[dryrun] {tag}: {status[:100]}{extra}", flush=True)


if __name__ == "__main__":
    main()
