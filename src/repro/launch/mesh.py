"""Production mesh + sharding resolution.

``make_production_mesh`` is a FUNCTION (importing this module never touches jax
device state):  single pod = (16, 16) ("data", "model") = 256 chips;
multi-pod = (2, 16, 16) ("pod", "data", "model") = 512 chips across the DCN.

``shard_tree`` resolves the models' *logical* specs ("fsdp"/"tp" tuples, see
repro.models.layers) into NamedShardings against actual array shapes, replicating any
dimension whose size does not divide the mesh axis (small archs on big meshes, B=1
long-context decode, odd vocabs).

XLA flags for real-TPU runs (latency-hiding overlap of the collectives this mesh
generates) are recorded in ``TPU_PERF_FLAGS`` and set by launch/train.py.
"""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

TPU_PERF_FLAGS = (
    "--xla_enable_async_collective_permute=true "
    "--xla_tpu_enable_data_parallel_all_reduce_opt=true "
    "--xla_tpu_data_parallel_opt_different_sized_ops=true "
    "--xla_tpu_enable_async_collective_fusion=true "
    "--xla_tpu_overlap_compute_collective_tc=true "
    "--xla_enable_async_all_gather=true "
)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def mesh_axes(mesh) -> tuple[tuple[str, ...], str]:
    """-> (fsdp axis names, tp axis name)."""
    names = mesh.axis_names
    fsdp = tuple(n for n in names if n != "model")
    return fsdp, "model"


def _axis_size(mesh, names) -> int:
    if isinstance(names, str):
        names = (names,)
    return int(np.prod([mesh.shape[n] for n in names]))


def resolve_entry(entry, dim: int, mesh, fsdp, tp):
    """Logical spec entry -> mesh axis (or None), honoring divisibility."""
    if entry is None:
        return None
    if entry == "fsdp" or (isinstance(entry, tuple) and entry[0] == "fsdp"):
        name = fsdp if len(fsdp) > 1 else fsdp[0]
        return name if dim % _axis_size(mesh, fsdp) == 0 else None
    if entry == "tp" or (isinstance(entry, tuple) and entry[0] == "tp"):
        return tp if dim % _axis_size(mesh, tp) == 0 else None
    raise ValueError(f"bad logical spec entry {entry!r}")


def shard_tree(shapes, logical_specs, mesh) -> "jax.tree":
    """Resolve a logical-spec tree against a ShapeDtypeStruct tree.

    Handles ("stacked", subtree) / ("stacked2", subtree) markers by left-padding the
    spec with None dims.
    """
    fsdp, tp = mesh_axes(mesh)

    def walk(shape_t, spec_t, lead):
        if (isinstance(spec_t, tuple) and len(spec_t) == 2
                and spec_t[0] in ("stacked", "stacked2")
                and isinstance(spec_t[1], dict)):
            return walk(shape_t, spec_t[1],
                        lead + (1 if spec_t[0] == "stacked" else 2))
        if isinstance(spec_t, dict):
            return {k: walk(shape_t[k], spec_t[k], lead) for k in spec_t}
        if spec_t is None:
            return NamedSharding(mesh, P())
        if isinstance(spec_t, P):
            return NamedSharding(mesh, spec_t)
        shp = tuple(shape_t.shape)
        entries = tuple(spec_t)
        assert len(entries) + lead == len(shp), (shp, spec_t, lead)
        resolved = (None,) * lead + tuple(
            resolve_entry(e, d, mesh, fsdp, tp)
            for e, d in zip(entries, shp[lead:]))
        return NamedSharding(mesh, P(*resolved))

    return walk(shapes, logical_specs, 0)


def replicated(mesh):
    return NamedSharding(mesh, P())
