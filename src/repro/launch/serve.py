"""Serving driver: ``python -m repro.launch.serve --arch <id> --smoke``

Continuous-batching engine over the uniform Model API (decode_step jitted once;
prefill via the engine).  Production meshes attach exactly as in launch/train.py.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import ARCHS, SMOKES
from repro.models import get_model
from repro.serve.engine import Request, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b", choices=sorted(ARCHS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=2)
    args = ap.parse_args()
    cfg = (SMOKES if args.smoke else ARCHS)[args.arch]
    model = get_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, batch_slots=args.slots, max_len=256, eos=-1)
    rng = np.random.default_rng(0)
    for rid in range(args.requests):
        eng.submit(Request(rid, rng.integers(0, cfg.vocab, 8).astype(np.int32),
                           max_new=args.max_new))
    done = eng.run_to_completion(max_steps=2000)
    for rid in sorted(done):
        print(f"[serve] request {rid}: {len(done[rid])} tokens -> "
              f"{done[rid][:8]}...")


if __name__ == "__main__":
    main()
