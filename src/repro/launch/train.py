"""Training driver: ``python -m repro.launch.train --arch <id> [--smoke] ...``

Wires together the full stack: compressed data loader (the paper's pipeline feeding
the step), train_step (FSDP+TP via shardings when a mesh is available), AdamW,
fault-tolerant loop with compressed checkpoints.  On this CPU container use --smoke
(reduced configs); on a real TPU slice the same driver runs the production configs
with ``make_production_mesh`` and ``TPU_PERF_FLAGS``.
"""
from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, SMOKES
from repro.data.loader import CompressedTokenLoader
from repro.launch.mesh import TPU_PERF_FLAGS, make_production_mesh, shard_tree
from repro.models import get_model
from repro.models.sharding_ctx import set_mesh_context
from repro.train import checkpoint as ckpt_mod
from repro.train.loop import LoopConfig, run
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import make_train_step
from repro.train import optimizer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b", choices=sorted(ARCHS))
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--remat", default=None)
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--production-mesh", action="store_true",
                    help="use the (16,16) mesh (requires a real slice)")
    args = ap.parse_args()

    cfg = (SMOKES if args.smoke else ARCHS)[args.arch]
    model = get_model(cfg)
    if args.production_mesh:
        os.environ.setdefault("LIBTPU_INIT_ARGS", TPU_PERF_FLAGS)
        mesh = make_production_mesh()
        set_mesh_context(mesh)
    params, specs = model.init(jax.random.PRNGKey(0))
    opt_state = optimizer.init(params)
    if args.production_mesh:
        shapes = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                              params)
        shardings = shard_tree(shapes, specs, mesh)
        params = jax.tree.map(jax.device_put, params, shardings)

    opt_cfg = AdamWConfig(lr=args.lr, total_steps=args.steps)
    step = jax.jit(make_train_step(cfg, opt_cfg, remat=args.remat,
                                   microbatch=args.microbatch),
                   donate_argnums=(0, 1))
    # the ZipFlow-compressed token pipeline: fixed-width packed transfer + fused
    # on-device decode prologue
    loader = CompressedTokenLoader(cfg.vocab, args.batch, args.seq)
    decode = loader.decode_fn()

    def step_with_decode(p, o, bufs):
        return step(p, o, decode(bufs))

    def batch_fn(i):
        return {k: jax.device_put(v) for k, v in loader.encode_host(i).items()}

    loop_cfg = LoopConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                          ckpt_every=args.ckpt_every)
    params, opt_state, hist = run(loop_cfg, step_with_decode, params, opt_state,
                                  batch_fn)
    print(f"[train] done: final loss {hist[-1]['loss']:.4f}; "
          f"data moved compressed at ratio {loader.ratio:.2f}x; "
          f"checkpoints in {args.ckpt_dir} "
          f"(ratio {ckpt_mod.compression_report(args.ckpt_dir)['ratio']:.3f})")


if __name__ == "__main__":
    main()
