"""ZipFlow core: patterns, plans, fusion, geometry scheduling, pipelining."""
from repro.core.compiler import compile_decoder, decode_on_device, device_buffers
from repro.core.geometry import CHIPS, Geometry, chip, native_config
from repro.core.plan import Encoded, Plan, decode_np, encode, flat_buffers, lower, make_plan
from repro.core.scheduler import Job, johnson_order, makespan, schedule

__all__ = [
    "CHIPS", "Encoded", "Geometry", "Job", "Plan", "chip", "compile_decoder",
    "decode_np", "decode_on_device", "device_buffers", "encode", "flat_buffers",
    "johnson_order", "lower", "make_plan", "makespan", "native_config", "schedule",
]
