"""ZipFlow core: patterns, plans, decode-graph IR, fusion, geometry, executor."""
from repro.core.compiler import (DEFAULT_CACHE, ChunkProgram, Program, ProgramCache,
                                 compile_blob, compile_decoder, decode_on_device,
                                 device_buffers)
from repro.core.executor import ColumnExec, StreamingExecutor
from repro.core.geometry import CHIPS, Geometry, chip, native_config
from repro.core.ir import (BufferDef, DecodeGraph, MetaSpec, element_chunk_layout,
                           structural_signature)
from repro.core.plan import (Encoded, Plan, decode_np, encode, flat_buffers,
                             host_operands, lower, lower_graph, make_plan,
                             meta_operands)
from repro.core.scheduler import Job, chunk_jobs, johnson_order, makespan, schedule

__all__ = [
    "CHIPS", "BufferDef", "ChunkProgram", "ColumnExec", "DEFAULT_CACHE",
    "DecodeGraph", "Encoded", "Geometry", "Job", "MetaSpec", "Plan", "Program",
    "ProgramCache", "StreamingExecutor", "chip", "chunk_jobs", "compile_blob",
    "compile_decoder", "decode_np", "decode_on_device", "device_buffers",
    "element_chunk_layout", "encode", "flat_buffers", "host_operands",
    "johnson_order", "lower", "lower_graph", "make_plan", "makespan",
    "meta_operands", "native_config", "schedule", "structural_signature",
]
