"""ZipFlow core: patterns, plans, decode-graph IR, fusion, geometry, planner,
executor."""
from repro.core.compiler import (DEFAULT_CACHE, ChunkProgram, Program, ProgramCache,
                                 compile_blob, compile_decoder, decode_on_device,
                                 device_buffers)
from repro.core.costmodel import ColumnProfile, CostModel, profile_from
from repro.core.executor import ColumnExec, StreamingExecutor
from repro.core.geometry import CHIPS, Geometry, chip, native_config
from repro.core.ir import (BufferDef, DecodeGraph, MetaSpec, element_chunk_layout,
                           structural_signature)
from repro.core.plan import (Encoded, Plan, decode_np, encode, flat_buffers,
                             host_operands, lower, lower_graph, make_plan,
                             meta_operands)
from repro.core.planner import ColumnDecision, ExecutionPlan, plan_execution
from repro.core.scheduler import (POLICIES, AdaptivePolicy, ChunkInfo,
                                  ChunkJohnsonPolicy, FifoPolicy, Job,
                                  JohnsonPolicy, SchedulingPolicy, chunk_jobs,
                                  get_policy, johnson_order, makespan, schedule,
                                  simulate_stream)

__all__ = [
    "CHIPS", "AdaptivePolicy", "BufferDef", "ChunkInfo", "ChunkJohnsonPolicy",
    "ChunkProgram", "ColumnDecision", "ColumnExec", "ColumnProfile", "CostModel",
    "DEFAULT_CACHE", "DecodeGraph", "Encoded", "ExecutionPlan", "FifoPolicy",
    "Geometry", "Job", "JohnsonPolicy", "MetaSpec", "POLICIES", "Plan",
    "Program", "ProgramCache", "SchedulingPolicy", "StreamingExecutor", "chip",
    "chunk_jobs", "compile_blob", "compile_decoder", "decode_np",
    "decode_on_device", "device_buffers", "element_chunk_layout", "encode",
    "flat_buffers", "get_policy", "host_operands", "johnson_order", "lower",
    "lower_graph", "make_plan", "makespan", "meta_operands", "native_config",
    "plan_execution", "profile_from", "schedule", "simulate_stream",
    "structural_signature",
]
