"""Plan-level kernel fusion (paper §3.2 Fig. 7(c), §4 'Scheduling Fully-Parallel with
Fusion', and the §5.3.3 ablation).

Rules, applied to a lowered stage list until fixpoint:

  1. FP -> FP        : compose map closures (single kernel, no intermediate round-trip).
  2. FP -> GP.values : absorb the producer into the Group-Parallel kernel's value
                       gather (the paper's "bit-packing that generates the value tensor
                       is fused with the Group-Parallel kernel inside RLE").
  3. GP -> FP        : absorb an elementwise consumer into the expansion kernel's
                       output map (e.g. type casts, dictionary lookups after RLE).
  4. NP -> FP        : absorb an elementwise consumer into the chunked decoder's
                       output map.
  5. FP -> Aux       : inline the producer into the auxiliary whole-array op (the
                       cumsum that computes `presum` consumes bit-packed counts without
                       materializing them; cheap on-the-fly in XLA).
  6. FP -> operator  : compose a Fully-Parallel producer into *any* input position of
                       a positional-input consumer (operator predicate/projection
                       stages and the terminal ``Reduce``) -- this is the codec x
                       operator fusion that grafts a whole decode chain into the
                       query's scan-filter-aggregate so the decompressed column is
                       never written to HBM (late materialization).

A buffer may only be fused away if it has exactly one consumer and is not the plan's
final output.  Memory-traffic accounting for each rule follows the paper's Eq. 2: every
avoided materialization saves one write + one read of the intermediate at HBM.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.core.patterns import (Aux, Ctx, FullyParallel, GroupParallel, NonParallel,
                                 Reduce, Stage, compose_fp, compose_positional)


def _stage_inputs(st: Stage) -> tuple[str, ...]:
    if isinstance(st, FullyParallel):
        return st.inputs
    if isinstance(st, GroupParallel):
        return (st.presum,) + st.value_inputs + st.extra_inputs
    if isinstance(st, NonParallel):
        return (st.streams, st.states, st.sym_tab, st.freq_tab, st.cum_tab)
    # Aux, Reduce, and any future stage kind carrying a flat ``inputs`` tuple
    return getattr(st, "inputs", ())


def _use_counts(stages: Sequence[Stage]) -> dict[str, int]:
    uses: dict[str, int] = {}
    for st in stages:
        for name in _stage_inputs(st):
            uses[name] = uses.get(name, 0) + 1
    return uses


def fuse(stages: list[Stage], final_out: str | None = None) -> list[Stage]:
    """Run fusion to fixpoint; returns a new stage list."""
    stages = list(stages)
    final_out = final_out or (stages[-1].out if stages else None)
    changed = True
    while changed:
        changed = False
        uses = _use_counts(stages)
        producer = {st.out: i for i, st in enumerate(stages)}
        for ci, cons in enumerate(stages):
            # --- rule 1: FP -> FP -------------------------------------------------
            if isinstance(cons, FullyParallel) and cons.elementwise and cons.inputs:
                pi = producer.get(cons.inputs[0])
                if pi is not None and isinstance(stages[pi], FullyParallel):
                    prod = stages[pi]
                    if uses.get(prod.out, 0) == 1 and prod.out != final_out:
                        fused = compose_fp(prod, cons)
                        stages[ci] = fused
                        del stages[pi]
                        changed = True
                        break
            # --- rule 2: FP -> GP.values -----------------------------------------
            if isinstance(cons, GroupParallel) and cons.value_inputs:
                pi = producer.get(cons.value_inputs[0])
                if (pi is not None and isinstance(stages[pi], FullyParallel)
                        and len(cons.value_inputs) == 1
                        and getattr(cons, "_identity_values", True)
                        and uses.get(cons.value_inputs[0], 0) == 1
                        and cons.value_inputs[0] != final_out):
                    prod = stages[pi]
                    p_fn, p_nin = prod.fn, len(prod.inputs)

                    def value_fn(ctx: Ctx, g, *blocks, _fn=p_fn, _n=p_nin):
                        return _fn(Ctx(out_idx=g, starts=ctx.starts[:_n]), *blocks[:_n])

                    new = dataclasses.replace(
                        cons, value_inputs=prod.inputs, value_specs=prod.specs,
                        value_fn=value_fn, name=f"{prod.name}>{cons.name}")
                    new._identity_values = False  # type: ignore[attr-defined]
                    stages[ci] = new
                    del stages[pi]
                    changed = True
                    break
            # --- rules 3/4: GP|NP -> FP ------------------------------------------
            if isinstance(cons, FullyParallel) and cons.elementwise and cons.inputs:
                pi = producer.get(cons.inputs[0])
                if pi is not None and isinstance(stages[pi], (GroupParallel, NonParallel)):
                    prod = stages[pi]
                    if (uses.get(prod.out, 0) == 1 and prod.out != final_out
                            and len(cons.inputs) == 1):  # extra inputs need VMEM plumbing
                        c_fn = cons.fn
                        if isinstance(prod, GroupParallel):
                            old_map = prod.map_fn

                            def map_fn(ctx, gval, pos, g, *extras, _old=old_map, _c=c_fn):
                                mid = _old(ctx, gval, pos, g, *extras)
                                return _c(Ctx(out_idx=ctx.out_idx, starts=(None,)), mid)

                            new = dataclasses.replace(
                                prod, map_fn=map_fn, out=cons.out, n_out=cons.n_out,
                                out_dtype=cons.out_dtype,
                                name=f"{prod.name}>{cons.name}")
                            new._identity_values = getattr(prod, "_identity_values", True)  # type: ignore[attr-defined]
                        else:
                            old_map = prod.out_map

                            def out_map(ctx, syms, _old=old_map, _c=c_fn):
                                mid = syms if _old is None else _old(ctx, syms)
                                return _c(Ctx(out_idx=ctx.out_idx, starts=(None,)), mid)

                            new = dataclasses.replace(
                                prod, out_map=out_map, out=cons.out, n_out=cons.n_out,
                                out_dtype=cons.out_dtype,
                                name=f"{prod.name}>{cons.name}")
                        stages[ci] = new
                        del stages[pi]
                        changed = True
                        break
            # --- rule 6: FP -> positional operator / Reduce ----------------------
            if (getattr(cons, "_positional_inputs", False)
                    and isinstance(cons, (FullyParallel, Reduce))):
                done = False
                for j, nm in enumerate(cons.inputs):
                    if cons.specs[j].kind != "tile":
                        continue   # "full" operands / "row" residents stay as-is
                    pi = producer.get(nm)
                    if pi is None or pi == ci:
                        continue
                    prod = stages[pi]
                    if (isinstance(prod, FullyParallel)
                            and uses.get(prod.out, 0) == 1
                            and prod.out != final_out):
                        stages[ci] = compose_positional(prod, cons, j)
                        del stages[pi]
                        changed = True
                        done = True
                        break
                if done:
                    break
            # --- rule 5: FP -> Aux -----------------------------------------------
            if isinstance(cons, Aux) and cons.inputs:
                pi = producer.get(cons.inputs[0])
                if pi is not None and isinstance(stages[pi], FullyParallel):
                    prod = stages[pi]
                    if uses.get(prod.out, 0) == 1 and prod.out != final_out:
                        # only the Aux's primary input is produced; trailing inputs
                        # (e.g. lifted meta operands like delta's base) pass through
                        a_fn, p_stage = cons.fn, prod

                        def aux_fn(*bufs, _a=a_fn, _p=p_stage,
                                   _n=len(prod.inputs)):
                            mid = _p.run_jnp(dict(zip(_p.inputs, bufs[:_n])))
                            return _a(mid, *bufs[_n:])

                        new = dataclasses.replace(
                            cons, fn=aux_fn, inputs=prod.inputs + cons.inputs[1:],
                            name=f"{prod.name}>{cons.name}")
                        stages[ci] = new
                        del stages[pi]
                        changed = True
                        break
        # (loop restarts after each rewrite: indices shifted)
    return stages


def fuse_graph(graph: "ir.DecodeGraph") -> "ir.DecodeGraph":
    """Rewrite a DecodeGraph through the fusion pass.

    Returns a new graph; the signature gains a ``+fused`` marker so fused and unfused
    programs never share a ProgramCache slot.
    """
    import dataclasses as _dc

    if graph.fused:
        return graph
    fused = fuse(list(graph.stages), final_out=graph.out)
    return _dc.replace(graph, stages=fused, fused=True,
                       signature=graph.signature + "+fused")


def kernel_count(stages: Sequence[Stage]) -> int:
    """Number of device kernels a stage list launches (Aux ops count: they
    materialize)."""
    return len(stages)


def hbm_traffic_bytes(stages: Sequence[Stage], bufs: dict[str, "object"]) -> int:
    """Eq.-2-style traffic model: every stage reads its inputs and writes its output
    once at HBM.  Used by the fusion ablation benchmark.

    Fused-operator graphs are priced correctly by construction: a terminal
    ``Reduce`` writes ``n_out`` accumulator lanes (a few scalars), not the
    elided materialized column, so a fully fused scan-filter-aggregate costs
    leaf reads + the aggregate write.  Resident ("row") inputs are charged at
    their decoded size when present in ``bufs``."""
    import numpy as np

    sizes = {k: int(getattr(v, "nbytes", 0)) for k, v in bufs.items()}
    total = 0
    for st in stages:
        if isinstance(st, FullyParallel):
            ins = st.inputs
        elif isinstance(st, GroupParallel):
            ins = (st.presum,) + st.value_inputs
        elif isinstance(st, NonParallel):
            ins = (st.streams, st.states)
        else:
            ins = getattr(st, "inputs", ())
        total += sum(sizes.get(k, 0) for k in ins)
        out_bytes = st.n_out * np.dtype(st.out_dtype).itemsize
        sizes[st.out] = out_bytes
        total += out_bytes
    return total
