"""The three ZipFlow parallel patterns (paper §3.1) as a small stage IR.

A decompression *plan* lowers to a list of stages over named buffers:

  * ``FullyParallel`` -- out[i] = fn(i, inputs...), no cross-element dependency.
  * ``GroupParallel`` -- variable-sized groups expand 1->N; out[i] is produced from the
    group g owning position i and the within-group offset pos = i - presum[g].
  * ``NonParallel``   -- chunked serial decode (ANS): lanes decode independent chunks in
    lockstep; see ``repro.algos.ans``.
  * ``Aux``           -- whole-array auxiliary ops (cumsum, exception scatter), the
    paper's "PyTorch out-of-the-box operations" escape hatch (§3.2, Fig. 7).

Each stage can be executed by three backends (``repro.core.compiler``): pure-jnp
(reference), Pallas TPU kernels (production; interpret=True on CPU), and an unfused
"baseline" emulating a fixed-schedule library (the nvCOMP role in the paper).

The per-element functions (``fn``, ``map_fn``) are jnp-traceable closures over *vectors*
of elements, so the very same closure is inlined into Pallas kernel bodies by the fusion
pass -- this is the TPU analogue of the paper's kernel fusion (§3.2, Fig. 7(c)).

Data-dependent scalar metadata (bitpack ``bit_width``/``base``, delta ``base``) is NOT
closed over: it arrives as extra (1,)-shaped *operand* inputs listed in ``inputs`` with
``BufSpec("full")``, so one traced program serves every blob that shares the structure
(see ``repro.core.ir.MetaSpec``).  Each stage also declares its **chunkability** --
which output boundaries it can be split at -- which the streaming executor uses to
decide between per-chunk decode launches and one whole-column launch.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import jax.numpy as jnp
import numpy as np


# --- chunkability levels (what output boundaries a stage can be split at) ---
# FullyParallel stages evaluate out[i] independently, so any element boundary works;
# GroupParallel can only split where whole groups do (data-dependent boundaries);
# NonParallel is serial *within* an ANS chunk but its chunks are mutually
# independent, so it also splits at group (= ANS chunk) boundaries; Aux
# (whole-array ops) only decodes whole buffers.  The streaming executor uses these
# declarations (via ``ir.element_chunk_layout`` / ``ir.group_chunk_layout``) to
# pick a per-chunk decode path or fall back to one whole-column launch.
CHUNK_ELEMENT = "element"
CHUNK_GROUP = "group"
CHUNK_NONE = "none"

# BufSpec kinds understood by the chunk planners:
#   "tile" -- sliced proportionally to the output tile (num/den ratio);
#   "full" -- whole buffer resident (small metadata, lifted operands);
#   "row"  -- a *decoded* column resident on device, gathered at the global
#             output row index (fused-query inputs that could not be fused,
#             e.g. an ANS-coded column feeding a group-by key).



@dataclasses.dataclass(frozen=True)
class BufSpec:
    """How an input buffer is tiled relative to the output tile.

    kind="tile": the block covering output range [o0, o1) is input range
                 [o0*num//den, o1*num//den) (+pad guard words); bitpack uses den=32
                 on uint32 words.  kind="full": whole buffer resident in VMEM
                 (small metadata: dictionaries, tables).

    ``num_op`` names a runtime meta operand (a (1,) buffer in the stage's inputs)
    that supplies ``num`` at execution time -- e.g. bitpack's data-dependent
    ``bit_width``.  A dynamic ratio cannot drive static kernel windowing, so the
    Pallas backends keep such buffers whole-resident; host-side chunk planning
    resolves the operand's value per blob and slices exactly.
    """

    kind: str = "tile"  # "tile" | "full" | "row"
    num: int = 1
    den: int = 1
    pad: int = 0        # extra trailing elements fetched (cross-word guard)
    num_op: str = ""    # env name of the runtime operand supplying num ("" = static)


@dataclasses.dataclass
class Ctx:
    """Execution context handed to per-element closures.

    out_idx: global output indices of the elements being produced (int32 vector).
    starts:  global start offset of each input block (0 for the jnp backend, the block
             origin inside Pallas kernels).
    """

    out_idx: jnp.ndarray
    starts: tuple[Any, ...] = ()


class Stage:
    out: str
    n_out: int
    out_dtype: Any
    chunkability = CHUNK_NONE   # overridden per pattern (not a dataclass field)


def primary(ctx: Ctx, block: jnp.ndarray) -> jnp.ndarray:
    """Fetch a stage's primary input for the elements at ``ctx.out_idx``.

    ``starts[0] is None`` means the block is already positionally aligned with
    ``out_idx`` (it is an in-register intermediate from a fused producer); otherwise
    gather at the block-local offsets.  Writing codec closures through this helper is
    what makes every Fully-Parallel stage *gather-capable*, i.e. evaluable at arbitrary
    indices -- the property fusion rule 2 (absorb into Group-Parallel values) relies on.
    """
    s = ctx.starts[0] if ctx.starts else 0
    if s is None:
        return block
    return block[ctx.out_idx - s]


def arg_at(ctx: Ctx, j: int, block: jnp.ndarray) -> jnp.ndarray:
    """``primary`` generalized to input position ``j``: fetch ``block`` at
    ``ctx.out_idx`` honouring its own start offset.  Operator stages
    (``_positional_inputs=True``) read *every* tiled/row input through this, so
    fusion can splice a producer into any position, not just position 0."""
    s = ctx.starts[j] if j < len(ctx.starts) else 0
    if s is None:
        return block
    return block[ctx.out_idx - s]


@dataclasses.dataclass
class FullyParallel(Stage):
    """out[i] = fn(ctx, *blocks);   inputs[k] tiled per specs[k]."""

    fn: Callable[..., jnp.ndarray]
    inputs: tuple[str, ...]
    specs: tuple[BufSpec, ...]
    out: str = "out"
    n_out: int = 0
    out_dtype: Any = jnp.int32
    elementwise: bool = True   # True iff fn reads inputs[0] only at position ctx.out_idx
    name: str = "fp"
    chunkability = CHUNK_ELEMENT   # out[i] independent => split anywhere

    def run_jnp(self, bufs: dict[str, jnp.ndarray]) -> jnp.ndarray:
        ctx = Ctx(out_idx=jnp.arange(self.n_out, dtype=jnp.int32),
                  starts=tuple(0 for _ in self.inputs))
        return self.fn(ctx, *[bufs[k] for k in self.inputs]).astype(self.out_dtype)


@dataclasses.dataclass
class GroupParallel(Stage):
    """Balanced 1->N expansion (paper §4 'Scheduling Group-Parallel for Load Balance').

    out[i]:  g   = searchsorted(presum, i, side='right') - 1
             pos = i - presum[g]
             out[i] = map_fn(ctx, value_fn(g, value-blocks...), pos, g)

    ``presum`` is the inclusive-prefix-sum of group counts with a leading 0
    (len n_groups+1) -- the paper's "one-time data scan".  ``value_fn`` materializes the
    per-group payload; absorbing a preceding Fully-Parallel stage here is exactly the
    paper's Fig. 7(c) fusion of bit-packing into the RLE kernel.
    """

    presum: str
    value_inputs: tuple[str, ...]
    value_specs: tuple[BufSpec, ...]
    # value_fn(ctx, g_idx, *value_blocks) -> per-group payload for group ids g_idx
    value_fn: Callable[..., jnp.ndarray]
    # map_fn(ctx, gval, pos, g, *extra_blocks) -> output elements
    map_fn: Callable[..., jnp.ndarray]
    out: str = "out"
    n_out: int = 0
    out_dtype: Any = jnp.int32
    n_groups: int = 0
    extra_inputs: tuple[str, ...] = ()  # whole-buffer metadata (dictionaries, offsets)
    name: str = "gp"
    # per-group output offsets ([0, c_0, c_0+c_1, ...], len n_groups+1) computed by
    # the ENCODER on the host -- the run/chunk metadata group-boundary chunking
    # plans with (ir.group_chunk_layout).  Host-side planning data only: it is
    # identified like a lifted operand (dtype/shape, never value -- see
    # ir._meta_tokens host_meta handling), so it does not enter program identity,
    # and it never transfers (the device recomputes presum from counts).
    host_group_presum: Any = None
    chunkability = CHUNK_GROUP   # splits only where whole groups do

    def run_jnp(self, bufs: dict[str, jnp.ndarray]) -> jnp.ndarray:
        presum = bufs[self.presum]
        i = jnp.arange(self.n_out, dtype=jnp.int32)
        g = jnp.searchsorted(presum, i, side="right").astype(jnp.int32) - 1
        pos = i - presum[g]
        ctx = Ctx(out_idx=i, starts=tuple(0 for _ in self.value_inputs))
        gval = self.value_fn(ctx, g, *[bufs[k] for k in self.value_inputs])
        extras = [bufs[k] for k in self.extra_inputs]
        return self.map_fn(ctx, gval, pos, g, *extras).astype(self.out_dtype)


@dataclasses.dataclass
class NonParallel(Stage):
    """Chunked serial decode executed lane-lockstep (paper §4 'towards SIMT').

    Specialized to interleaved rANS (the paper's N.P. exemplar).  Buffers:
      streams: (max_words, n_chunks) uint16 striped words (chunk-transposed layout),
      states:  (n_chunks,) uint32 initial decoder states,
      tables:  (sym, freq, cum) alphabet tables, each (4096,) int32.
    Decodes n_chunks * chunk_size symbols; chunk c owns out[c*chunk_size:(c+1)*chunk_size].
    ``out_map`` post-maps decoded symbols (fusion target).
    """

    streams: str
    states: str
    sym_tab: str
    freq_tab: str
    cum_tab: str
    chunk_size: int
    n_chunks: int
    # out_map(ctx, syms) -> output elements; identity by default
    out_map: Callable[..., jnp.ndarray] | None = None
    out: str = "out"
    n_out: int = 0
    out_dtype: Any = jnp.uint8
    name: str = "np"
    # actual (pre-padding) compressed word count per chunk, host planning data
    # emitted by the encoder (per-group compressed-byte offsets = cumsum * 2);
    # identified by dtype/shape only, never transferred.  Recorded for the
    # unpadded-stripe follow-on (ROADMAP) -- today's planner prices the padded
    # stripe that actually transfers, so nothing reads it yet.
    host_group_words: Any = None
    # serial within a chunk, but chunks are independent: splits where whole
    # chunks (= groups) do.  The stripe layout interleaves chunks along axis 1,
    # so a group span is a column slice streams[:, g0:g1].
    chunkability = CHUNK_GROUP

    def run_jnp(self, bufs: dict[str, jnp.ndarray]) -> jnp.ndarray:
        from repro.algos.ans import decode_chunks_jnp  # avoids import cycle

        syms = decode_chunks_jnp(
            bufs[self.streams], bufs[self.states], bufs[self.sym_tab],
            bufs[self.freq_tab], bufs[self.cum_tab], self.chunk_size)
        flat = syms.reshape(-1)[: self.n_out]
        if self.out_map is not None:
            ctx = Ctx(out_idx=jnp.arange(self.n_out, dtype=jnp.int32))
            flat = self.out_map(ctx, flat)
        return flat.astype(self.out_dtype)


@dataclasses.dataclass
class Aux(Stage):
    """Whole-array auxiliary op (cumsum, scatter-patch).  Fusion barrier."""

    fn: Callable[..., jnp.ndarray]
    inputs: tuple[str, ...]
    out: str = "out"
    n_out: int = 0
    out_dtype: Any = jnp.int32
    name: str = "aux"
    chunkability = CHUNK_NONE   # whole-array op (cumsum, scatter) by definition

    def run_jnp(self, bufs: dict[str, jnp.ndarray]) -> jnp.ndarray:
        return self.fn(*[bufs[k] for k in self.inputs]).astype(self.out_dtype)


@dataclasses.dataclass
class Reduce(Stage):
    """Aggregate an item axis into a tiny partial vector (operator fusion).

    ``fn(ctx, *blocks) -> (n_out,)`` computes partial sums over the items at
    ``ctx.out_idx`` (predicated sums, segment-sums); because the reduction is
    additive, partials over any disjoint cover of ``[0, n_in)`` sum to the
    whole -- that is what makes a Reduce element-chunkable along its *item*
    axis even though ``n_out`` is a handful of accumulator lanes, not rows.
    Inputs are read positionally through ``arg_at`` (``_positional_inputs``),
    so fusion can graft whole decode chains into any input slot and the
    decompressed column never materializes at HBM.
    """

    fn: Callable[..., jnp.ndarray]
    inputs: tuple[str, ...]
    specs: tuple[BufSpec, ...]
    n_in: int = 0               # item-axis length (rows, or RLE runs)
    out: str = "agg"
    n_out: int = 0              # accumulator lanes (n_lanes * n_segments)
    out_dtype: Any = jnp.float32
    name: str = "reduce"
    chunkability = CHUNK_ELEMENT    # partials over any item cover sum to whole
    _positional_inputs = True

    def run_jnp(self, bufs: dict[str, jnp.ndarray]) -> jnp.ndarray:
        ctx = Ctx(out_idx=jnp.arange(self.n_in, dtype=jnp.int32),
                  starts=tuple(0 for _ in self.inputs))
        return self.fn(ctx, *[bufs[k] for k in self.inputs]).astype(self.out_dtype)


# --------------------------------------------------------------------------- helpers
def compose_fp(first: FullyParallel, second: FullyParallel) -> FullyParallel:
    """Fuse two Fully-Parallel stages: second(first(x)).  Requires the second stage to
    be elementwise in its primary input (out[i] reads first_out[i])."""
    assert second.elementwise, "cannot compose into a non-elementwise consumer"
    assert second.inputs[0] == first.out
    f_fn, s_fn = first.fn, second.fn
    n_first = len(first.inputs)

    def fused(ctx: Ctx, *blocks):
        f_ctx = Ctx(out_idx=ctx.out_idx, starts=ctx.starts[:n_first])
        mid = f_fn(f_ctx, *blocks[:n_first]).astype(first.out_dtype)
        # None start: `mid` is an in-register intermediate positionally aligned with
        # out_idx -- the consumer must not gather it by global index
        s_ctx = Ctx(out_idx=ctx.out_idx, starts=(None,) + ctx.starts[n_first:])
        return s_fn(s_ctx, mid, *blocks[n_first:])

    return FullyParallel(
        fn=fused,
        inputs=first.inputs + second.inputs[1:],
        specs=first.specs + second.specs[1:],
        out=second.out, n_out=second.n_out, out_dtype=second.out_dtype,
        elementwise=first.elementwise,
        name=f"{first.name}+{second.name}")


def compose_positional(first: FullyParallel, cons: Stage, j: int) -> Stage:
    """Fuse a Fully-Parallel producer into input position ``j`` of a consumer
    whose closure reads every input through ``arg_at`` (``_positional_inputs``:
    operator predicate/projection stages and ``Reduce``).  The producer's
    gather-capable closure evaluates at the consumer's indices; its result is
    handed over in-register with a ``None`` start (positionally aligned)."""
    n_first = len(first.inputs)
    f_fn, c_fn = first.fn, cons.fn

    def fused(ctx: Ctx, *blocks):
        f_ctx = Ctx(out_idx=ctx.out_idx, starts=ctx.starts[j:j + n_first])
        mid = f_fn(f_ctx, *blocks[j:j + n_first]).astype(first.out_dtype)
        s_starts = ctx.starts[:j] + (None,) + ctx.starts[j + n_first:]
        return c_fn(Ctx(out_idx=ctx.out_idx, starts=s_starts),
                    *blocks[:j], mid, *blocks[j + n_first:])

    new = dataclasses.replace(
        cons, fn=fused,
        inputs=cons.inputs[:j] + first.inputs + cons.inputs[j + 1:],
        specs=cons.specs[:j] + first.specs + cons.specs[j + 1:],
        name=f"{first.name}>{cons.name}")
    new._positional_inputs = True  # type: ignore[attr-defined]
    return new


def identity_value_fn(ctx: Ctx, g: jnp.ndarray, values: jnp.ndarray) -> jnp.ndarray:
    start = ctx.starts[0] if ctx.starts else 0
    return values[g - start] if not isinstance(start, int) or start != 0 else values[g]


def run_stages_jnp(stages: Sequence[Stage], bufs: dict[str, jnp.ndarray]) -> jnp.ndarray:
    """Reference executor: run every stage with the pure-jnp backend."""
    bufs = dict(bufs)
    out = None
    for st in stages:
        out = st.run_jnp(bufs)
        bufs[st.out] = out
    return out
