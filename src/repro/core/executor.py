"""Streaming decode executor: plan-driven chunked transfer + per-chunk or
batched decode.

This is the runtime half of the compile pipeline (``plan.lower_graph`` ->
``fusion.fuse_graph`` -> ``ProgramCache``).  ``run`` *consumes* an
``ExecutionPlan`` (``core/planner.py``): issue order, per-column chunk size,
decode mode and in-flight window all come from the plan -- the executor contains
no scheduling heuristics of its own.  When no plan is passed, one is built from
the constructor defaults through the same planner (so the legacy knobs
``chunk_bytes`` / ``chunk_decode`` / ``prefetch_chunks`` survive only as inputs
to auto-planning).  Given a plan over a set of compressed blobs it

  1. splits every leaf buffer into the plan's per-column chunk sizes,
  2. issues transfers in plan order as async ``jax.device_put`` with the plan's
     bounded in-flight window (double buffering: chunks k+1..k+w are in flight
     while chunk k is consumed),
  3. decodes each column through its cached Program in the plan's decode mode:

     * **per-chunk** (element-chunkable graphs): every transferred chunk is
       decoded in its own launch while later chunks are still in flight --
       transfer/decode overlap *within* a column, the configuration the fig19
       ``Zc`` model describes.  Chunk slices are coordinated through the
       graph's ``ChunkLayout`` so outputs concatenate to exactly the one-shot
       result.  Group-chunkable graphs (RLE/DeltaStride expansions, ANS chunk
       grids -- ``ir.group_chunk_layout``) stream at group boundaries instead:
       a one-shot prologue decodes the whole-resident metadata (presums, nested
       children), then each transferred span of whole groups decodes in its own
       body/tail launch, outputs concatenated on device.  Graphs with neither
       layout (e.g. delta's cumsum) fall back to one whole-column launch.
     * **whole-column / batched-by-signature**: chunks reassemble on device and
       the column decodes in one launch; adjacent plan-marked "batched" columns
       sharing one Program stack into ONE launch (``Program.batched``, vmap over
       the leading axis -- lifted meta operands stack and vmap along), and

  4. feeds measured per-column (transfer_s, decode_s) actuals back into the
     ``CostModel`` so the next plan is built from calibrated predictions
     instead of re-measuring every column.

Chunked, batched and per-chunk execution are all bitwise-identical to the one-shot
path: chunks concatenate back to the exact source bytes, vmap runs the same program
per lane, and per-chunk launches evaluate the same stage closures at the same global
indices over exact slices.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import costmodel, planner as planner_mod
from repro.core import plan as plan_mod, scheduler
from repro.core.compiler import DEFAULT_CACHE, Program, ProgramCache
from repro.core.costmodel import CostModel, profile_from
from repro.core.geometry import DEFAULT_CHIP
from repro.core.ir import DecodeGraph, element_chunk_layout, group_chunk_layout
from repro.core.planner import ExecutionPlan


# ragged ANS stripes: per-span row caps are rounded up to this many words so
# the number of distinct stripe shapes (= jit retraces of the span programs)
# stays bounded while still skipping most of the max_words padding
ROW_CAP_QUANTUM = 64


def split_chunks(arr: np.ndarray, chunk_bytes: int | None) -> list[np.ndarray]:
    """Split a host buffer into <=chunk_bytes pieces along axis 0 (2-D buffers like
    the ANS stream matrix chunk by rows).  Concatenating the pieces restores the
    buffer exactly, so chunked transfer cannot change decode results.  The piece
    count comes from ``costmodel.rows_per_chunk`` -- the same formula
    ``ColumnProfile.n_transfer_chunks`` predicts with, so plans match execution."""
    if (chunk_bytes is None or arr.ndim == 0 or arr.nbytes <= chunk_bytes
            or arr.shape[0] <= 1):
        return [arr]
    rows = costmodel.rows_per_chunk(arr.shape[0], arr.nbytes, chunk_bytes)
    return [arr[i:i + rows] for i in range(0, arr.shape[0], rows)]


@dataclasses.dataclass(frozen=True)
class ChunkSchedule:
    """Coordinated per-chunk slicing for one column (resolved from the graph's
    chunk layout and the column's actual operand values / group metadata).

    ``kind="element"`` is the Fully-Parallel path: every chunk covers a fixed
    element range and ``out_sizes == pad_sizes``.  ``kind="group"`` is the
    group-boundary path: chunk k decodes the ``g_sizes[k]`` whole groups from
    ``g_starts[k]`` in its own launch, producing ``pad_sizes[k]`` elements of
    which ``out_sizes[k]`` are valid (uneven group sizes pad body launches to
    one shared shape -- ONE body program plus one tail program per structure);
    ``axes`` gives per-leaf slice axes (the ANS stripe slices columns).
    """

    out_starts: tuple[int, ...]
    out_sizes: tuple[int, ...]
    slices: dict[str, list[tuple[int, int]]]   # tile leaf -> per-chunk [lo, hi)
    whole: tuple[str, ...]                     # transferred once, shared by chunks
    kind: str = "element"                      # "element" | "group"
    g_starts: tuple[int, ...] = ()             # group path: span start groups
    g_sizes: tuple[int, ...] = ()              # group path: groups per span
    pad_sizes: tuple[int, ...] = ()            # group path: padded launch elems
    axes: dict[str, int] = dataclasses.field(default_factory=dict)
    # unpadded ANS stripes: per-chunk row caps for axis-1 leaves -- span k of
    # the stripe transfers only streams[:row_caps[leaf][k], g0:g1] (the words
    # its groups actually consume, quantized) instead of all max_words rows
    row_caps: dict[str, tuple[int, ...]] = dataclasses.field(
        default_factory=dict)
    # host-sourced whole buffers (layout.host_push): staged alongside the
    # whole leaves but materialized from encoder metadata, not operands
    host_push: dict[str, np.ndarray] = dataclasses.field(default_factory=dict)

    @property
    def n_chunks(self) -> int:
        return len(self.out_starts)

    def piece(self, arr: np.ndarray, leaf: str, k: int) -> np.ndarray:
        """Host slice of ``leaf`` for chunk ``k`` (row-capped for ragged
        axis-1 stripes)."""
        lo, hi = self.slices[leaf][k]
        if self.axes.get(leaf, 0) == 0:
            return arr[lo:hi]
        caps = self.row_caps.get(leaf)
        rows = int(arr.shape[0]) if caps is None else caps[k]
        return np.ascontiguousarray(arr[:rows, lo:hi])


# --------------------------------------------------------- dispatch engine
#
# Transfer issuance and decode dispatch are split into two roles:
#
#   * an *issuer* owns the ordered transfer-item list of one host->device
#     link and commits ``jax.device_put`` for items the dispatcher has
#     allowed (the plan's in-flight window, expressed as an item watermark)
#     subject to the shared host-staging budget;
#   * the *decode driver* is a generator (``_decode_leg`` and the per-chunk
#     runners) that yields ``("need", n)`` before it touches staged items
#     < n, and launches span/chunk programs as soon as those commits land.
#
# ``_InlineIssuer`` reproduces the historical single-threaded behavior
# exactly (``advance`` == the old ``issue_until``); ``_WorkerIssuer`` moves
# the puts onto a per-link worker thread so H2D copies for chunks k+1..k+w
# genuinely overlap chunk k's decode launch.  Workers NEVER trace: they only
# call ``jax.device_put``; every ``ProgramCache.get_*`` (and therefore every
# jit trace/compile) happens on the dispatcher thread driving the generator.

# one transfer item: (column name for issue-time accounting, destination
# staging list, slot index, host piece)
_TransferItem = tuple  # (str, list, int, np.ndarray)


class _InlineIssuer:
    """Synchronous issuer: ``advance(target)`` commits items < target on the
    calling thread -- byte-for-byte the legacy ``issue_until`` closure."""

    def __init__(self, items: list, device, issue_s: dict[str, float]):
        self._items = items
        self._device = device
        self.issue_s = issue_s
        self.total = len(items)
        self.committed = 0

    def advance(self, target: int) -> None:
        while self.committed < min(target, self.total):
            name, dest, i, piece = self._items[self.committed]
            t = time.perf_counter()
            dest[i] = jax.device_put(piece, self._device)   # async H2D
            self.issue_s[name] = (self.issue_s.get(name, 0.0)
                                  + time.perf_counter() - t)
            self.committed += 1

    def wait(self, target: int) -> None:      # advance already committed them
        pass

    def consumed(self, upto: int) -> None:    # no staging budget to release
        pass

    def close(self) -> None:
        pass


class _WorkerIssuer:
    """One transfer-worker thread for one host->device link.

    The dispatcher advances an item watermark (``advance``, the plan's
    in-flight window); the worker commits ``device_put`` for allowed items
    strictly in list order, acquiring one shared host-staging slot per
    chunk-holding chunk (``acq``/``rel`` flags mark the first/last item of
    each per-chunk-decode chunk, mirroring ``simulate_stream_multi``'s
    budget unit).  The dispatcher releases slots as it consumes decoded
    chunks (``consumed``).  Worker exceptions surface on the dispatcher's
    next ``wait``/``check_error``; the worker never traces (puts only).
    """

    def __init__(self, items: list, device, issue_s: dict[str, float],
                 acq: Sequence[bool] | None = None,
                 rel: Sequence[bool] | None = None,
                 budget: threading.BoundedSemaphore | None = None,
                 cv: threading.Condition | None = None,
                 name: str = "zipflow-xfer", sync: bool = False):
        self._items = items
        self._device = device
        self._sync = sync
        self.issue_s = issue_s
        self.total = len(items)
        self.committed = 0
        self._allowed = 0
        self._acq = acq
        self._rel = rel
        self._budget = budget
        self._rel_ptr = 0
        self._stop = False
        self.error: BaseException | None = None
        self._cv = cv if cv is not None else threading.Condition()
        self._thread = threading.Thread(target=self._work, name=name,
                                        daemon=True)
        self._thread.start()

    # ----- worker side
    def _work(self) -> None:
        try:
            i = 0
            while i < self.total:
                with self._cv:
                    while self._allowed <= i and not self._stop:
                        self._cv.wait()
                    if self._stop:
                        return
                    hi = min(self._allowed, self.total)
                while i < hi:
                    name, dest, slot, piece = self._items[i]
                    if self._budget is not None and self._acq is not None \
                            and self._acq[i]:
                        # shared pinned-host-staging budget: one slot per
                        # transferred-but-undecoded chunk across ALL links
                        while not self._budget.acquire(timeout=0.1):
                            if self._stop:
                                return
                    t = time.perf_counter()
                    buf = jax.device_put(piece, self._device)  # async H2D
                    if self._sync:
                        # D2D copy legs block here so issue_s records the
                        # true copy duration (this worker has nothing else
                        # to do; the dispatcher keeps launching decodes)
                        jax.block_until_ready(buf)
                    self.issue_s[name] = (self.issue_s.get(name, 0.0)
                                          + time.perf_counter() - t)
                    dest[slot] = buf
                    with self._cv:
                        self.committed = i + 1
                        self._cv.notify_all()
                    i += 1
        except BaseException as e:          # surfaced at the next wait()
            with self._cv:
                self.error = e
                self._cv.notify_all()

    # ----- dispatcher side
    def advance(self, target: int) -> None:
        target = min(target, self.total)
        with self._cv:
            if target > self._allowed:
                self._allowed = target
                self._cv.notify_all()

    def wait(self, target: int) -> None:
        """Block until items < target are committed (or raise the worker's
        exception)."""
        target = min(target, self.total)
        with self._cv:
            while self.committed < target:
                if self.error is not None:
                    raise RuntimeError(
                        "transfer worker failed") from self.error
                self._cv.wait(timeout=0.5)

    def check_error(self) -> None:
        if self.error is not None:
            raise RuntimeError("transfer worker failed") from self.error

    def consumed(self, upto: int) -> None:
        """Dispatcher consumed items < upto: release their chunks' staging
        slots (called from the one dispatcher thread only)."""
        if self._budget is None or self._rel is None:
            return
        upto = min(upto, self.total)
        while self._rel_ptr < upto:
            if self._rel[self._rel_ptr]:
                self._budget.release()
            self._rel_ptr += 1

    def close(self) -> None:
        with self._cv:
            self._stop = True
            self._cv.notify_all()
        self._thread.join(timeout=30.0)


class DispatchEngine:
    """Async dispatch engine: per-link transfer workers + ONE decode
    dispatcher.

    ``issuer`` spawns a ``_WorkerIssuer`` bound to this engine's shared
    condition (so any link's commit wakes the dispatcher) and shared
    host-staging budget (``LinkTopology.host_window``).  ``drive`` round-
    robins a set of decode-driver generators -- one per device leg -- on the
    calling thread: a leg is resumed as soon as its pending ``("need", n)``
    is satisfied, so decode launches for device A interleave with device B's
    while every link's worker keeps its H2D stream busy.  All tracing /
    compilation happens here, on the dispatcher thread; workers only
    ``device_put``.  Liveness: a leg's needs are satisfied in item order and
    staging slots are released as chunks are consumed, so every held slot
    belongs to a chunk some leg will consume without further budget -- the
    any-progress loop cannot deadlock.
    """

    def __init__(self, host_window: int | None = None):
        self._cv = threading.Condition()
        self._budget = (None if host_window is None
                        else threading.BoundedSemaphore(max(1, host_window)))
        self._issuers: list[_WorkerIssuer] = []

    def issuer(self, items: list, device, issue_s: dict[str, float],
               acq: Sequence[bool] | None = None,
               rel: Sequence[bool] | None = None,
               name: str = "zipflow-xfer",
               sync: bool = False) -> _WorkerIssuer:
        iss = _WorkerIssuer(items, device, issue_s, acq=acq, rel=rel,
                            budget=self._budget, cv=self._cv, name=name,
                            sync=sync)
        self._issuers.append(iss)
        return iss

    def drive(self, tasks: dict) -> dict:
        """``tasks``: key -> (generator, issuer).  Returns key -> generator
        return value.  Must be called from the thread that owns tracing."""
        results: dict = {}
        live = dict(tasks)
        need: dict = {k: None for k in tasks}      # None = not yet started
        while live:
            progressed = False
            for key in list(live):
                gen, iss = live[key]
                n = need[key]
                if n is not None and iss.committed < min(n, iss.total):
                    iss.check_error()
                    continue
                try:
                    # engine mode reports no per-wait residual: the wait
                    # happened while OTHER legs were being dispatched
                    _, need[key] = gen.send(None if n is None else 0.0)
                except StopIteration as stop:
                    results[key] = stop.value
                    del live[key]
                progressed = True
            if live and not progressed:
                with self._cv:
                    any_err = any(i.error is not None for _, i in live.values())
                    if not any_err and all(
                            i.committed < min(need[k], i.total)
                            for k, (_, i) in live.items()):
                        self._cv.wait(timeout=0.05)
        return results

    def close(self) -> None:
        for iss in self._issuers:
            iss.close()


def _drive_seq(gen, issuer):
    """Drive ONE decode-leg generator to completion on the calling thread,
    timing each transfer wait and feeding it back as the generator's residual.
    With an ``_InlineIssuer`` (whose ``wait`` is a no-op because ``advance``
    already committed synchronously) this reproduces the legacy sequential
    executor exactly."""
    wait_s = None
    while True:
        try:
            _, n = gen.send(wait_s)
        except StopIteration as stop:
            return stop.value
        t0 = time.perf_counter()
        issuer.wait(n)
        wait_s = time.perf_counter() - t0


@dataclasses.dataclass
class _StagedLeg:
    """Host-staged transfer state for one device leg (one ``run`` call or
    the whole-column part of one mesh device): the ordered decode units plus
    the GLOBAL transfer-item indices each unit needs committed."""

    decisions: dict
    scheds: dict[str, ChunkSchedule | None]
    staged: dict[str, dict[str, list]]
    col_end: dict[str, int]
    chunk_ends: dict[str, list[int]]
    units: list
    window: int


@dataclasses.dataclass
class ColumnExec:
    """Execution record for one decoded column."""

    name: str
    array: jnp.ndarray
    transfer_s: float
    decode_s: float
    compressed_bytes: int
    plain_bytes: int
    n_chunks: int
    signature: str
    batched_with: tuple[str, ...] = ()   # same-signature columns sharing the launch
    decode_launches: int = 1             # >1 iff the per-chunk path ran
    chunk_decoded: bool = False
    shard_devices: tuple[int, ...] = ()  # mesh path: device id per group shard


@dataclasses.dataclass
class QueryExec:
    """Execution record for one decode-fused query (late materialization).

    ``traffic_bytes`` is the fused graph's modeled HBM traffic (leaf reads +
    the ``n_out`` accumulator lanes); ``prefuse_traffic_bytes`` prices the same
    stage list before operator fusion, where every decoded column and mask
    round-trips HBM -- the delta is what fusion removed."""

    name: str
    result: jnp.ndarray
    acc: jnp.ndarray                  # raw partial-aggregate lanes
    transfer_s: float
    decode_s: float
    n_chunks: int
    decode_launches: int
    selectivity: float
    compressed_bytes: int
    plain_bytes: int                  # decoded bytes that were NEVER written
    traffic_bytes: int
    prefuse_traffic_bytes: int
    resident: dict[str, ColumnExec] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class MeshRunResult:
    """Execution record for one ``run_sharded`` over a device mesh.

    ``columns`` maps every requested column to its record (sharded columns
    appear once, assembled); ``per_device`` lists the plan items each logical
    device executed and ``device_launches`` its decode-launch count.
    ``d2d_copies`` records each executed redistribution leg as
    ``item -> (src physical device, dst physical device, measured copy
    seconds)`` -- empty when the plan carried no redistribution."""

    columns: dict[str, ColumnExec]
    per_device: dict[int, tuple[str, ...]]
    device_launches: dict[int, int]
    plan: "planner_mod.MeshExecutionPlan"
    d2d_copies: dict[str, tuple[int, int, float]] = dataclasses.field(
        default_factory=dict)

    def __getitem__(self, name: str) -> ColumnExec:
        return self.columns[name]


class StreamingExecutor:
    """Plan-driven chunked, cached, batched/per-chunk decode engine.

    ``chunk_bytes`` (an int, None for whole-blob, or "auto" for per-column
    sizing), ``chunk_decode`` and ``prefetch_chunks`` are *planner defaults*:
    they parameterize the ``ExecutionPlan`` built when ``run`` is called
    without one; a passed plan is authoritative.
    """

    def __init__(self, backend: str = "jnp", fuse: bool = True,
                 chunk_bytes: int | None | str = 1 << 20, pipeline: bool = True,
                 batch_columns: bool = True, prefetch_chunks: int | None = None,
                 chunk_decode: bool = False,
                 chip: str = DEFAULT_CHIP, cache: ProgramCache | None = None,
                 policy: str = "chunk-johnson",
                 cost_model: CostModel | None = None,
                 async_dispatch: bool = False):
        self.backend = backend
        self.fuse = fuse
        self.chunk_bytes = chunk_bytes
        self.pipeline = pipeline
        # True routes single-device runs through the DispatchEngine (transfer
        # worker thread + decode dispatcher) by default; run(async_dispatch=..)
        # overrides per call.  Mesh runs overlap devices regardless (see
        # run_sharded(concurrent=...)).
        self.async_dispatch = async_dispatch
        self.batch_columns = batch_columns
        self.prefetch_chunks = (None if prefetch_chunks is None
                                else max(1, prefetch_chunks))
        self.chunk_decode = chunk_decode
        self.chip = chip
        self.cache = cache if cache is not None else DEFAULT_CACHE
        self.policy = policy
        self.cost_model = cost_model or CostModel(chip=chip)
        self._encoded: dict[str, plan_mod.Encoded] = {}
        self._graphs: dict[str, DecodeGraph] = {}
        self._programs: dict[str, Program] = {}
        self._chunk_counts: dict[tuple[str, int | None], int] = {}
        self._schedules: dict[tuple[str, int | None], ChunkSchedule | None] = {}
        # fused-query row-axis schedules + traffic accounting, keyed by the
        # fused graph's signature (which folds in the query digest and every
        # column's structure) -- warm run_query calls skip re-deriving both
        self._query_schedules: dict[tuple, tuple] = {}
        self._query_traffic: dict[str, tuple[int, int]] = {}
        # measured (transfer_s, decode_s) per column from the latest run --
        # an ALIAS of the cost model's store (one source of truth)
        self.timings: dict[str, tuple[float, float]] = self.cost_model.measured

    @property
    def _fixed_chunk_bytes(self) -> int | None:
        """Constructor chunk size as an int/None ("auto" falls back to the
        default fixed size for legacy single-size paths)."""
        cb = self.chunk_bytes
        return planner_mod.DEFAULT_CHUNK_BYTES if isinstance(cb, str) else cb

    # ------------------------------------------------------------------ compile
    def compile(self, name: str, enc: plan_mod.Encoded) -> Program:
        """Register a blob and return its (cache-shared) Program."""
        from repro.core.compiler import compile_blob

        self._encoded[name] = enc
        # re-registering a name invalidates anything derived from the old blob
        for store in (self._chunk_counts, self._schedules):
            for key in [k for k in store if k[0] == name]:
                store.pop(key)
        self.cost_model.forget(name)    # drops profile + measured timings
        prog = compile_blob(enc, backend=self.backend, fuse=self.fuse,
                            chip=self.chip, cache=self.cache)
        self._graphs[name] = prog.graph
        self._programs[name] = prog
        self.cost_model.register(profile_from(name, enc, prog.graph))
        return prog

    def column_profile(self, name: str):
        """Planner-facing profile of a registered column."""
        if name not in self.cost_model.profiles:
            self.cost_model.register(
                profile_from(name, self._encoded[name], self._graphs[name]))
        return self.cost_model.profiles[name]

    def program(self, name: str) -> Program:
        return self._programs[name]

    def graph(self, name: str) -> DecodeGraph:
        return self._graphs[name]

    # ----------------------------------------------------------------- schedule
    _DEFAULTS = object()     # sentinel: "use the constructor's chunk config"

    def _n_chunks(self, name: str, chunk_bytes: int | None | object = _DEFAULTS
                  ) -> int:
        """Number of transfer pieces the executor will issue for a column's leaf
        buffers (row-granular) -- the chunk count the Zc model uses.  Lifted meta
        operands ride along as extra scalar puts but are not counted."""
        if chunk_bytes is self._DEFAULTS:
            chunk_bytes = self._fixed_chunk_bytes
        if chunk_bytes is None:
            return 1
        cached = self._chunk_counts.get((name, chunk_bytes))
        if cached is None:
            flat = plan_mod.flat_buffers(self._encoded[name])
            cached = sum(len(split_chunks(np.asarray(v), chunk_bytes))
                         for v in flat.values())
            self._chunk_counts[(name, chunk_bytes)] = cached
        return cached

    def chunk_schedule(self, name: str,
                       chunk_bytes: int | None | object = _DEFAULTS
                       ) -> ChunkSchedule | None:
        """Coordinated per-chunk decode schedule for a column at the given chunk
        size, or None when the graph is not element-chunkable / chunking is off /
        one chunk suffices.  Without an explicit size, the constructor defaults
        gate it (chunk_decode flag + fixed chunk size), preserving the legacy
        probe semantics."""
        if chunk_bytes is self._DEFAULTS:
            if not self.chunk_decode:
                return None
            chunk_bytes = self._fixed_chunk_bytes
        if chunk_bytes is None:
            return None
        key = (name, chunk_bytes)
        if key in self._schedules:
            return self._schedules[key]
        sched = self._build_schedule(name, chunk_bytes)
        self._schedules[key] = sched
        return sched

    def _build_schedule(self, name: str,
                        chunk_bytes: int) -> ChunkSchedule | None:
        graph = self._graphs[name]
        layout = element_chunk_layout(graph)
        if layout is None:
            return self._build_group_schedule(name, chunk_bytes)
        ops = plan_mod.host_operands(self._encoded[name])
        # resolve tile ratios (operand-driven ratios use this column's meta value)
        ratios: dict[str, tuple[int, int]] = {}
        per_elem = 0.0
        for nm, spec in layout.tiled.items():
            num = int(ops[spec.num_op][0]) if spec.num_op else int(spec.num)
            ratios[nm] = (num, int(spec.den))
            per_elem += num / spec.den * np.dtype(ops[nm].dtype).itemsize
        n = int(graph.n_out)
        align = int(layout.align)
        # chunk size targets ~chunk_bytes of *compressed* tile bytes per chunk,
        # rounded to the alignment every boundary must respect -- via the same
        # shared formula ColumnProfile.decode_chunking predicts with
        chunk_elems = costmodel.aligned_chunk_elems(chunk_bytes, per_elem, align)
        if chunk_elems >= n:
            return None                      # degenerate: one chunk = whole column
        out_starts = tuple(range(0, n, chunk_elems))
        out_sizes = tuple(min(chunk_elems, n - s) for s in out_starts)
        slices: dict[str, list[tuple[int, int]]] = {}
        for nm, (num, den) in ratios.items():
            length = int(ops[nm].shape[0])
            per = []
            for s, sz in zip(out_starts, out_sizes):
                lo = (s * num) // den
                # the final chunk takes the remaining rows (incl. guard words);
                # interior boundaries are aligned so (b*num) % den == 0 exactly
                hi = length if s + sz >= n else ((s + sz) * num) // den
                per.append((lo, max(hi, lo + 1)))
            slices[nm] = per
        return ChunkSchedule(out_starts=out_starts, out_sizes=out_sizes,
                             slices=slices, whole=layout.whole)

    def _build_group_schedule(self, name: str, chunk_bytes: int,
                              g_lo: int = 0, g_hi: int | None = None,
                              force: bool = False) -> ChunkSchedule | None:
        """Group-boundary schedule: spans of whole groups sized to ~chunk_bytes
        of streamed group bytes, boundaries snapped to the encoder-emitted
        group-boundary prefix sums -- via the same shared formulas
        (``costmodel.groups_per_chunk`` / ``group_bytes_per_group``) the
        planner's ``ColumnProfile`` predicts with, so planned span counts equal
        executed span counts.

        ``g_lo``/``g_hi`` restrict the schedule to a group range (a mesh
        shard); ``g_starts``/``out_starts`` stay GLOBAL so the cached span
        programs decode shard-local at the right output offsets.  ``force``
        returns a schedule even when one span would cover the range (shards
        always need one, the whole-column path treats that as "don't chunk")."""
        graph = self._graphs[name]
        layout = group_chunk_layout(graph)
        if layout is None:
            return None
        ops = plan_mod.host_operands(self._encoded[name])
        n_groups = int(layout.n_groups)
        g_hi = n_groups if g_hi is None else min(int(g_hi), n_groups)
        g_lo = max(0, int(g_lo))
        span_groups = g_hi - g_lo
        bpg = costmodel.group_bytes_per_group(layout, ops)
        if span_groups < 1 or bpg <= 0 and not force:
            return None
        if n_groups <= 1 and not force:
            return None
        G = costmodel.groups_per_chunk(chunk_bytes, max(bpg, 1e-9),
                                       layout.align_groups)
        if G >= span_groups and not force:
            return None                  # degenerate: one span = whole column
        G = max(1, min(G, span_groups))
        presum = np.asarray(layout.group_presum, dtype=np.int64)
        g_starts = tuple(range(g_lo, g_hi, G))
        g_sizes = tuple(min(G, g_hi - s) for s in g_starts)
        out_starts = tuple(int(presum[s]) for s in g_starts)
        out_sizes = tuple(int(presum[s + z] - presum[s])
                          for s, z in zip(g_starts, g_sizes))
        if min(out_sizes) <= 0:
            return None                  # empty span (defensive; groups are >=1)
        if layout.elems_per_group:
            # uniform groups (ANS chunk grid): launches produce exactly the
            # decoded span, no padding needed
            pad_sizes = tuple(z * layout.elems_per_group for z in g_sizes)
        else:
            body = [sz for sz, z in zip(out_sizes, g_sizes) if z == G]
            body_pad = costmodel.pad_group_elems(max(body)) if body else 0
            pad_sizes = tuple(
                body_pad if z == G else costmodel.pad_group_elems(sz)
                for sz, z in zip(out_sizes, g_sizes))
        slices: dict[str, list[tuple[int, int]]] = {}
        for nm, spec in layout.sliced.items():
            arr = ops[nm]
            axis = layout.axes.get(nm, 0)
            length = int(arr.shape[axis])
            num = int(ops[spec.num_op][0]) if spec.num_op else int(spec.num)
            per = []
            for s, z in zip(g_starts, g_sizes):
                if axis == 1:
                    per.append((s, s + z))          # stripe: exact columns
                    continue
                lo = (s * num) // spec.den
                # the final span takes the remaining rows (incl. guard words);
                # interior boundaries are group-aligned so slices are integral
                if s + z >= n_groups:
                    hi = length
                elif spec.num_op:
                    # dynamic ratios (bitpack words) floor at span starts:
                    # round the end up and keep the cross-word guard the
                    # decode closure's straddle read touches
                    hi = min(length, -(-((s + z) * num) // spec.den) + 1)
                else:
                    hi = ((s + z) * num) // spec.den
                per.append((lo, max(hi, lo + 1)))
            slices[nm] = per
        # unpadded ANS stripes: when the encoder emitted per-chunk word counts,
        # each span only transfers the stripe rows its own groups consume
        # (quantized to ROW_CAP_QUANTUM so span-program retraces stay bounded)
        row_caps: dict[str, tuple[int, ...]] = {}
        gw = self._host_group_words(graph, layout)
        if gw is not None and len(gw) >= g_hi:
            for nm, axis in layout.axes.items():
                if axis != 1 or nm not in layout.sliced:
                    continue
                max_rows = int(np.asarray(ops[nm]).shape[0])
                caps = []
                for s, z in zip(g_starts, g_sizes):
                    need = max(1, int(np.max(gw[s:s + z])))
                    q = -(-need // ROW_CAP_QUANTUM) * ROW_CAP_QUANTUM
                    caps.append(min(max_rows, q))
                row_caps[nm] = tuple(caps)
        return ChunkSchedule(
            out_starts=out_starts, out_sizes=out_sizes, slices=slices,
            whole=layout.whole, kind="group", g_starts=g_starts,
            g_sizes=g_sizes, pad_sizes=pad_sizes, axes=dict(layout.axes),
            row_caps=row_caps,
            host_push=dict(getattr(layout, "host_push", None) or {}))

    @staticmethod
    def _host_group_words(graph: DecodeGraph, layout) -> np.ndarray | None:
        """Encoder-emitted per-chunk compressed word counts for the layout's
        ANS stripe, or None when the stage doesn't carry them."""
        if getattr(layout, "kind", None) != "np":
            return None
        stage = graph.stages[layout.stage_index]
        gw = getattr(stage, "host_group_words", None)
        return None if gw is None else np.asarray(gw)

    def shard_schedule(self, name: str, chunk_bytes: int | None,
                       g_lo: int, g_hi: int) -> ChunkSchedule | None:
        """Group-span schedule restricted to ``[g_lo, g_hi)`` (mesh shards).
        Always returns a schedule for group-chunkable columns (``force=True``:
        a shard needs one even when it fits a single span)."""
        key = (name, chunk_bytes, (int(g_lo), int(g_hi)))
        if key in self._schedules:
            return self._schedules[key]
        cb = (planner_mod.DEFAULT_CHUNK_BYTES if chunk_bytes is None
              else chunk_bytes)
        sched = self._build_group_schedule(name, cb, g_lo=g_lo, g_hi=g_hi,
                                           force=True)
        self._schedules[key] = sched
        return sched

    def issue_order(self, names: Sequence[str] | None = None) -> list[str]:
        """Column issue order from the configured scheduling policy."""
        names = list(self._encoded) if names is None else list(names)
        if not self.pipeline or len(names) <= 1:
            return names
        return list(self.plan(names).order)

    def plan(self, names: Sequence[str] | None = None,
             policy: str | None = None, order: Sequence[str] | None = None,
             chunk_bytes: int | None | str | object = _DEFAULTS,
             chunk_decode: bool | None = None,
             window: int | None = None,
             fused_columns=None) -> ExecutionPlan:
        """Build an ``ExecutionPlan`` for a set of registered columns.

        Defaults come from the constructor knobs; any argument overrides them.
        An explicit ``order`` pins the issue order (decisions still planned);
        ``pipeline=False`` degrades to submission order (FIFO).
        ``fused_columns`` maps columns a pending query could decode-fuse to a
        selectivity estimate (None = learned EWMA) -- see
        ``planner.plan_execution``.
        """
        names = list(self._encoded) if names is None else list(names)
        profiles = {n: self.column_profile(n) for n in names}
        # an explicit policy always wins; pipeline=False only downgrades the
        # constructor DEFAULT to submission order
        if policy is not None:
            pol = policy
        else:
            pol = "fifo" if not self.pipeline else self.policy
        ep = planner_mod.plan_execution(
            profiles, self.cost_model, policy=pol,
            chunk_bytes=(self.chunk_bytes if chunk_bytes is self._DEFAULTS
                         else chunk_bytes),
            chunk_decode=(self.chunk_decode if chunk_decode is None
                          else chunk_decode),
            window=self.prefetch_chunks if window is None else window,
            batch_columns=self.batch_columns, fused_columns=fused_columns)
        if order is not None:
            ep = dataclasses.replace(ep, order=tuple(order), policy="explicit")
        return ep

    # --------------------------------------------------------------------- run
    def run(self, encs: dict[str, plan_mod.Encoded] | None = None,
            order: Sequence[str] | None = None,
            plan: ExecutionPlan | None = None,
            preempt=None, on_ready=None,
            device=None,
            async_dispatch: bool | None = None) -> dict[str, ColumnExec]:
        """Transfer + decode a set of columns per an ExecutionPlan; returns
        per-column records.  Without a plan, one is built from the constructor
        defaults; measured actuals feed back into the cost model either way.

        ``preempt`` (optional, ``() -> None``) is invoked at every safe yield
        point -- between decode units and at per-chunk launch boundaries --
        so a serving layer can interleave urgent work (e.g. a point query's
        nested ``run``) into a long bulk decode without killing it: the
        outer run's staged transfers and launched chunks are all local state,
        so a nested ``run`` on the same executor composes.  ``on_ready``
        (optional, ``(name: str) -> None``) fires as soon as each column's
        output array is materialized (blocked-on) -- per-column completion
        is what per-REQUEST latency is made of when one shared run serves
        many queries' columns.  ``device`` (optional ``jax.Device``) commits
        every staged transfer to that device, so the cached programs execute
        there -- the per-device leg of a mesh plan (``run_sharded``).
        ``async_dispatch`` (None = the constructor knob) routes transfers
        through a ``DispatchEngine`` worker thread so H2D puts overlap decode
        launches; results are bitwise identical to the inline path."""
        if encs is not None:
            for name, enc in encs.items():
                if self._programs.get(name) is None or self._encoded.get(name) is not enc:
                    self.compile(name, enc)
            names = list(encs)
        else:
            names = list(self._encoded)
        if plan is None:
            plan = self.plan(names, order=order)
        elif order is not None:
            plan = dataclasses.replace(plan, order=tuple(order),
                                       policy="explicit")
        missing = [n for n in names if n not in plan.decisions]
        if missing:
            raise ValueError(
                f"plan does not cover requested columns {missing}; it was "
                f"built over {sorted(plan.decisions)} -- re-plan after "
                "registering new columns")
        names_set = set(names)
        order = [n for n in plan.order if n in names_set]
        decisions = plan.decisions

        # host-side staging, in plan order, into ONE ordered transfer-item
        # list (the issuer's queue); decode units plus the global item index
        # each unit needs committed come back as a _StagedLeg the decode-
        # driver generator consumes.
        items: list[_TransferItem] = []
        acq: list[bool] = []
        rel: list[bool] = []
        leg = self._stage_leg(order, decisions, plan.window, items, acq, rel)
        # time spent issuing each column's device_puts: on CPU the copy happens
        # synchronously at issue; on accelerators issue is cheap and the
        # residual wait at the block is the real transfer tail -- transfer_s
        # sums both
        issue_s: dict[str, float] = {}
        use_async = (self.async_dispatch if async_dispatch is None
                     else async_dispatch)
        if not use_async:
            # inline path: puts issue synchronously from this thread at the
            # generator's advance() points -- the legacy sequential executor
            issuer = _InlineIssuer(items, device, issue_s)
            gen = self._decode_leg(leg, issuer, preempt=preempt,
                                   on_ready=on_ready)
            return _drive_seq(gen, issuer)
        engine = DispatchEngine(
            host_window=self.cost_model.topology.host_window)
        try:
            issuer = engine.issuer(items, device, issue_s, acq=acq, rel=rel)
            gen = self._decode_leg(leg, issuer, preempt=preempt,
                                   on_ready=on_ready)
            return engine.drive({0: (gen, issuer)})[0]
        finally:
            engine.close()

    def _stage_leg(self, order: Sequence[str], decisions, window: int,
                   items: list, acq: list, rel: list) -> _StagedLeg:
        """Stage one device leg's columns host-side, APPENDING to the shared
        per-link ``items``/``acq``/``rel`` lists (so a mesh device's whole
        columns and shards share one issuer queue and the recorded indices
        are global).

        Whole-mode columns split every operand row-granularly at the column's
        planned chunk size; per-chunk columns use the coordinated schedule
        (whole-resident buffers first, then chunk 0's slices, chunk 1's, ...).
        ``acq``/``rel`` mark each per-chunk-decode chunk's first/last item --
        the unit at which a transfer worker acquires / the dispatcher releases
        one shared host-staging slot (matching ``simulate_stream_multi``'s
        budget granularity; whole-mode columns hold no slots there either)."""
        scheds: dict[str, ChunkSchedule | None] = {}
        for name in order:
            d = decisions[name]
            scheds[name] = (self.chunk_schedule(name, d.chunk_bytes)
                            if d.decode_mode == planner_mod.CHUNK else None)
        staged: dict[str, dict[str, list]] = {}
        col_end: dict[str, int] = {}
        chunk_ends: dict[str, list[int]] = {}
        for name in order:
            ops = plan_mod.host_operands(self._encoded[name])
            sched = scheds[name]
            cols: dict[str, list] = {}
            staged[name] = cols
            if sched is None:
                for k, v in ops.items():
                    pieces = split_chunks(np.asarray(v),
                                          decisions[name].chunk_bytes)
                    cols[k] = [None] * len(pieces)
                    for i, piece in enumerate(pieces):
                        items.append((name, cols[k], i, piece))
                        acq.append(False)
                        rel.append(False)
            else:
                for k in sched.whole:
                    cols[k] = [None]
                    src = sched.host_push.get(k)
                    items.append((name, cols[k], 0,
                                  np.asarray(ops[k]) if src is None else src))
                    acq.append(False)
                    rel.append(False)
                ends = []
                for i in range(sched.n_chunks):
                    first = len(items)
                    for k in sched.slices:
                        # group-path leaves may slice off axis 0 (ANS stripes
                        # hand each span its own row-capped column block)
                        cols.setdefault(k, [None] * sched.n_chunks)
                        piece = sched.piece(np.asarray(ops[k]), k, i)
                        items.append((name, cols[k], i, piece))
                        acq.append(False)
                        rel.append(False)
                    if len(items) > first:   # one staging slot per chunk
                        acq[first] = True
                        rel[-1] = True
                    ends.append(len(items))
                chunk_ends[name] = ends
            col_end[name] = len(items)

        # decode units.  Per-chunk columns are singleton units (their launches
        # are already split along the chunk axis); *consecutive-in-order*
        # columns the plan marked batched-by-signature decode in a single vmap
        # launch when they share one Program.  Grouping only adjacent columns
        # keeps the transfer/decode overlap: a global group spanning the whole
        # order would force every transfer to finish before the first decode.
        # (Johnson's rule keys on (transfer, decode) times, which are equal
        # for same-signature columns, so they end up adjacent anyway.)
        units: list[tuple[str, Program | None, list[str]]] = []
        for name in order:
            if scheds[name] is not None:
                units.append(("chunk", None, [name]))
                continue
            prog = self._programs[name]
            if (decisions[name].decode_mode == planner_mod.BATCHED
                    and units and units[-1][0] == "whole"
                    and units[-1][1] is prog
                    and decisions[units[-1][2][-1]].decode_mode
                    == planner_mod.BATCHED):
                units[-1][2].append(name)
            else:
                units.append(("whole", prog, [name]))
        return _StagedLeg(decisions=decisions, scheds=scheds, staged=staged,
                          col_end=col_end, chunk_ends=chunk_ends, units=units,
                          window=window)

    def _decode_leg(self, leg: _StagedLeg, issuer, preempt=None,
                    on_ready=None):
        """Decode-driver generator for one staged leg.

        Yields ``("need", n)`` before consuming staged items < n (the driver
        -- ``_drive_seq`` or ``DispatchEngine.drive`` -- resumes it once the
        issuer has committed them, sending back the seconds it waited, 0.0
        when the wait overlapped other legs' dispatch); all tracing and
        decode launches happen on the resuming thread.  Returns the
        per-column ``ColumnExec`` dict."""
        decisions = leg.decisions
        issue_s = issuer.issue_s
        window = leg.window
        results: dict[str, ColumnExec] = {}
        for kind, prog, members in leg.units:
            if preempt is not None and results:
                preempt()                       # unit boundary: safe yield point
            if kind == "chunk":
                name = members[0]
                runner = (self._run_group_chunked
                          if leg.scheds[name].kind == "group"
                          else self._run_chunked)
                results[name] = yield from runner(
                    name, leg.scheds[name], leg.staged[name],
                    leg.chunk_ends[name], issuer, window, preempt=preempt)
                if on_ready is not None:
                    on_ready(name)
                continue
            last_end = max(leg.col_end[m] for m in members)
            issuer.advance(last_end + window)   # keep the link busy ahead of decode
            wait_s = (yield ("need", last_end)) or 0.0
            t0 = time.perf_counter()
            bufs_per_member = []
            for m in members:
                chunks = leg.staged[m]
                bufs = {k: (pieces[0] if len(pieces) == 1
                            else jnp.concatenate(pieces, axis=0))
                        for k, pieces in chunks.items()}
                bufs_per_member.append(bufs)
            for bufs in bufs_per_member:
                jax.block_until_ready(list(bufs.values()))
            t1 = time.perf_counter()
            issuer.consumed(last_end)
            residual_wait = (wait_s + (t1 - t0)) / len(members)
            if len(members) > 1:
                cold = prog.batched_calls == 0
                stacked = {k: jnp.stack([b[k] for b in bufs_per_member])
                           for k in bufs_per_member[0]}
                out = prog.batched(stacked)
                jax.block_until_ready(out)
                t2 = time.perf_counter()
                if cold:      # first call traced+compiled; re-time warm so cached
                    t1 = time.perf_counter()      # timings model decode, not jit
                    jax.block_until_ready(prog.batched(stacked))
                    t2 = time.perf_counter()
                outs = [out[i] for i in range(len(members))]
            else:
                cold = prog.calls == 0
                outs = [prog(bufs_per_member[0])]
                jax.block_until_ready(outs[0])
                t2 = time.perf_counter()
                if cold:
                    t1 = time.perf_counter()
                    jax.block_until_ready(prog(bufs_per_member[0]))
                    t2 = time.perf_counter()
            # members of one unit share a signature => identical buffer shapes and
            # bytes, so the even decode split is exact, not an approximation
            decode_s = (t2 - t1) / len(members)
            siblings = tuple(members) if len(members) > 1 else ()
            for m, arr in zip(members, outs):
                enc = self._encoded[m]
                transfer_s = issue_s.get(m, 0.0) + residual_wait
                # actuals feed the cost model's calibration loop (and, via the
                # aliased timings dict, future plans' measured jobs)
                self.cost_model.observe(m, transfer_s, decode_s)
                results[m] = ColumnExec(
                    name=m, array=arr, transfer_s=transfer_s, decode_s=decode_s,
                    compressed_bytes=enc.compressed_nbytes,
                    plain_bytes=enc.plain_nbytes,
                    n_chunks=self._n_chunks(m, decisions[m].chunk_bytes),
                    signature=self._graphs[m].signature,
                    batched_with=tuple(s for s in siblings if s != m))
                if on_ready is not None:
                    on_ready(m)
        return results

    def _run_chunked(self, name: str, sched: ChunkSchedule,
                     device_col: dict[str, list], ends: list[int],
                     issuer, window: int, preempt=None):
        """Per-chunk decode of one column: launch chunk k's decode while chunks
        k+1..k+w transfer, then concatenate the chunk outputs on device.
        Generator (see ``_decode_leg``): yields ``("need", n)`` per chunk,
        returns the ``ColumnExec``."""
        graph = self._graphs[name]
        K = sched.n_chunks
        residual = 0.0
        dispatch = 0.0
        cold = False
        whole_bufs: dict[str, jnp.ndarray] | None = None
        launches = []     # (ChunkProgram, bufs, out_start) -- kept for warm re-time
        outs = []
        for k in range(K):
            if preempt is not None and k:
                preempt()          # chunk boundary: point queries may cut in
            issuer.advance(ends[k] + window)
            residual += (yield ("need", ends[k])) or 0.0
            t0 = time.perf_counter()
            if whole_bufs is None:     # issued ahead of chunk 0 by construction
                whole_bufs = {nm: device_col[nm][0] for nm in sched.whole}
                jax.block_until_ready(list(whole_bufs.values()))
            pieces = {nm: device_col[nm][k] for nm in sched.slices}
            jax.block_until_ready(list(pieces.values()))
            residual += time.perf_counter() - t0
            prog = self.cache.get_chunk(graph, sched.out_sizes[k])
            cold = cold or prog.calls == 0
            bufs = {**whole_bufs, **pieces}
            start = np.int32(sched.out_starts[k])
            t0 = time.perf_counter()
            outs.append(prog(bufs, start))       # async launch; k+1 still in flight
            dispatch += time.perf_counter() - t0
            issuer.consumed(ends[k])             # chunk k's staging slot frees
            launches.append((prog, bufs, start))
        t0 = time.perf_counter()
        arr = outs[0] if K == 1 else jnp.concatenate(outs)
        jax.block_until_ready(arr)
        dispatch += time.perf_counter() - t0
        if cold:      # first use traced+compiled: re-run warm so cached timings
            t0 = time.perf_counter()              # model decode, not jit
            outs2 = [p(b, s) for p, b, s in launches]
            jax.block_until_ready(outs2[0] if K == 1 else jnp.concatenate(outs2))
            decode_s = time.perf_counter() - t0
        else:
            decode_s = dispatch
        enc = self._encoded[name]
        transfer_s = issuer.issue_s.get(name, 0.0) + residual
        self.cost_model.observe(name, transfer_s, decode_s)
        return ColumnExec(
            name=name, array=arr, transfer_s=transfer_s, decode_s=decode_s,
            compressed_bytes=enc.compressed_nbytes, plain_bytes=enc.plain_nbytes,
            n_chunks=K, signature=graph.signature,
            decode_launches=K, chunk_decoded=True)

    def _run_group_chunked(self, name: str, sched: ChunkSchedule,
                           device_col: dict[str, list], ends: list[int],
                           issuer, window: int, preempt=None,
                           observe: bool = True):
        """Group-boundary streaming decode of one column.

        The prologue (presum auxes, nested child decodes) launches once over
        the whole-resident buffers ahead of span 0; then span k's decode (a
        body or tail GroupChunkProgram over whole groups) launches while spans
        k+1..k+w are still in flight.  Launch outputs are padded to the shared
        body shape, trimmed to each span's true size and concatenated on
        device -- bitwise identical to the whole-column result.  Generator
        (see ``_decode_leg``): yields ``("need", n)`` per span, returns the
        ``ColumnExec``."""
        graph = self._graphs[name]
        K = sched.n_chunks
        residual = 0.0
        dispatch = 0.0
        cold = False
        whole_bufs: dict[str, jnp.ndarray] | None = None
        resident: dict[str, jnp.ndarray] = {}
        pro_prog = self.cache.get_group_prologue(graph)
        launches = []     # (GroupChunkProgram, bufs, args) kept for warm re-time
        outs = []
        for k in range(K):
            if preempt is not None and k:
                preempt()          # span boundary: point queries may cut in
            issuer.advance(ends[k] + window)
            residual += (yield ("need", ends[k])) or 0.0
            t0 = time.perf_counter()
            if whole_bufs is None:     # issued ahead of span 0 by construction
                whole_bufs = {nm: device_col[nm][0] for nm in sched.whole}
                jax.block_until_ready(list(whole_bufs.values()))
            pieces = {nm: device_col[nm][k] for nm in sched.slices}
            jax.block_until_ready(list(pieces.values()))
            residual += time.perf_counter() - t0
            t0 = time.perf_counter()
            if k == 0 and pro_prog is not None:
                cold = cold or pro_prog.calls == 0
                resident = pro_prog(whole_bufs)    # async one-shot prologue
            prog = self.cache.get_group_chunk(graph, sched.g_sizes[k],
                                              sched.pad_sizes[k])
            cold = cold or prog.calls == 0
            bufs = {**whole_bufs, **resident, **pieces}
            args = (np.int32(sched.out_starts[k]), np.int32(sched.g_starts[k]),
                    np.int32(sched.out_sizes[k]))
            outs.append(prog(bufs, *args))   # async launch; k+1 still in flight
            dispatch += time.perf_counter() - t0
            issuer.consumed(ends[k])         # span k's staging slot frees
            launches.append((prog, bufs, args))
        t0 = time.perf_counter()
        trimmed = [o if int(p) == int(s) else o[:int(s)]
                   for o, p, s in zip(outs, sched.pad_sizes, sched.out_sizes)]
        arr = trimmed[0] if K == 1 else jnp.concatenate(trimmed)
        jax.block_until_ready(arr)
        dispatch += time.perf_counter() - t0
        if cold:      # first use traced+compiled: re-run warm so cached timings
            t0 = time.perf_counter()              # model decode, not jit
            res2 = pro_prog(whole_bufs) if pro_prog is not None else {}
            outs2 = [p({**b, **res2}, *a) for p, b, a in launches]
            outs2 = [o if int(pd) == int(s) else o[:int(s)] for o, pd, s
                     in zip(outs2, sched.pad_sizes, sched.out_sizes)]
            jax.block_until_ready(outs2[0] if K == 1
                                  else jnp.concatenate(outs2))
            decode_s = time.perf_counter() - t0
        else:
            decode_s = dispatch
        enc = self._encoded[name]
        transfer_s = issuer.issue_s.get(name, 0.0) + residual
        if observe:
            # shard-local runs skip calibration: a fraction of a column would
            # skew the per-column (transfer_s, decode_s) actuals
            self.cost_model.observe(name, transfer_s, decode_s)
        return ColumnExec(
            name=name, array=arr, transfer_s=transfer_s, decode_s=decode_s,
            compressed_bytes=enc.compressed_nbytes, plain_bytes=enc.plain_nbytes,
            n_chunks=K, signature=graph.signature,
            decode_launches=K + (1 if pro_prog is not None else 0),
            chunk_decoded=True)

    # ------------------------------------------------------------------- mesh
    def _stage_shard(self, column: str, spec, chunk_bytes: int | None,
                     items: list, acq: list, rel: list):
        """Stage one group-span shard host-side, appending its transfer items
        (whole-resident leaves first, then per-span row-capped slices) to the
        shared per-link lists; returns ``(sched, device_col, ends)`` with
        GLOBAL item indices, ready for ``_run_group_chunked``."""
        sched = self.shard_schedule(column, chunk_bytes, spec.g_lo, spec.g_hi)
        if sched is None:
            raise ValueError(f"column {column!r} is not group-span shardable")
        ops = plan_mod.host_operands(self._encoded[column])
        device_col: dict[str, list] = {}
        for nm in sched.whole:
            device_col[nm] = [None]
            src = sched.host_push.get(nm)
            items.append((column, device_col[nm], 0,
                          np.asarray(ops[nm]) if src is None else src))
            acq.append(False)
            rel.append(False)
        ends: list[int] = []
        for i in range(sched.n_chunks):
            first = len(items)
            for nm in sched.slices:
                device_col.setdefault(nm, [None] * sched.n_chunks)
                items.append((column, device_col[nm], i,
                              sched.piece(np.asarray(ops[nm]), nm, i)))
                acq.append(False)
                rel.append(False)
            if len(items) > first:   # one staging slot per span
                acq[first] = True
                rel[-1] = True
            ends.append(len(items))
        return sched, device_col, ends

    def _run_shard(self, column: str, spec, chunk_bytes: int | None,
                   device, window: int) -> ColumnExec:
        """Decode one group-span shard of a registered column on ``device``
        (inline issue -- the sequential mesh path).

        Stages the whole-resident leaves plus the span's sliced (row-capped)
        pieces committed to the target device, then delegates to the group-
        chunked runner with GLOBAL group/output offsets so the cached span
        programs decode shard-local unchanged.  Shard timings do not feed
        ``CostModel.observe`` (they would skew whole-column calibration)."""
        items: list[_TransferItem] = []
        sched, device_col, ends = self._stage_shard(column, spec, chunk_bytes,
                                                    items, [], [])
        issuer = _InlineIssuer(items, device, {})
        gen = self._run_group_chunked(column, sched, device_col, ends,
                                      issuer, window, observe=False)
        rec = _drive_seq(gen, issuer)
        return dataclasses.replace(
            rec, name=planner_mod.shard_name(column, spec.index))

    def _device_leg(self, leg: _StagedLeg | None, shard_stage: list,
                    issuer, window: int, on_ready=None, on_shard=None):
        """Combined decode-driver generator for one mesh device: the whole-
        column leg first (plan order), then each group-span shard -- exactly
        the sequence the sequential path executes per device, over ONE shared
        issuer queue.  ``on_shard(item, rec)`` fires the moment a shard's
        decode completes (the hook the D2D redistribution legs hang off, so
        fabric copies start while later shards still decode).  Returns
        ``(whole_results, shard_recs)``."""
        whole_res: dict[str, ColumnExec] = {}
        if leg is not None:
            whole_res = yield from self._decode_leg(leg, issuer,
                                                    on_ready=on_ready)
        recs = []
        for col, spec, sched, device_col, ends in shard_stage:
            rec = yield from self._run_group_chunked(
                col, sched, device_col, ends, issuer, window, observe=False)
            rec = dataclasses.replace(
                rec, name=planner_mod.shard_name(col, spec.index))
            if on_shard is not None:
                on_shard(rec.name, rec)
            recs.append((col, spec, rec))
        return whole_res, recs

    def _observe_link_actuals(self, dev_id: int, dplan: ExecutionPlan,
                              recs: Sequence[ColumnExec]) -> None:
        """Feed one device leg's measured-vs-predicted transfer ratio into the
        per-link EWMA calibration (``CostModel.observe_link``)."""
        pred = sum(d.est_transfer_s for d in dplan.decisions.values())
        meas = sum(r.transfer_s for r in recs)
        if pred > 0.0 and meas > 0.0:
            self.cost_model.observe_link(dev_id, meas / pred)

    def _observe_d2d_actual(self, nbytes: int, copy_s: float) -> None:
        """Feed one fabric copy's measured time, as a ratio over the
        calibrated H2D-equivalent for the same byte count, into the
        ``CostModel.observe_d2d`` EWMA."""
        ref = self.cost_model.h2d_equiv_s(nbytes)
        if ref > 0.0 and copy_s > 0.0:
            self.cost_model.observe_d2d(copy_s / ref)

    def _d2d_target(self, mesh_plan, devices, dst_logical: int):
        """(physical device id, jax device) for a redistribution leg's
        destination logical device."""
        ids = mesh_plan.device_ids
        dst_id = int(ids[dst_logical % len(ids)]) if ids else int(dst_logical)
        return dst_id, devices[dst_id % len(devices)]

    def _copy_shard_d2d(self, rec: ColumnExec, dst_logical: int, mesh_plan,
                        devices) -> tuple[ColumnExec, int, object, float]:
        """Move one decoded shard to its final device over the D2D fabric
        (sequential mesh path: timed, blocking ``jax.device_put``); the
        measured copy feeds the fabric EWMA."""
        dst_id, dst_dev = self._d2d_target(mesh_plan, devices, dst_logical)
        t0 = time.perf_counter()
        arr = jax.device_put(rec.array, dst_dev)
        jax.block_until_ready(arr)
        copy_s = time.perf_counter() - t0
        self._observe_d2d_actual(int(arr.nbytes), copy_s)
        return dataclasses.replace(rec, array=arr), dst_id, dst_dev, copy_s

    def run_sharded(self, mesh_plan, encs: dict[str, plan_mod.Encoded] | None = None,
                    on_ready=None, concurrent: bool | None = None
                    ) -> "MeshRunResult":
        """Execute a ``MeshExecutionPlan``: each logical device runs its
        per-device ``ExecutionPlan`` for whole columns (committed transfers,
        per-device in-flight window) plus shard-local group-span decodes;
        sharded columns assemble into one ``jax.sharding``-annotated global
        array when shard sizes are even (no host gather), falling back to
        device concatenation otherwise.

        ``concurrent`` (default: auto, on when more than one device has work)
        issues all devices' transfer streams at once -- one ``DispatchEngine``
        worker per host->device link, decode launches interleaved across
        devices from this thread as chunks commit -- instead of walking
        devices one at a time.  Results are bitwise identical either way
        (per-column sequence numbers fix chunk order; assembly is unchanged);
        measured per-link actuals feed ``CostModel.observe_link`` in both
        modes."""
        if encs is not None:
            for name, enc in encs.items():
                if (self._programs.get(name) is None
                        or self._encoded.get(name) is not enc):
                    self.compile(name, enc)
        devices = jax.devices()
        active = sum(1 for p in mesh_plan.plans if p.order)
        if concurrent is None:
            concurrent = active > 1
        if concurrent and active > 1:
            return self._run_sharded_concurrent(mesh_plan, devices, on_ready)
        per_device: dict[int, tuple[str, ...]] = {}
        device_launches: dict[int, int] = {}
        results: dict[str, ColumnExec] = {}
        shard_recs: dict[str, list] = {}
        redist_dst = {it: dst for it, _src, dst
                      in getattr(mesh_plan, "redistribution", ())}
        d2d_done: dict[str, tuple[int, int, float]] = {}
        for li, dplan in enumerate(mesh_plan.plans):
            dev_id = int(mesh_plan.device_ids[li])
            dev = devices[dev_id % len(devices)]
            d_items = list(dplan.order)
            per_device[dev_id] = tuple(d_items)
            launches = 0
            dev_recs: list[ColumnExec] = []
            whole = [it for it in d_items if planner_mod.SHARD_SEP not in it]
            if whole:
                res = self.run({n: self._encoded[n] for n in whole},
                               plan=dplan, on_ready=on_ready, device=dev,
                               async_dispatch=False)
                seen: set[frozenset] = set()
                for n, rec in res.items():
                    results[n] = rec
                    dev_recs.append(rec)
                    grp = frozenset((n,) + rec.batched_with)
                    if grp not in seen:     # batched members share one launch
                        seen.add(grp)
                        launches += rec.decode_launches
            for it in d_items:
                if planner_mod.SHARD_SEP not in it:
                    continue
                col = planner_mod.shard_column_of(it)
                spec = next(s for s in mesh_plan.shards[col] if s.name == it)
                rec = self._run_shard(col, spec,
                                      dplan.decisions[it].chunk_bytes,
                                      dev, dplan.window)
                launches += rec.decode_launches
                dev_recs.append(rec)
                dst = redist_dst.get(it)
                if dst is not None and int(dst) != li:
                    rec, dst_id, dst_dev, copy_s = self._copy_shard_d2d(
                        rec, int(dst), mesh_plan, devices)
                    d2d_done[it] = (dev_id, dst_id, copy_s)
                    shard_recs.setdefault(col, []).append(
                        (spec, rec, dst_id, dst_dev))
                else:
                    shard_recs.setdefault(col, []).append(
                        (spec, rec, dev_id, dev))
            device_launches[dev_id] = launches
            if d_items:
                self._observe_link_actuals(dev_id, dplan, dev_recs)
        return self._finish_sharded(results, shard_recs, per_device,
                                    device_launches, mesh_plan, on_ready,
                                    d2d_copies=d2d_done)

    def _run_sharded_concurrent(self, mesh_plan, devices,
                                on_ready=None) -> "MeshRunResult":
        """Concurrent-issue mesh execution: stage every device's leg, spawn
        one transfer worker per link (shared host-staging budget from the
        plan's topology), and drive all device legs' decode generators from
        THIS thread -- H2D streams overlap each other and every decode launch
        (all tracing stays here; workers only ``device_put``).

        Redistribution legs ride the SAME engine: each D2D copy gets its own
        single-item issuer bound to the destination device, filled via the
        ``on_shard`` hook the moment its shard's decode completes -- the
        fabric copy then runs on that worker thread, overlapping every other
        leg's remaining transfers and decodes; its blocking ``issue_s``
        records the true copy duration for ``observe_d2d``."""
        engine = DispatchEngine(
            host_window=mesh_plan.topology.host_window)
        tasks: dict[int, tuple] = {}
        legmeta: dict[int, tuple] = {}
        per_device: dict[int, tuple[str, ...]] = {}
        device_launches: dict[int, int] = {}
        redist_dst = {it: dst for it, _src, dst
                      in getattr(mesh_plan, "redistribution", ())}
        # item -> mutable D2D leg state (issuer filled at decode completion)
        d2d_legs: dict[str, dict] = {}
        d2d_done: dict[str, tuple[int, int, float]] = {}
        try:
            for li, dplan in enumerate(mesh_plan.plans):
                dev_id = int(mesh_plan.device_ids[li])
                d_items = list(dplan.order)
                per_device[dev_id] = tuple(d_items)
                device_launches[dev_id] = 0
                if not d_items:
                    continue
                dev = devices[dev_id % len(devices)]
                items: list[_TransferItem] = []
                acq: list[bool] = []
                rel: list[bool] = []
                whole = [it for it in d_items
                         if planner_mod.SHARD_SEP not in it]
                leg = (self._stage_leg(whole, dplan.decisions, dplan.window,
                                       items, acq, rel) if whole else None)
                shard_stage = []
                for it in d_items:
                    if planner_mod.SHARD_SEP not in it:
                        continue
                    col = planner_mod.shard_column_of(it)
                    spec = next(s for s in mesh_plan.shards[col]
                                if s.name == it)
                    sched, device_col, ends = self._stage_shard(
                        col, spec, dplan.decisions[it].chunk_bytes,
                        items, acq, rel)
                    shard_stage.append((col, spec, sched, device_col, ends))
                    dst = redist_dst.get(it)
                    if dst is not None and int(dst) != li:
                        dst_id, dst_dev = self._d2d_target(mesh_plan, devices,
                                                           int(dst))
                        # placeholder item: the worker never reads it until
                        # on_shard fills the slot and advances the watermark
                        d_items_list: list = [None]
                        d_times: dict[str, float] = {}
                        d2d_legs[it] = {
                            "items": d_items_list, "dest": [None],
                            "times": d_times, "src_id": dev_id,
                            "dst_id": dst_id, "dst_dev": dst_dev,
                            "filled": False,
                            "iss": engine.issuer(
                                d_items_list, dst_dev, d_times,
                                acq=[False], rel=[False],
                                name=f"zipflow-d2d-{it}", sync=True)}

                def on_shard(item, rec, _legs=d2d_legs):
                    ent = _legs.get(item)
                    if ent is not None:
                        ent["items"][0] = (item, ent["dest"], 0, rec.array)
                        ent["filled"] = True
                        ent["iss"].advance(1)

                iss = engine.issuer(items, dev, {}, acq=acq, rel=rel,
                                    name=f"zipflow-xfer-d{dev_id}")
                gen = self._device_leg(leg, shard_stage, iss, dplan.window,
                                       on_ready=on_ready, on_shard=on_shard)
                tasks[li] = (gen, iss)
                legmeta[li] = (dev_id, dev, dplan)
            done = engine.drive(tasks)
            for it, ent in d2d_legs.items():
                if ent["filled"]:
                    ent["iss"].wait(1)
                    d2d_done[it] = (ent["src_id"], ent["dst_id"],
                                    ent["times"].get(it, 0.0))
        finally:
            engine.close()
        results: dict[str, ColumnExec] = {}
        shard_recs: dict[str, list] = {}
        for li, (dev_id, dev, dplan) in legmeta.items():
            whole_res, recs = done[li]
            launches = 0
            seen: set[frozenset] = set()
            for n, rec in whole_res.items():
                results[n] = rec
                grp = frozenset((n,) + rec.batched_with)
                if grp not in seen:         # batched members share one launch
                    seen.add(grp)
                    launches += rec.decode_launches
            for col, spec, rec in recs:
                launches += rec.decode_launches
                ent = d2d_legs.get(rec.name)
                if ent is not None and ent["filled"]:
                    copied = ent["dest"][0]
                    self._observe_d2d_actual(int(copied.nbytes),
                                             ent["times"].get(rec.name, 0.0))
                    shard_recs.setdefault(col, []).append(
                        (spec, dataclasses.replace(rec, array=copied),
                         ent["dst_id"], ent["dst_dev"]))
                else:
                    shard_recs.setdefault(col, []).append(
                        (spec, rec, dev_id, dev))
            device_launches[dev_id] = launches
            self._observe_link_actuals(
                dev_id, dplan,
                list(whole_res.values()) + [r for _, _, r in recs])
        return self._finish_sharded(results, shard_recs, per_device,
                                    device_launches, mesh_plan, on_ready,
                                    d2d_copies=d2d_done)

    def _finish_sharded(self, results: dict, shard_recs: dict,
                        per_device: dict, device_launches: dict,
                        mesh_plan, on_ready=None,
                        d2d_copies: dict | None = None) -> "MeshRunResult":
        """Assemble shard outputs (shared by both mesh issue modes).  Shard
        tuples carry their FINAL device (redistributed shards arrive already
        copied), so the assembled ``NamedSharding`` reflects the plan's
        requested placement, not where the bytes landed."""
        for col in sorted(shard_recs):
            lst = sorted(shard_recs[col], key=lambda t: t[0].index)
            recs = [t[1] for t in lst]
            arr = self._assemble_shards([r.array for r in recs],
                                        [t[3] for t in lst])
            enc = self._encoded[col]
            results[col] = ColumnExec(
                name=col, array=arr,
                transfer_s=max(r.transfer_s for r in recs),
                decode_s=max(r.decode_s for r in recs),
                compressed_bytes=enc.compressed_nbytes,
                plain_bytes=enc.plain_nbytes,
                n_chunks=sum(r.n_chunks for r in recs),
                signature=self._graphs[col].signature,
                decode_launches=sum(r.decode_launches for r in recs),
                chunk_decoded=True,
                shard_devices=tuple(t[2] for t in lst))
            if on_ready is not None:
                on_ready(col)
        return MeshRunResult(columns=results, per_device=per_device,
                             device_launches=device_launches, plan=mesh_plan,
                             d2d_copies=dict(d2d_copies or {}))

    @staticmethod
    def _assemble_shards(arrs: list, devs: list):
        """Join shard outputs into one global array.  Equal-size shards on
        distinct devices join zero-copy via
        ``jax.make_array_from_single_device_arrays`` over a 1-axis mesh, so
        the result is already sharding-annotated for a sharded consumer;
        uneven or co-located shards fall back to device concatenation."""
        if len(arrs) == 1:
            return arrs[0]
        sizes = [int(a.shape[0]) for a in arrs]
        if len(set(sizes)) == 1 and len(set(devs)) == len(devs):
            from jax.sharding import Mesh, NamedSharding, PartitionSpec
            mesh = Mesh(np.array(devs), ("shard",))
            sharding = NamedSharding(mesh, PartitionSpec("shard"))
            gshape = (sum(sizes),) + tuple(arrs[0].shape[1:])
            singles = [jax.device_put(a, d) for a, d in zip(arrs, devs)]
            return jax.make_array_from_single_device_arrays(
                gshape, sharding, singles)
        return jnp.concatenate([jax.device_put(a, devs[0]) for a in arrs])

    # ------------------------------------------------------------- fused query
    def run_query(self, fq, encs: dict[str, plan_mod.Encoded] | None = None,
                  chunk_bytes: int | None | object = _DEFAULTS,
                  window: int | None = None) -> "QueryExec":
        """Execute a decode-fused query (``core.query.lower_query`` output).

        Non-fusible (resident) columns decode first through the normal planned
        ``run`` path; then ONE shared row-axis chunk schedule streams every
        fused column's leaf buffers together, and each chunk launches the
        cached ``QueryChunkProgram`` -- scan-filter-aggregate fused into the
        decode launch.  Each launch returns a partial-aggregate vector
        (``graph.n_out`` lanes) summed into an on-device accumulator; the
        decompressed columns never exist in HBM.  The accumulator itself holds
        one in-flight staging slot, so the effective transfer window is
        ``max(1, window - 1)``.  Measured selectivity (the Reduce count lane)
        feeds the cost model's per-signature EWMA for future fused-vs-
        materialize planning."""
        from repro.core import fusion
        from repro.core.ir import query_chunk_layout

        if chunk_bytes is self._DEFAULTS:
            chunk_bytes = self._fixed_chunk_bytes
        resident_execs: dict[str, ColumnExec] = {}
        res_bufs: dict[str, jnp.ndarray] = {}
        if fq.resident:
            missing = [c for c in fq.resident if not encs or c not in encs]
            if missing:
                raise ValueError(
                    f"resident columns need their Encoded blobs: {missing}")
            resident_execs = self.run({c: encs[c] for c in fq.resident})
            for c in fq.resident:
                res_bufs[fq.resident_input(c)] = resident_execs[c].array

        graph = fq.graph
        n, ops = fq.n_rows, fq.operands
        # shared row-axis schedule over the fused columns' tiled leaves --
        # the same leaf addressing _build_schedule uses, resolved against
        # THIS query's merged operand set; memoized per (structure, chunking)
        # so warm calls go straight to staging
        skey = (graph.signature,
                None if chunk_bytes is None else int(chunk_bytes), n)
        sched = self._query_schedules.get(skey)
        if sched is None:
            layout = query_chunk_layout(graph)
            if layout is None:
                raise ValueError(
                    f"graph {graph.nesting!r} is not query-chunkable")
            ratios: dict[str, tuple[int, int]] = {}
            per_elem = 0.0
            for nm, spec in layout.tiled.items():
                num = int(ops[spec.num_op][0]) if spec.num_op else int(spec.num)
                ratios[nm] = (num, int(spec.den))
                per_elem += num / spec.den * np.dtype(ops[nm].dtype).itemsize
            chunk_elems = (n if chunk_bytes is None
                           else costmodel.aligned_chunk_elems(
                               chunk_bytes, per_elem, layout.align))
            chunk_elems = min(chunk_elems, n)
            out_starts = tuple(range(0, n, chunk_elems))
            out_sizes = tuple(min(chunk_elems, n - s) for s in out_starts)
            host_slices: list[dict[str, tuple[int, int]]] = []
            for s, sz in zip(out_starts, out_sizes):
                sl = {}
                for nm, (num, den) in ratios.items():
                    length = int(np.asarray(ops[nm]).shape[0])
                    lo = (s * num) // den
                    hi = length if s + sz >= n else ((s + sz) * num) // den
                    sl[nm] = (lo, max(hi, lo + 1))
                host_slices.append(sl)
            sched = (tuple(layout.whole), out_starts, out_sizes, host_slices)
            self._query_schedules[skey] = sched
        whole_names, out_starts, out_sizes, host_slices = sched
        K = len(out_starts)

        t_issue = 0.0

        def put_group(pieces: dict[str, np.ndarray]) -> dict[str, jnp.ndarray]:
            # ONE batched device_put per staging group: per-call dispatch
            # overhead, not bytes, dominates small-buffer H2D
            nonlocal t_issue
            t0 = time.perf_counter()
            keys = list(pieces)
            outs = jax.device_put([pieces[nm] for nm in keys])  # async H2D
            t_issue += time.perf_counter() - t0
            return dict(zip(keys, outs))

        whole_bufs = put_group({nm: np.asarray(ops[nm]) for nm in whole_names})
        # the on-device partial-aggregate accumulator holds one staging slot
        win = 2 if window is None else max(1, int(window))
        eff = max(1, win - 1)
        device_pieces: list[dict[str, jnp.ndarray] | None] = [None] * K
        next_issue = 0

        def issue_upto(m: int) -> None:
            nonlocal next_issue
            while next_issue < min(m, K):
                sl = host_slices[next_issue]
                device_pieces[next_issue] = put_group(
                    {nm: np.asarray(ops[nm])[lo:hi]
                     for nm, (lo, hi) in sl.items()})
                next_issue += 1

        residual = 0.0
        dispatch = 0.0
        cold = False
        launches = []      # (QueryChunkProgram, bufs, start) for warm re-time
        acc = None
        for k in range(K):
            issue_upto(k + eff)
            t0 = time.perf_counter()
            if k == 0:
                jax.block_until_ready(list(whole_bufs.values()))
            pieces = device_pieces[k]
            jax.block_until_ready(list(pieces.values()))
            residual += time.perf_counter() - t0
            prog = self.cache.get_query_chunk(graph, out_sizes[k])
            cold = cold or prog.calls == 0
            bufs = {**whole_bufs, **res_bufs, **pieces}
            start = np.int32(out_starts[k])
            t0 = time.perf_counter()
            part = prog(bufs, start)          # async launch; k+1.. in flight
            acc = part if acc is None else acc + part
            dispatch += time.perf_counter() - t0
            launches.append((prog, bufs, start))
        t0 = time.perf_counter()
        jax.block_until_ready(acc)
        dispatch += time.perf_counter() - t0
        if cold:      # first use traced+compiled: re-run warm so timings model
            t0 = time.perf_counter()               # the fused decode, not jit
            acc2 = None
            for p, b, s in launches:
                part = p(b, s)
                acc2 = part if acc2 is None else acc2 + part
            jax.block_until_ready(acc2)
            decode_s = time.perf_counter() - t0
            acc = acc2
        else:
            decode_s = dispatch
        transfer_s = t_issue + residual

        # acc is tiny (lanes x segments): one D2H pull serves selectivity and
        # the finalized result without extra device slicing round-trips
        acc_np = np.asarray(acc)
        sel = float(fq.selectivity(acc_np))
        for c in fq.fused_cols:
            if c not in self.cost_model.profiles and encs and c in encs:
                from repro.core.compiler import build_graph
                self.cost_model.register(
                    profile_from(c, encs[c], build_graph(encs[c])))
            if c in self.cost_model.profiles:
                self.cost_model.observe_selectivity(c, sel)
        traffic = self._query_traffic.get(graph.signature)
        if traffic is None:
            all_bufs = {**ops, **res_bufs}
            traffic = (fusion.hbm_traffic_bytes(graph.stages, all_bufs),
                       fusion.hbm_traffic_bytes(fq.prefuse_stages, all_bufs))
            self._query_traffic[graph.signature] = traffic
        compressed = sum(int(np.asarray(ops[b.name]).nbytes)
                         for b in graph.buffers)
        plain = (sum(int(encs[c].plain_nbytes) for c in fq.fused_cols)
                 if encs else 0)
        return QueryExec(
            name=fq.qplan.name, result=fq.finalize(acc_np), acc=acc,
            transfer_s=transfer_s, decode_s=decode_s,
            n_chunks=K, decode_launches=K, selectivity=sel,
            compressed_bytes=compressed, plain_bytes=plain,
            traffic_bytes=traffic[0], prefuse_traffic_bytes=traffic[1],
            resident=resident_execs)

    def unregister(self, name: str) -> None:
        """Drop one registered blob's per-column state (profile, schedules,
        measured timings).  Compiled programs stay in the shared ProgramCache,
        and the cost model's per-SIGNATURE history survives -- so a long-lived
        server keeps its calibration while per-request names come and go."""
        for store in (self._encoded, self._graphs, self._programs):
            store.pop(name, None)
        for store in (self._chunk_counts, self._schedules):
            for key in [k for k in store if k[0] == name]:
                store.pop(key)
        self.cost_model.forget(name)

    def run_one(self, enc: plan_mod.Encoded, name: str = "_single") -> jnp.ndarray:
        """Decode a single blob through the cache (serving-path helper).

        The blob is unregistered afterwards so a long-lived engine serving many
        requests does not accumulate per-request state; compiled programs stay in
        the shared ProgramCache."""
        self.compile(name, enc)
        try:
            return self.run({name: enc})[name].array
        finally:
            self.unregister(name)

    # ------------------------------------------------------------------- model
    def measured_jobs(self, names: Sequence[str] | None = None) -> list[scheduler.Job]:
        """Scheduling jobs from the cost model, in CONSISTENT units: measured
        wall-clock when every column has a measurement, EWMA-calibrated chip
        estimates for all otherwise (see ``CostModel.jobs``)."""
        names = list(self._encoded) if names is None else list(names)
        return self.cost_model.jobs(names)

    def modeled_makespan(self, names: Sequence[str] | None = None,
                         pipeline: bool = True, johnson: bool = True,
                         chunked: bool = False) -> float:
        """Two-machine flow-shop makespan from current (measured or estimated)
        per-column times, optionally at chunk granularity."""
        jobs = self.measured_jobs(names)
        if not pipeline:
            return scheduler.serial_time(jobs)
        if chunked:
            jobs = scheduler.chunk_jobs(jobs, [self._n_chunks(j.name)
                                               for j in jobs])
        order = (scheduler.johnson_order(jobs) if johnson
                 else scheduler.fifo_order(jobs))
        return scheduler.makespan(jobs, order)
