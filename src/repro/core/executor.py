"""Streaming decode executor: chunked double-buffered transfer + batched decode.

This is the runtime half of the compile pipeline (``plan.lower_graph`` ->
``fusion.fuse_graph`` -> ``ProgramCache``).  Given a set of compressed blobs it

  1. splits every leaf buffer into fixed-size chunks (``chunk_bytes``),
  2. orders the chunk transfers by Johnson's rule at *chunk* granularity
     (``scheduler.chunk_jobs``) so transfer of later chunks overlaps decode of
     earlier columns, with a bounded in-flight window (double buffering: the async
     ``jax.device_put`` of chunk k+1..k+w is in flight while chunk k is consumed),
  3. reassembles chunks on device and decodes each column through its cached
     Program -- stacking same-signature columns and decoding them in ONE batched
     launch (``Program.batched``, vmap over the leading axis), and
  4. records per-column (transfer_s, decode_s) timings so clients schedule future
     runs from real measurements instead of re-measuring every column.

Chunked+batched execution is bitwise-identical to the one-shot path: chunks
concatenate back to the exact source bytes and vmap runs the same program per lane.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import plan as plan_mod, scheduler
from repro.core.compiler import DEFAULT_CACHE, Program, ProgramCache
from repro.core.geometry import DEFAULT_CHIP, chip as chip_spec
from repro.core.ir import DecodeGraph


def split_chunks(arr: np.ndarray, chunk_bytes: int | None) -> list[np.ndarray]:
    """Split a host buffer into <=chunk_bytes pieces along axis 0 (2-D buffers like
    the ANS stream matrix chunk by rows).  Concatenating the pieces restores the
    buffer exactly, so chunked transfer cannot change decode results."""
    if (chunk_bytes is None or arr.ndim == 0 or arr.nbytes <= chunk_bytes
            or arr.shape[0] <= 1):
        return [arr]
    row_bytes = max(1, arr.nbytes // max(1, arr.shape[0]))
    rows = max(1, chunk_bytes // row_bytes)
    return [arr[i:i + rows] for i in range(0, arr.shape[0], rows)]


@dataclasses.dataclass
class ColumnExec:
    """Execution record for one decoded column."""

    name: str
    array: jnp.ndarray
    transfer_s: float
    decode_s: float
    compressed_bytes: int
    plain_bytes: int
    n_chunks: int
    signature: str
    batched_with: tuple[str, ...] = ()   # same-signature columns sharing the launch


class StreamingExecutor:
    """Chunked, cached, batched decode engine over a ProgramCache."""

    def __init__(self, backend: str = "jnp", fuse: bool = True,
                 chunk_bytes: int | None = 1 << 20, pipeline: bool = True,
                 batch_columns: bool = True, prefetch_chunks: int = 2,
                 chip: str = DEFAULT_CHIP, cache: ProgramCache | None = None):
        self.backend = backend
        self.fuse = fuse
        self.chunk_bytes = chunk_bytes
        self.pipeline = pipeline
        self.batch_columns = batch_columns
        self.prefetch_chunks = max(1, prefetch_chunks)
        self.chip = chip
        self.cache = cache if cache is not None else DEFAULT_CACHE
        self._encoded: dict[str, plan_mod.Encoded] = {}
        self._graphs: dict[str, DecodeGraph] = {}
        self._programs: dict[str, Program] = {}
        self._chunk_counts: dict[str, int] = {}
        # measured (transfer_s, decode_s) per column from the latest run
        self.timings: dict[str, tuple[float, float]] = {}

    # ------------------------------------------------------------------ compile
    def compile(self, name: str, enc: plan_mod.Encoded) -> Program:
        """Register a blob and return its (cache-shared) Program."""
        from repro.core.compiler import compile_blob

        self._encoded[name] = enc
        # re-registering a name invalidates anything derived from the old blob
        self._chunk_counts.pop(name, None)
        self.timings.pop(name, None)
        prog = compile_blob(enc, backend=self.backend, fuse=self.fuse,
                            chip=self.chip, cache=self.cache)
        self._graphs[name] = prog.graph
        self._programs[name] = prog
        return prog

    def program(self, name: str) -> Program:
        return self._programs[name]

    def graph(self, name: str) -> DecodeGraph:
        return self._graphs[name]

    # ----------------------------------------------------------------- schedule
    def _estimate(self, name: str) -> tuple[float, float]:
        """Static (transfer_s, decode_s) estimate from the chip resource table --
        used for issue ordering before any measured timings exist."""
        enc = self._encoded[name]
        spec = chip_spec(self.chip)
        transfer = enc.compressed_nbytes / (spec.host_link_gbps * 1e9)
        # decode is HBM-bound: read compressed + write plain, plus per-kernel launch
        graph = self._graphs[name]
        traffic = enc.compressed_nbytes + enc.plain_nbytes
        decode = (traffic / (spec.hbm_gbps * 1e9)
                  + graph.n_kernels * spec.grid_step_overhead_ns * 1e-9)
        return transfer, decode

    def _n_chunks(self, name: str) -> int:
        """Number of transfer pieces the executor will actually issue for a column
        (per leaf buffer, row-granular) -- the chunk count the Zc model uses."""
        if self.chunk_bytes is None:
            return 1
        cached = self._chunk_counts.get(name)
        if cached is None:
            flat = plan_mod.flat_buffers(self._encoded[name])
            cached = sum(len(split_chunks(np.asarray(v), self.chunk_bytes))
                         for v in flat.values())
            self._chunk_counts[name] = cached
        return cached

    def issue_order(self, names: Sequence[str] | None = None) -> list[str]:
        """Column issue order induced by chunk-level Johnson scheduling."""
        names = list(self._encoded) if names is None else list(names)
        if not self.pipeline or len(names) <= 1:
            return names
        jobs = self.measured_jobs(names)
        cjobs = scheduler.chunk_jobs(jobs, [self._n_chunks(n) for n in names])
        corder = scheduler.johnson_order(cjobs)
        return scheduler.column_order([cjobs[i].name for i in corder])

    # --------------------------------------------------------------------- run
    def run(self, encs: dict[str, plan_mod.Encoded] | None = None,
            order: Sequence[str] | None = None) -> dict[str, ColumnExec]:
        """Transfer + decode a set of columns; returns per-column records."""
        if encs is not None:
            for name, enc in encs.items():
                if self._programs.get(name) is None or self._encoded.get(name) is not enc:
                    self.compile(name, enc)
            names = list(encs)
        else:
            names = list(self._encoded)
        order = list(order) if order is not None else self.issue_order(names)

        # host-side chunking, in issue order
        host: dict[str, dict[str, list[np.ndarray]]] = {}
        transfer_items: list[tuple[str, str, int, np.ndarray]] = []
        col_end: dict[str, int] = {}
        for name in order:
            flat = plan_mod.flat_buffers(self._encoded[name])
            host[name] = {k: split_chunks(np.asarray(v), self.chunk_bytes)
                          for k, v in flat.items()}
            for k, pieces in host[name].items():
                for i, piece in enumerate(pieces):
                    transfer_items.append((name, k, i, piece))
            col_end[name] = len(transfer_items)

        device: dict[str, dict[str, list]] = {n: {k: [None] * len(p) for k, p in
                                                  host[n].items()} for n in order}
        cursor = 0
        # time spent issuing each column's device_puts: on CPU the copy happens
        # synchronously here; on accelerators issue is cheap and the residual wait
        # at the block is the real transfer tail -- transfer_s sums both
        issue_s: dict[str, float] = {n: 0.0 for n in order}

        def issue_until(target: int) -> None:
            nonlocal cursor
            while cursor < min(target, len(transfer_items)):
                name, k, i, piece = transfer_items[cursor]
                t = time.perf_counter()
                device[name][k][i] = jax.device_put(piece)   # async H2D
                issue_s[name] += time.perf_counter() - t
                cursor += 1

        # decode units: *consecutive-in-order* columns sharing one Program decode in
        # a single batched launch.  Grouping only adjacent columns keeps the
        # transfer/decode overlap: a global group spanning the whole order would
        # force every transfer to finish before the first decode.  (Johnson's rule
        # keys on (transfer, decode) times, which are equal for same-signature
        # columns, so they end up adjacent anyway.)
        units: list[tuple[Program, list[str]]] = []
        for name in order:
            prog = self._programs[name]
            if self.batch_columns and units and units[-1][0] is prog:
                units[-1][1].append(name)
            else:
                units.append((prog, [name]))

        window = self.prefetch_chunks
        results: dict[str, ColumnExec] = {}
        for prog, members in units:
            last_end = max(col_end[m] for m in members)
            issue_until(last_end + window)      # keep the link busy ahead of decode
            t0 = time.perf_counter()
            bufs_per_member = []
            for m in members:
                chunks = device[m]
                bufs = {k: (pieces[0] if len(pieces) == 1
                            else jnp.concatenate(pieces, axis=0))
                        for k, pieces in chunks.items()}
                bufs_per_member.append(bufs)
            for bufs in bufs_per_member:
                jax.block_until_ready(list(bufs.values()))
            t1 = time.perf_counter()
            residual_wait = (t1 - t0) / len(members)
            if len(members) > 1:
                cold = prog.batched_calls == 0
                stacked = {k: jnp.stack([b[k] for b in bufs_per_member])
                           for k in bufs_per_member[0]}
                out = prog.batched(stacked)
                jax.block_until_ready(out)
                t2 = time.perf_counter()
                if cold:      # first call traced+compiled; re-time warm so cached
                    t1 = time.perf_counter()      # timings model decode, not jit
                    jax.block_until_ready(prog.batched(stacked))
                    t2 = time.perf_counter()
                outs = [out[i] for i in range(len(members))]
            else:
                cold = prog.calls == 0
                outs = [prog(bufs_per_member[0])]
                jax.block_until_ready(outs[0])
                t2 = time.perf_counter()
                if cold:
                    t1 = time.perf_counter()
                    jax.block_until_ready(prog(bufs_per_member[0]))
                    t2 = time.perf_counter()
            # members of one unit share a signature => identical buffer shapes and
            # bytes, so the even decode split is exact, not an approximation
            decode_s = (t2 - t1) / len(members)
            siblings = tuple(members) if len(members) > 1 else ()
            for m, arr in zip(members, outs):
                enc = self._encoded[m]
                transfer_s = issue_s[m] + residual_wait
                self.timings[m] = (transfer_s, decode_s)
                results[m] = ColumnExec(
                    name=m, array=arr, transfer_s=transfer_s, decode_s=decode_s,
                    compressed_bytes=enc.compressed_nbytes,
                    plain_bytes=enc.plain_nbytes, n_chunks=self._n_chunks(m),
                    signature=self._graphs[m].signature,
                    batched_with=tuple(s for s in siblings if s != m))
        return results

    def run_one(self, enc: plan_mod.Encoded, name: str = "_single") -> jnp.ndarray:
        """Decode a single blob through the cache (serving-path helper).

        The blob is unregistered afterwards so a long-lived engine serving many
        requests does not accumulate per-request state; compiled programs stay in
        the shared ProgramCache."""
        self.compile(name, enc)
        try:
            return self.run({name: enc})[name].array
        finally:
            for store in (self._encoded, self._graphs, self._programs,
                          self._chunk_counts, self.timings):
                store.pop(name, None)

    # ------------------------------------------------------------------- model
    def measured_jobs(self, names: Sequence[str] | None = None) -> list[scheduler.Job]:
        """Scheduling jobs for a set of columns, in CONSISTENT units: measured
        wall-clock only when every column has a measurement, chip-model estimates
        for all otherwise.  Mixing the two (microsecond-scale model vs
        millisecond-scale CPU measurements) would make Johnson's transfer-vs-decode
        comparison arbitrary."""
        names = list(self._encoded) if names is None else list(names)
        if all(n in self.timings for n in names):
            est = {n: self.timings[n] for n in names}
        else:
            est = {n: self._estimate(n) for n in names}
        return [scheduler.Job(n, est[n][0], est[n][1]) for n in names]

    def modeled_makespan(self, names: Sequence[str] | None = None,
                         pipeline: bool = True, johnson: bool = True,
                         chunked: bool = False) -> float:
        """Two-machine flow-shop makespan from current (measured or estimated)
        per-column times, optionally at chunk granularity."""
        jobs = self.measured_jobs(names)
        if not pipeline:
            return scheduler.serial_time(jobs)
        if chunked:
            jobs = scheduler.chunk_jobs(jobs, [self._n_chunks(j.name)
                                               for j in jobs])
        order = (scheduler.johnson_order(jobs) if johnson
                 else scheduler.fifo_order(jobs))
        return scheduler.makespan(jobs, order)
