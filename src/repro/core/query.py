"""Query-operator IR + QueryPlan-to-graph lowering (codec x operator fusion).

Late materialization: instead of decoding every column to HBM and then running
the engine over the materialized columns, a ``QueryPlan`` (compare/between
predicates, arithmetic projections, predicated sums, segment-sum group-by)
lowers onto the columns' decode stages as operator stages -- per-column
predicate masks plus one terminal ``Reduce`` -- and ``fusion.fuse`` grafts the
decode chains into them (rule 6).  The fused graph's output is a partial
aggregate (a few scalars or an 8-lane segment accumulator), so the decompressed
columns never round-trip through HBM.

Predicates are evaluated in compressed domain where the codec allows it:

  * bit-packed integers: compared pre-widening on the packed words
    (``algos.bitpack.compare_stage``);
  * dictionary columns with a bit-packed index: value bounds map to dictionary
    *code* bounds (``algos.dictionary.code_bounds``, ``np.unique`` sorts the
    dictionary) and the code range is compared pre-widening -- the dictionary
    gather never happens;
  * RLE columns: per-run, run-length-weighted (``algos.rle.run_reduce_graph``),
    never per-row;
  * everything else (e.g. float2int decimals): fused-post-decode -- the decode
    closure is composed into the operator stage, and the float comparison uses
    the exact arithmetic of the reference engine (bitwise-identical masks).

Columns whose decode is not Fully-Parallel (ANS, RLE inside a multi-column
query) fall back to **resident** inputs: decoded once by the normal executor
path and gathered at the global row index by every fused chunk launch
(``BufSpec("row")``).
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Mapping

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fusion, ir as ir_mod, plan as plan_mod
from repro.core.patterns import (BufSpec, Ctx, FullyParallel, Reduce, Stage,
                                 arg_at)


# ------------------------------------------------------------- expression IR

@dataclasses.dataclass(frozen=True)
class Col:
    """Column reference; ``cast`` applies ``astype`` on read (e.g. uint8 flag
    bytes entering integer arithmetic)."""

    name: str
    cast: str = ""

    def eval(self, env: Mapping[str, jnp.ndarray]) -> jnp.ndarray:
        v = env[self.name]
        return v.astype(jnp.dtype(self.cast)) if self.cast else v

    def cols(self) -> set[str]:
        return {self.name}

    def token(self) -> str:
        return f"col:{self.name}:{self.cast}"


@dataclasses.dataclass(frozen=True)
class Const:
    value: float

    def eval(self, env: Mapping[str, jnp.ndarray]):
        return self.value          # python scalar: weak-typed, like the engine

    def cols(self) -> set[str]:
        return set()

    def token(self) -> str:
        return f"const:{self.value!r}"


@dataclasses.dataclass(frozen=True)
class Bin:
    """Binary arithmetic node; op in '+', '-', '*', '%'."""

    op: str
    a: Any
    b: Any

    def eval(self, env: Mapping[str, jnp.ndarray]):
        x, y = self.a.eval(env), self.b.eval(env)
        if self.op == "+":
            return x + y
        if self.op == "-":
            return x - y
        if self.op == "*":
            return x * y
        if self.op == "%":
            return x % y
        raise ValueError(f"unknown op {self.op!r}")

    def cols(self) -> set[str]:
        return self.a.cols() | self.b.cols()

    def token(self) -> str:
        return f"({self.a.token()}{self.op}{self.b.token()})"


@dataclasses.dataclass(frozen=True)
class Pred:
    """Range predicate on one column; op in '<', '<=', '>=', '>', 'between'
    (inclusive both ends, like SQL BETWEEN)."""

    col: str
    op: str
    value: Any
    value2: Any = None

    def mask(self, v: jnp.ndarray) -> jnp.ndarray:
        if self.op == "<":
            return v < self.value
        if self.op == "<=":
            return v <= self.value
        if self.op == ">=":
            return v >= self.value
        if self.op == ">":
            return v > self.value
        if self.op == "between":
            return (v >= self.value) & (v <= self.value2)
        raise ValueError(f"unknown predicate op {self.op!r}")

    def int_range(self) -> tuple[int | None, int | None] | None:
        """As a half-open integer range [lo, hi), or None if not exact."""
        def ok(x):
            return x is not None and float(x) == int(x)
        if self.op == "<" and ok(self.value):
            return None, int(self.value)
        if self.op == "<=" and ok(self.value):
            return None, int(self.value) + 1
        if self.op == ">=" and ok(self.value):
            return int(self.value), None
        if self.op == ">" and ok(self.value):
            return int(self.value) + 1, None
        if self.op == "between" and ok(self.value) and ok(self.value2):
            return int(self.value), int(self.value2) + 1
        return None

    def token(self) -> str:
        return f"pred:{self.col}:{self.op}:{self.value!r}:{self.value2!r}"


@dataclasses.dataclass(frozen=True)
class QueryPlan:
    """A scan-filter-aggregate query: ANDed predicates, mask-weighted sum
    aggregates, optional segment-sum group-by.  A trailing selected-row count
    lane is always computed (selectivity feedback for the cost model);
    ``keep_count_lane`` includes it in the result (TPC-H Q1's count(*) lane)."""

    name: str
    predicates: tuple[Pred, ...] = ()
    aggregates: tuple[tuple[str, Any], ...] = ()    # (label, Expr)
    group_key: Any = None                           # Expr -> int32 segment ids
    n_segments: int = 1
    keep_count_lane: bool = False

    def columns(self) -> list[str]:
        seen: list[str] = []
        for p in self.predicates:
            if p.col not in seen:
                seen.append(p.col)
        for _, e in self.aggregates:
            for c in sorted(e.cols()):
                if c not in seen:
                    seen.append(c)
        if self.group_key is not None:
            for c in sorted(self.group_key.cols()):
                if c not in seen:
                    seen.append(c)
        return seen

    def digest(self) -> str:
        toks = [self.name, str(self.n_segments), str(self.keep_count_lane)]
        toks += [p.token() for p in self.predicates]
        toks += [f"{lbl}={e.token()}" for lbl, e in self.aggregates]
        if self.group_key is not None:
            toks.append(f"key={self.group_key.token()}")
        return hashlib.sha1("|".join(toks).encode()).hexdigest()[:16]


# ------------------------------------------------------------------ lowering

def _all_fp(stages: list[Stage]) -> bool:
    return all(isinstance(st, FullyParallel) for st in stages)


def _merge_ranges(preds: tuple[Pred, ...]) -> tuple[int | None, int | None] | None:
    lo: int | None = None
    hi: int | None = None
    for p in preds:
        r = p.int_range()
        if r is None:
            return None
        plo, phi = r
        if plo is not None:
            lo = plo if lo is None else max(lo, plo)
        if phi is not None:
            hi = phi if hi is None else min(hi, phi)
    return lo, hi


def _compressed_domain_mask(col: str, enc, lo, hi) -> FullyParallel | None:
    """Pre-widening range mask over the packed words, or None if unsupported."""
    from repro.algos import bitpack as bp_mod
    from repro.algos import dictionary as dict_mod

    if enc.codec == "bitpack":
        return bp_mod.compare_stage(
            enc, f"{col}.packed", f"{col}.@bit_width", f"{col}.@base",
            f"{col}.mask", lo, hi)
    if enc.codec == "dictionary":
        child = enc.children.get("index")
        if child is None or child.codec != "bitpack":
            return None
        clo, chi = dict_mod.code_bounds(enc.buffers["dictionary"], lo, hi)
        return bp_mod.compare_stage(
            child, f"{col}/index.packed", f"{col}/index.@bit_width",
            f"{col}/index.@base", f"{col}.mask", clo, chi)
    return None


@dataclasses.dataclass
class FusedQuery:
    """A lowered, fused query: one Reduce-terminated DecodeGraph over the
    fusible columns plus the names of resident fallback columns."""

    qplan: QueryPlan
    graph: ir_mod.DecodeGraph
    operands: dict[str, np.ndarray]      # leaf buffers + meta operands (host)
    fused_cols: tuple[str, ...]
    resident: tuple[str, ...]            # columns fed decoded ("row" inputs)
    n_rows: int
    n_lanes: int                         # aggregates + the count lane
    n_segments: int
    prefuse_stages: list[Stage] = dataclasses.field(default_factory=list)

    def resident_input(self, col: str) -> str:
        return f"{col}.resident"

    def finalize(self, acc: jnp.ndarray) -> jnp.ndarray:
        """Partial-sum accumulator -> the engine-shaped result."""
        if self.qplan.group_key is None:
            vec = acc[: self.n_lanes - 1]
            return vec[0] if self.n_lanes == 2 else vec
        mat = acc.reshape(self.n_lanes, self.n_segments)
        return mat if self.qplan.keep_count_lane else mat[:-1]

    def selected_rows(self, acc: jnp.ndarray) -> float:
        return float(np.sum(np.asarray(acc[-self.n_segments:])))

    def selectivity(self, acc: jnp.ndarray) -> float:
        return self.selected_rows(acc) / max(self.n_rows, 1)


def lower_query(qplan: QueryPlan, encs: Mapping[str, Any]) -> FusedQuery:
    """Lower a QueryPlan over compressed columns to a fused DecodeGraph.

    ``encs`` maps column name -> ``plan.Encoded``; every column the query
    touches must be present and all columns must share the row count.
    """
    cols = qplan.columns()
    for c in cols:
        if c not in encs:
            raise KeyError(f"query {qplan.name} needs column {c!r}")
    n_rows = int(encs[cols[0]].n)
    for c in cols:
        if int(encs[c].n) != n_rows:
            raise ValueError(f"column {c} has {encs[c].n} rows, expected {n_rows}")

    value_cols: set[str] = set()
    for _, e in qplan.aggregates:
        value_cols |= e.cols()
    if qplan.group_key is not None:
        value_cols |= qplan.group_key.cols()

    stages: list[Stage] = []
    roles: list[tuple[str, str, str]] = []   # (kind, col, input name)
    inline_preds: list[Pred] = []
    fused_cols: list[str] = []
    resident: list[str] = []

    for col in cols:
        enc = encs[col]
        preds = tuple(p for p in qplan.predicates if p.col == col)
        dec_stages = plan_mod.lower(enc, prefix=col, out_name=f"{col}.val")
        if not _all_fp(dec_stages):
            resident.append(col)
            roles.append(("value", col, f"{col}.resident"))
            inline_preds += list(preds)
            continue
        fused_cols.append(col)
        if preds and col not in value_cols:
            rng = _merge_ranges(preds)
            cmask = (_compressed_domain_mask(col, enc, *rng)
                     if rng is not None else None)
            if cmask is not None:
                stages.append(cmask)         # decode chain elided entirely
                roles.append(("mask", col, cmask.out))
                continue
            # fused-post-decode mask stage (composed into the decode by rule 6)
            stages += dec_stages

            def mk_mask(ps):
                def fn(ctx: Ctx, v: jnp.ndarray) -> jnp.ndarray:
                    x = arg_at(ctx, 0, v)
                    m = ps[0].mask(x)
                    for p in ps[1:]:
                        m = m & p.mask(x)
                    return m
                return fn

            mst = FullyParallel(
                fn=mk_mask(preds), inputs=(f"{col}.val",),
                specs=(BufSpec("tile"),), out=f"{col}.mask", n_out=n_rows,
                out_dtype=jnp.bool_, elementwise=False, name=f"pred[{col}]")
            mst._positional_inputs = True   # type: ignore[attr-defined]
            stages.append(mst)
            roles.append(("mask", col, mst.out))
        else:
            stages += dec_stages
            roles.append(("value", col, f"{col}.val"))
            inline_preds += list(preds)

    n_lanes = len(qplan.aggregates) + 1     # + selected-row count lane
    S = int(qplan.n_segments)
    aggs = tuple(qplan.aggregates)
    key_expr = qplan.group_key
    role_list = list(roles)
    ipreds = tuple(inline_preds)

    def reduce_fn(ctx: Ctx, *blocks):
        env: dict[str, jnp.ndarray] = {}
        mask = None
        for j, (kind, cn, _) in enumerate(role_list):
            v = arg_at(ctx, j, blocks[j])
            if kind == "mask":
                mask = v if mask is None else mask & v
            else:
                env[cn] = v
        for p in ipreds:
            m = p.mask(env[p.col])
            mask = m if mask is None else mask & m
        w = (jnp.ones(ctx.out_idx.shape, jnp.float32) if mask is None
             else mask.astype(jnp.float32))
        lanes = [e.eval(env).astype(jnp.float32) * w for _, e in aggs] + [w]
        # ONE reduction over the stacked lanes: per-lane reduces would each
        # root their own fusion, letting XLA re-run the shared decode chains
        # once per lane
        if key_expr is None:
            return jnp.sum(jnp.stack(lanes), axis=1)          # (L, n) -> (L,)
        key = key_expr.eval(env).astype(jnp.int32)
        seg = jax.ops.segment_sum(jnp.stack(lanes, axis=1), key,
                                  num_segments=S)              # (S, L)
        return seg.T.reshape(-1)

    red = Reduce(
        fn=reduce_fn,
        inputs=tuple(inp for _, _, inp in roles),
        specs=tuple(BufSpec("row") if c in resident else BufSpec("tile")
                    for _, c, _ in roles),
        n_in=n_rows, out=f"{qplan.name}.agg", n_out=n_lanes * S,
        out_dtype=jnp.float32, name=f"reduce[{qplan.name}]")
    stages.append(red)

    prefuse = list(stages)
    fused = fusion.fuse(stages, final_out=red.out)

    # only ship what the fused program actually reads (a compressed-domain
    # predicate elides e.g. the dictionary buffer along with the decode)
    used: set[str] = set()
    for st in fused:
        used.update(getattr(st, "inputs", ()))
    operands: dict[str, np.ndarray] = {}
    buffers: list[ir_mod.BufferDef] = []
    meta_specs: list[ir_mod.MetaSpec] = []
    h = hashlib.sha1()
    for col in fused_cols:
        enc = encs[col]
        h.update(f"{col}:{ir_mod.structural_signature(enc)}".encode())
        for k, v in plan_mod.flat_buffers(enc, prefix=col).items():
            if k in used:
                operands[k] = v
                buffers.append(ir_mod.BufferDef(
                    name=k, shape=tuple(v.shape), dtype=np.dtype(v.dtype).str))
        for k, v in plan_mod.meta_operands(enc, prefix=col).items():
            if k in used:
                operands[k] = v
                meta_specs.append(ir_mod.MetaSpec(
                    name=k, shape=tuple(v.shape), dtype=np.dtype(v.dtype).str))
    for col in resident:
        h.update(f"row:{col}:{np.dtype(encs[col].dtype).str}".encode())
    h.update(qplan.digest().encode())

    graph = ir_mod.DecodeGraph(
        stages=fused, buffers=tuple(buffers), out=red.out,
        n_out=int(red.n_out), out_dtype="<f4",
        signature=h.hexdigest() + "+qfused", meta_specs=tuple(meta_specs),
        nesting=f"query[{qplan.name}]", fused=True)
    return FusedQuery(
        qplan=qplan, graph=graph, operands=operands,
        fused_cols=tuple(fused_cols), resident=tuple(resident),
        n_rows=n_rows, n_lanes=n_lanes, n_segments=S, prefuse_stages=prefuse)
