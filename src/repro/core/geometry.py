"""Device-geometry scheduling: the paper's <L, S, C> configuration vector, re-derived
for the TPU execution model (paper §4).

On a GPU, <L, S, C> = (main-loop iterations, threads per block, contiguous elements per
thread): the tile processed by one block is L*S*C elements.  TPUs have no independent
threads; the unit of scheduling is the VMEM block fetched per grid step of a
``pallas_call``.  We therefore map:

    L  -> iterations of the in-kernel loop over (S, C) sub-tiles (amortizes grid/DMA
          overhead exactly like the paper's thread main loop),
    S  -> sublane extent of the sub-tile (multiples of 8, the VPU sublane count),
    C  -> lane extent of the sub-tile (multiples of 128, the VPU lane count).

One grid step owns an (L*S, C) VMEM block; grid = ceil(N / (L*S*C)).  The product
L*S*C is the paper's "tile size".  Choosing <L,S,C> trades VMEM footprint, DMA
double-buffering efficiency and grid overhead -- the same trade the paper tunes per-GPU,
here tuned per TPU generation.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Callable, Iterable


@dataclasses.dataclass(frozen=True)
class Geometry:
    """The paper's <L, S, C> kernel configuration vector (TPU interpretation)."""

    L: int  # in-kernel loop iterations (grid-overhead amortization)
    S: int  # sublane extent of one sub-tile (multiple of 8)
    C: int  # lane extent of one sub-tile (multiple of 128)

    @property
    def tile(self) -> int:
        """Elements processed per grid step (the paper's L*S*C tile size)."""
        return self.L * self.S * self.C

    @property
    def block_shape(self) -> tuple[int, int]:
        """VMEM block shape for one grid step."""
        return (self.L * self.S, self.C)

    def vmem_bytes(self, itemsize: int, n_buffers: int = 2) -> int:
        """Approximate VMEM footprint (double-buffered in+out by default)."""
        return self.tile * itemsize * n_buffers * 2  # x2: pallas double-buffers DMA

    def grid(self, n: int) -> int:
        return max(1, math.ceil(n / self.tile))

    def __str__(self) -> str:  # <L,S,C> like the paper
        return f"<{self.L},{self.S},{self.C}>"


@dataclasses.dataclass(frozen=True)
class ChipSpec:
    """Per-chip resource table (the paper's per-GPU architectural features, §4/§5.5).

    TPU generations differ in VMEM capacity, HBM bandwidth, MXU throughput and
    grid-step overhead the same way MI50/A100/H100/MI300X differ in SM count, cache and
    wavefront size; this table is what makes a config "Native" to a chip.
    """

    name: str
    vmem_bytes: int            # per-core VMEM usable by one kernel
    sublanes: int              # VPU second-minor dim (8 on all current TPUs)
    lanes: int                 # VPU minor dim (128 on all current TPUs)
    hbm_gbps: float            # HBM bandwidth, GB/s
    peak_bf16_tflops: float    # MXU peak, TFLOP/s
    ici_gbps_per_link: float   # inter-chip link bandwidth, GB/s
    grid_step_overhead_ns: float  # per-grid-step scheduling + DMA setup cost
    vpu_elems_per_ns: float    # VPU elementwise throughput (elements/ns, 32-bit)
    host_link_gbps: float      # host<->device (PCIe) bandwidth, GB/s


# Resource tables for the chips this framework targets.  v5e numbers match the roofline
# constants mandated for this exercise; others are public-datasheet-scale figures used
# only for *relative* native-vs-shared config studies (paper Fig. 22 analogue).
CHIPS: dict[str, ChipSpec] = {
    "v4": ChipSpec("v4", vmem_bytes=16 * 2**20, sublanes=8, lanes=128,
                   hbm_gbps=1228.0, peak_bf16_tflops=275.0, ici_gbps_per_link=50.0,
                   grid_step_overhead_ns=250.0, vpu_elems_per_ns=2.4,
                   host_link_gbps=16.0),
    "v5e": ChipSpec("v5e", vmem_bytes=16 * 2**20, sublanes=8, lanes=128,
                    hbm_gbps=819.0, peak_bf16_tflops=197.0, ici_gbps_per_link=50.0,
                    grid_step_overhead_ns=200.0, vpu_elems_per_ns=1.9,
                    host_link_gbps=32.0),
    "v5p": ChipSpec("v5p", vmem_bytes=32 * 2**20, sublanes=8, lanes=128,
                    hbm_gbps=2765.0, peak_bf16_tflops=459.0, ici_gbps_per_link=100.0,
                    grid_step_overhead_ns=180.0, vpu_elems_per_ns=3.7,
                    host_link_gbps=32.0),
    "v6e": ChipSpec("v6e", vmem_bytes=32 * 2**20, sublanes=8, lanes=128,
                    hbm_gbps=1640.0, peak_bf16_tflops=918.0, ici_gbps_per_link=100.0,
                    grid_step_overhead_ns=150.0, vpu_elems_per_ns=3.9,
                    host_link_gbps=64.0),
}

DEFAULT_CHIP = "v5e"


def chip(name: str = DEFAULT_CHIP) -> ChipSpec:
    return CHIPS[name]


# ----------------------------------------------------------------------------- spaces
# Config spaces per pattern, powers of two only (paper Table 3).  The GPU table's
# warp-size lower bound on S becomes the sublane count; C's dtype coupling on GPU
# (4/dtype.size vectorization) becomes the lane multiple.

def fp_space(spec: ChipSpec, itemsize: int = 4) -> Iterable[Geometry]:
    """Fully-Parallel space: L in 2^0..2^4, S in {8..512}, C in {128..1024}."""
    for L in (1, 2, 4, 8, 16):
        for S in (8, 16, 32, 64, 128, 256, 512):
            for C in (128, 256, 512, 1024):
                g = Geometry(L, S, C)
                if g.vmem_bytes(itemsize) <= spec.vmem_bytes:
                    yield g


def gp_space(spec: ChipSpec, itemsize: int = 4) -> Iterable[Geometry]:
    """Group-Parallel space: output-centric tiles; L fixed small (the balanced
    decomposition makes group sizes irrelevant), S and C sized to VMEM."""
    for L in (1, 2, 4):
        for S in (8, 16, 32, 64, 128, 256, 512):
            for C in (128, 256, 512, 1024):
                g = Geometry(L, S, C)
                # expand kernels hold presum + values + out: 3 buffers
                if g.vmem_bytes(itemsize, n_buffers=3) <= spec.vmem_bytes:
                    yield g


def np_space(spec: ChipSpec, itemsize: int = 4) -> Iterable[Geometry]:
    """Non-Parallel space: S fixed to sublanes (the 'warp size' analogue), C = chunks
    per lane group, L = grid steps worth of chunk batches."""
    for L in (1, 2, 4, 8):
        for C in (128, 256, 512, 1024):
            g = Geometry(L, spec.sublanes, C)
            if g.vmem_bytes(itemsize, n_buffers=4) <= spec.vmem_bytes:
                yield g


SPACES: dict[str, Callable[..., Iterable[Geometry]]] = {
    "fp": fp_space,
    "gp": gp_space,
    "np": np_space,
}


# ------------------------------------------------------------------------- cost model
def analytic_cost_ns(pattern: str, geom: Geometry, n_elems: int, itemsize: int,
                     spec: ChipSpec, bytes_in: int | None = None,
                     bytes_out: int | None = None) -> float:
    """Analytic per-kernel cost model used for offline geometry tuning.

    Three terms, mirroring how the paper reasons about its config space:
      * HBM traffic time   (compulsory: bytes in + bytes out at hbm_gbps)
      * grid overhead      (grid steps x per-step cost; shrinks with larger L*S*C)
      * VPU time           (elementwise work; grows with poorly shaped tiles)
    The model is intentionally monotone in each of L, S, C until the VMEM cliff --
    the structure the paper's pruned search exploits (Table 3).
    """
    bytes_out = n_elems * itemsize if bytes_out is None else bytes_out
    bytes_in = bytes_out if bytes_in is None else bytes_in
    hbm_ns = (bytes_in + bytes_out) / spec.hbm_gbps  # GB/s == bytes/ns
    steps = geom.grid(n_elems)
    overhead_ns = steps * spec.grid_step_overhead_ns
    # VPU term: vector issue is per (sublanes x lanes) register; narrow C wastes lanes,
    # narrow S wastes sublanes.
    lane_eff = min(1.0, geom.C / spec.lanes) if geom.C < spec.lanes else 1.0
    sub_eff = min(1.0, geom.S / spec.sublanes)
    work_ns = n_elems / (spec.vpu_elems_per_ns * lane_eff * sub_eff)
    if pattern == "gp":
        work_ns *= 1.35   # binary search over presum adds VPU ops per element
    if pattern == "np":
        work_ns *= 4.0    # serial decode: table lookups + renorm selects per symbol
        # N.P. parallelism is bounded by chunks in flight = S*C per step
        chunk_par = geom.S * geom.C
        work_ns = max(work_ns, n_elems / max(1, chunk_par) * 2.0)
    # VMEM pressure cliff: double-buffering dies when the working set (same buffer
    # count the per-pattern config spaces use) no longer fits.
    n_buffers = {"fp": 2, "gp": 3, "np": 4}[pattern]
    if geom.vmem_bytes(itemsize, n_buffers=n_buffers) > spec.vmem_bytes:
        hbm_ns *= 4.0
    return hbm_ns + overhead_ns + work_ns


def native_config(pattern: str, spec: ChipSpec, n_elems: int = 1 << 24,
                  itemsize: int = 4) -> Geometry:
    """Best geometry under the analytic model -- a chip's 'Native Config' (§5.5)."""
    space = list(SPACES[pattern](spec, itemsize))
    return min(space, key=lambda g: analytic_cost_ns(pattern, g, n_elems, itemsize, spec))


@functools.lru_cache(maxsize=None)
def native_subtile(pattern: str, chip_name: str = DEFAULT_CHIP,
                   itemsize: int = 4) -> int:
    """S*C of the chip's native config: the elements one grid-step sub-tile
    covers (one L-loop iteration).  The planner's chunk-size ladder snaps
    element-chunk boundaries to multiples of this, so every streamed decode
    launch covers whole kernel tiles of the pattern it runs."""
    pat = pattern if pattern in SPACES else "fp"
    g = native_config(pat, CHIPS[chip_name], itemsize=itemsize)
    return int(g.S) * int(g.C)
