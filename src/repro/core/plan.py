"""Nesting Layer (paper §3.2): compression-plan trees and compressed blobs.

A ``Plan`` is a tree: a codec plus child plans attached to named output buffers of that
codec's encoder (paper Table 2, e.g. ``RLE[DeltaStride[...], Bit-packing]``).  Encoding
recursively compresses the designated buffers; the remaining *leaf* buffers are what
actually moves host->device.  Decoding lowers the tree post-order into a stage list
(``repro.core.patterns``) which the fusion pass then optimizes.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

from repro.core import registry
from repro.core.patterns import Stage


@dataclasses.dataclass
class Plan:
    codec: str
    params: dict[str, Any] = dataclasses.field(default_factory=dict)
    children: dict[str, "Plan"] = dataclasses.field(default_factory=dict)

    def describe(self) -> str:
        """Human-readable nesting string in the paper's Table-2 notation."""
        base = self.codec
        if not self.children:
            return base
        inner = ", ".join(f"{k}={v.describe()}" for k, v in self.children.items())
        return f"{base}[{inner}]"


def make_plan(codec: str, /, **children: "Plan | None") -> Plan:
    """Convenience constructor: ``make_plan('rle', counts=make_plan('bitpack'))``."""
    kids = {k: v for k, v in children.items() if v is not None}
    return Plan(codec, children=kids)


@dataclasses.dataclass
class Encoded:
    """A compressed blob: leaf buffers (transferred) + static metadata + children."""

    codec: str
    meta: dict[str, Any]
    buffers: dict[str, np.ndarray]
    children: dict[str, "Encoded"]
    n: int
    dtype: Any

    @property
    def compressed_nbytes(self) -> int:
        total = sum(int(b.nbytes) for b in self.buffers.values())
        return total + sum(c.compressed_nbytes for c in self.children.values())

    @property
    def plain_nbytes(self) -> int:
        return int(self.n) * int(np.dtype(self.dtype).itemsize)

    @property
    def ratio(self) -> float:
        """Compression ratio = plain / compressed (larger is better)."""
        c = self.compressed_nbytes
        return float("inf") if c == 0 else self.plain_nbytes / c


def encode(p: Plan, arr: np.ndarray) -> Encoded:
    codec = registry.get(p.codec)
    bufs, meta = codec.encode(np.asarray(arr), **p.params)
    children = {}
    for slot, sub in p.children.items():
        if slot not in bufs:
            raise KeyError(f"{p.codec} has no buffer slot '{slot}' "
                           f"(has {sorted(bufs)})")
        children[slot] = encode(sub, bufs.pop(slot))
    return Encoded(codec=p.codec, meta=meta, buffers=bufs, children=children,
                   n=int(arr.size), dtype=arr.dtype)


def decode_np(enc: Encoded) -> np.ndarray:
    """Pure-numpy recursive oracle (independent of the jnp/Pallas executors)."""
    codec = registry.get(enc.codec)
    bufs = dict(enc.buffers)
    for slot, child in enc.children.items():
        bufs[slot] = decode_np(child)
    return codec.decode_np(bufs, enc.meta, enc.n, enc.dtype)


def flat_buffers(enc: Encoded, prefix: str = "root") -> dict[str, np.ndarray]:
    """Leaf buffers under hierarchical names -- the arrays that move host->device."""
    out = {f"{prefix}.{k}": v for k, v in enc.buffers.items()}
    for slot, child in enc.children.items():
        out.update(flat_buffers(child, f"{prefix}/{slot}"))
    return out


def _meta_operand_names(codec, prefix: str) -> dict[str, str]:
    # "@" keeps operand names disjoint from buffer names (buffers never contain it)
    return {k: f"{prefix}.@{k}" for k in getattr(codec, "lifted_meta", {})}


def meta_operands(enc: Encoded, prefix: str = "root") -> dict[str, np.ndarray]:
    """Lifted meta values as (1,)-shaped arrays under their operand names.

    These are the runtime operands of the compiled program: hashed by dtype/shape
    only (``ir.MetaSpec``), fed by value at call time.  Integer values route through
    int64 so out-of-range bases wrap mod 2^32 exactly like the old baked constants.
    """
    codec = registry.get(enc.codec)
    out: dict[str, np.ndarray] = {}
    for key, dt in getattr(codec, "lifted_meta", {}).items():
        v = enc.meta[key]
        if np.issubdtype(np.dtype(dt), np.integer):
            out[f"{prefix}.@{key}"] = np.asarray([v], np.int64).astype(dt)
        else:
            out[f"{prefix}.@{key}"] = np.asarray([v], dt)
    for slot, child in enc.children.items():
        out.update(meta_operands(child, f"{prefix}/{slot}"))
    return out


def host_operands(enc: Encoded) -> dict[str, np.ndarray]:
    """Everything a compiled Program consumes: leaf buffers + lifted meta operands."""
    return {**flat_buffers(enc), **meta_operands(enc)}


def lower(enc: Encoded, prefix: str = "root", out_name: str | None = None) -> list[Stage]:
    """Lower a compressed blob to a stage list (children first, post-order)."""
    codec = registry.get(enc.codec)
    stages: list[Stage] = []
    buf_names: dict[str, str] = {k: f"{prefix}.{k}" for k in enc.buffers}
    for slot, child in enc.children.items():
        child_out = f"{prefix}/{slot}.decoded"
        stages.extend(lower(child, f"{prefix}/{slot}", out_name=child_out))
        buf_names[slot] = child_out
    out = out_name or f"{prefix}.decoded"
    stages.extend(codec.stages(enc, buf_names, out,
                               meta_names=_meta_operand_names(codec, prefix)))
    return stages


def lower_graph(enc: Encoded) -> "ir.DecodeGraph":
    """Lower a compressed blob to a DecodeGraph: the stage list plus buffer defs and
    the structural signature the ProgramCache keys on (repro.core.ir)."""
    from repro.core import ir

    return ir.graph_from_encoded(enc, lower(enc))
