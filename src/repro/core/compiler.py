"""ZipFlow compiler driver: DecodeGraph -> executable on-device program.

The compile pipeline is ``plan.lower_graph`` -> ``fusion.fuse_graph`` ->
``compile_graph``; compiled programs live in a ``ProgramCache`` keyed by the graph's
structural signature plus compile options, so N structurally identical columns share
ONE jitted executable (one trace, one XLA compile, one launch geometry) instead of
compiling per blob.  ``compile_decoder`` remains as the thin per-blob compatibility
shim over that pipeline.

Backends:
  * "jnp"      -- pure jax.numpy stages (reference semantics; fast on CPU; also what a
                  TPU falls back to when a shape is hostile to the Pallas kernels).
  * "pallas"   -- the Pallas TPU kernels of ``repro.kernels`` (interpret=True off-TPU).
  * "baseline" -- the nvCOMP role: fixed geometry, **no fusion**, every stage
                  materializes its output (paper §5.2/§5.3 baseline behaviour).
"""
from __future__ import annotations

import dataclasses
import functools
import threading
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core import fusion as fusion_mod
from repro.core import plan as plan_mod
from repro.core.geometry import DEFAULT_CHIP, Geometry, chip as chip_spec, native_config
from repro.core.ir import DecodeGraph
from repro.core.patterns import Aux, Stage


def _run_stage(st: Stage, bufs: dict[str, jnp.ndarray], backend: str,
               geoms: dict[str, Geometry], interpret: bool) -> jnp.ndarray:
    if backend == "pallas" and not isinstance(st, Aux):
        from repro.kernels import ops

        return ops.run_stage(st, bufs, geoms, interpret=interpret)
    return st.run_jnp(bufs)


BASELINE_GEOMS = {"fp": Geometry(1, 8, 128), "gp": Geometry(1, 8, 128),
                  "np": Geometry(1, 8, 128)}


@dataclasses.dataclass
class Program:
    """One compiled decode program, shared by every blob with the same signature.

    ``fn`` decodes a single column's buffer dict; ``batched`` decodes a stack of
    same-signature columns in one launch (vmap over the leading axis) -- built lazily
    because most programs only ever see one column.
    """

    fn: Callable[[dict[str, jnp.ndarray]], jnp.ndarray]
    raw_fn: Callable[[dict[str, jnp.ndarray]], jnp.ndarray]  # unjitted decode body
    graph: DecodeGraph
    backend: str
    jit: bool = True
    calls: int = 0              # single-column executions (0 => next call traces)
    batched_calls: int = 0      # batched executions
    _batched: Callable | None = dataclasses.field(default=None, repr=False)

    @property
    def signature(self) -> str:
        return self.graph.signature

    @property
    def stages(self) -> list[Stage]:
        return self.graph.stages

    @property
    def n_kernels(self) -> int:
        return len(self.graph.stages)

    def __call__(self, bufs: dict[str, jnp.ndarray]) -> jnp.ndarray:
        self.calls += 1
        return self.fn(bufs)

    def batched(self, stacked: dict[str, jnp.ndarray]) -> jnp.ndarray:
        """Decode K same-signature columns stacked on a new leading axis: one
        launch instead of K (multi-column batched decode)."""
        if self._batched is None:
            vfn = jax.vmap(self.raw_fn)
            self._batched = jax.jit(vfn) if self.jit else vfn
        self.batched_calls += 1
        return self._batched(stacked)


def compile_graph(graph: DecodeGraph, backend: str = "jnp",
                  chip: str = DEFAULT_CHIP,
                  geometry: dict[str, Geometry] | None = None,
                  interpret: bool | None = None,
                  jit: bool = True) -> Program:
    """Compile a DecodeGraph to a Program (no caching -- see ProgramCache)."""
    spec = chip_spec(chip)
    geoms = geometry or {p: native_config(p, spec) for p in ("fp", "gp", "np")}
    if backend == "baseline":
        # fixed library geometry, deliberately not adapted to the chip (paper §5.2)
        geoms = dict(BASELINE_GEOMS)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    stages = graph.stages

    def decode(bufs: dict[str, jnp.ndarray]) -> jnp.ndarray:
        env = dict(bufs)
        out = None
        for st in stages:
            out = _run_stage(st, env, backend, geoms, interpret)
            env[st.out] = out
        return out

    fn = jax.jit(decode) if jit else decode
    return Program(fn=fn, raw_fn=decode, graph=graph, backend=backend, jit=jit)


def _geometry_key(geometry: dict[str, Geometry] | None):
    if geometry is None:
        return None
    return tuple(sorted(geometry.items()))


class ProgramCache:
    """Signature-keyed cache of compiled programs: one jit per *structure*.

    The key is (graph signature, backend, chip, geometry override, interpret, jit);
    everything value-dependent is already folded into the signature by the IR layer.
    ``max_programs`` bounds the cache LRU-style (None = unbounded): long-lived
    servers seeing unbounded shape variety (e.g. one signature per prompt length)
    should set it so old programs are evicted instead of retained forever.
    """

    def __init__(self, max_programs: int | None = None):
        self._programs: dict[tuple, Program] = {}   # insertion order = LRU order
        self._lock = threading.Lock()
        self._compiling: dict[tuple, threading.Lock] = {}   # per-key compile guard
        self.max_programs = max_programs
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._programs)

    @property
    def stats(self) -> dict[str, int]:
        return {"programs": len(self._programs), "hits": self.hits,
                "misses": self.misses}

    def clear(self) -> None:
        with self._lock:
            self._programs.clear()
            self._compiling.clear()
            self.hits = self.misses = 0

    def get(self, graph: DecodeGraph, backend: str = "jnp",
            chip: str = DEFAULT_CHIP,
            geometry: dict[str, Geometry] | None = None,
            interpret: bool | None = None, jit: bool = True) -> Program:
        key = (graph.signature, backend, chip, _geometry_key(geometry),
               interpret, jit)
        with self._lock:
            prog = self._programs.get(key)
            if prog is not None:
                self.hits += 1
                if self.max_programs is not None:       # refresh LRU position
                    self._programs[key] = self._programs.pop(key)
                return prog
            key_lock = self._compiling.setdefault(key, threading.Lock())
        # serialize same-key compiles (different keys still compile concurrently)
        # so racing callers never duplicate a trace+XLA compile
        with key_lock:
            try:
                with self._lock:
                    prog = self._programs.get(key)
                    if prog is not None:
                        self.hits += 1
                        if self.max_programs is not None:
                            self._programs[key] = self._programs.pop(key)
                        return prog
                prog = compile_graph(graph, backend=backend, chip=chip,
                                     geometry=geometry, interpret=interpret,
                                     jit=jit)
                with self._lock:
                    self._programs[key] = prog
                    self.misses += 1
                    while (self.max_programs is not None
                           and len(self._programs) > self.max_programs):
                        self._programs.pop(next(iter(self._programs)))
            finally:
                with self._lock:
                    self._compiling.pop(key, None)
        return prog


# Process-wide default cache: the ``compile_decoder`` shim and every executor that
# doesn't bring its own cache share it, so e.g. 100 same-plan columns anywhere in the
# process trace and XLA-compile exactly once.  Deliberately unbounded: analytics and
# benchmark workloads see a bounded set of structures.  A long-lived process decoding
# unbounded shape variety should bring its own ``ProgramCache(max_programs=...)``
# (ServeEngine's default executor does).
DEFAULT_CACHE = ProgramCache()


def build_graph(enc: plan_mod.Encoded, fuse: bool = True) -> DecodeGraph:
    """Lower + (optionally) fuse: the front half of the compile pipeline."""
    graph = plan_mod.lower_graph(enc)
    return fusion_mod.fuse_graph(graph) if fuse else graph


def compile_blob(enc: plan_mod.Encoded, backend: str = "jnp", fuse: bool = True,
                 chip: str = DEFAULT_CHIP,
                 geometry: dict[str, Geometry] | None = None,
                 interpret: bool | None = None, jit: bool = True,
                 cache: ProgramCache | None = None) -> Program:
    """Blob -> cached Program (the modern entry point)."""
    if backend == "baseline":
        fuse = False
    graph = build_graph(enc, fuse=fuse)
    cache = DEFAULT_CACHE if cache is None else cache
    return cache.get(graph, backend=backend, chip=chip, geometry=geometry,
                     interpret=interpret, jit=jit)


# --------------------------------------------------------------- compatibility shim

@dataclasses.dataclass
class CompiledDecoder:
    """Legacy per-blob handle; now a thin view over a cached Program."""

    fn: Callable[[dict[str, jnp.ndarray]], jnp.ndarray]
    stages: list[Stage]
    backend: str
    n_kernels: int
    program: Program | None = None

    def __call__(self, bufs: dict[str, jnp.ndarray]) -> jnp.ndarray:
        if self.program is not None:   # keep Program.calls (cold-detection) honest
            return self.program(bufs)
        return self.fn(bufs)


def compile_decoder(enc: plan_mod.Encoded, backend: str = "jnp", fuse: bool = True,
                    chip: str = DEFAULT_CHIP,
                    geometry: dict[str, Geometry] | None = None,
                    interpret: bool | None = None,
                    jit: bool = True) -> CompiledDecoder:
    prog = compile_blob(enc, backend=backend, fuse=fuse, chip=chip,
                        geometry=geometry, interpret=interpret, jit=jit)
    return CompiledDecoder(fn=prog.fn, stages=prog.stages, backend=backend,
                           n_kernels=prog.n_kernels, program=prog)


def device_buffers(enc: plan_mod.Encoded, device=None,
                   sharding=None) -> dict[str, jnp.ndarray]:
    """Move a blob's leaf buffers host->device (the compressed transfer itself)."""
    flat = plan_mod.flat_buffers(enc)
    put = functools.partial(jax.device_put, device=sharding or device)
    return {k: put(v) for k, v in flat.items()}


def decode_on_device(enc: plan_mod.Encoded, backend: str = "jnp",
                     **kw: Any) -> jnp.ndarray:
    """One-shot helper: transfer + decode."""
    dec = compile_decoder(enc, backend=backend, **kw)
    return dec(device_buffers(enc))
