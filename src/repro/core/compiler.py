"""ZipFlow compiler driver: compressed blob -> executable on-device decoder.

``compile_decoder`` lowers a blob's plan tree to pattern stages, runs the fusion pass,
binds a device geometry per stage (native config of the target chip unless overridden),
and returns a jitted function ``bufs -> decoded array``.

Backends:
  * "jnp"      -- pure jax.numpy stages (reference semantics; fast on CPU; also what a
                  TPU falls back to when a shape is hostile to the Pallas kernels).
  * "pallas"   -- the Pallas TPU kernels of ``repro.kernels`` (interpret=True off-TPU).
  * "baseline" -- the nvCOMP role: fixed geometry, **no fusion**, every stage
                  materializes its output (paper §5.2/§5.3 baseline behaviour).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core import fusion as fusion_mod
from repro.core import plan as plan_mod
from repro.core.geometry import DEFAULT_CHIP, Geometry, chip as chip_spec, native_config
from repro.core.patterns import Aux, FullyParallel, GroupParallel, NonParallel, Stage


@dataclasses.dataclass
class CompiledDecoder:
    fn: Callable[[dict[str, jnp.ndarray]], jnp.ndarray]
    stages: list[Stage]
    backend: str
    n_kernels: int

    def __call__(self, bufs: dict[str, jnp.ndarray]) -> jnp.ndarray:
        return self.fn(bufs)


def _run_stage(st: Stage, bufs: dict[str, jnp.ndarray], backend: str,
               geoms: dict[str, Geometry], interpret: bool) -> jnp.ndarray:
    if backend == "pallas" and not isinstance(st, Aux):
        from repro.kernels import ops

        return ops.run_stage(st, bufs, geoms, interpret=interpret)
    return st.run_jnp(bufs)


def compile_decoder(enc: plan_mod.Encoded, backend: str = "jnp", fuse: bool = True,
                    chip: str = DEFAULT_CHIP,
                    geometry: dict[str, Geometry] | None = None,
                    interpret: bool | None = None,
                    jit: bool = True) -> CompiledDecoder:
    if backend == "baseline":
        fuse = False
    stages = plan_mod.lower(enc)
    final_out = stages[-1].out
    if fuse:
        stages = fusion_mod.fuse(stages, final_out=final_out)
    spec = chip_spec(chip)
    geoms = geometry or {p: native_config(p, spec) for p in ("fp", "gp", "np")}
    if backend == "baseline":
        # fixed library geometry, deliberately not adapted to the chip (paper §5.2)
        geoms = {"fp": Geometry(1, 8, 128), "gp": Geometry(1, 8, 128),
                 "np": Geometry(1, 8, 128)}
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    def decode(bufs: dict[str, jnp.ndarray]) -> jnp.ndarray:
        env = dict(bufs)
        out = None
        for st in stages:
            out = _run_stage(st, env, backend, geoms, interpret)
            env[st.out] = out
        return out

    fn = jax.jit(decode) if jit else decode
    return CompiledDecoder(fn=fn, stages=stages, backend=backend,
                           n_kernels=len(stages))


def device_buffers(enc: plan_mod.Encoded, device=None,
                   sharding=None) -> dict[str, jnp.ndarray]:
    """Move a blob's leaf buffers host->device (the compressed transfer itself)."""
    flat = plan_mod.flat_buffers(enc)
    put = functools.partial(jax.device_put, device=sharding or device)
    return {k: put(v) for k, v in flat.items()}


def decode_on_device(enc: plan_mod.Encoded, backend: str = "jnp",
                     **kw: Any) -> jnp.ndarray:
    """One-shot helper: transfer + decode."""
    dec = compile_decoder(enc, backend=backend, **kw)
    return dec(device_buffers(enc))
