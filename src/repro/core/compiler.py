"""ZipFlow compiler driver: DecodeGraph -> executable on-device program.

The compile pipeline is ``plan.lower_graph`` -> ``fusion.fuse_graph`` ->
``compile_graph``; compiled programs live in a ``ProgramCache`` keyed by the graph's
STRUCTURE-ONLY signature plus compile options, so N structurally identical columns
share ONE jitted executable (one trace, one XLA compile, one launch geometry) even
when their data-dependent meta differs: programs are *called* with an operand pytree
(leaf buffers + lifted meta scalars, ``plan.host_operands``), never specialized on
meta values.  ``compile_decoder`` remains as the thin per-blob compatibility shim
over that pipeline; ``get_chunk``/``compile_chunk_graph`` build the per-chunk decode
programs the streaming executor launches chunk-by-chunk.

Backends:
  * "jnp"      -- pure jax.numpy stages (reference semantics; fast on CPU; also what a
                  TPU falls back to when a shape is hostile to the Pallas kernels).
  * "pallas"   -- the Pallas TPU kernels of ``repro.kernels`` (interpret=True off-TPU).
  * "baseline" -- the nvCOMP role: fixed geometry, **no fusion**, every stage
                  materializes its output (paper §5.2/§5.3 baseline behaviour).
"""
from __future__ import annotations

import dataclasses
import functools
import threading
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core import fusion as fusion_mod
from repro.core import plan as plan_mod
from repro.core.geometry import DEFAULT_CHIP, Geometry, chip as chip_spec, native_config
from repro.core.ir import (DecodeGraph, element_chunk_layout, group_chunk_layout,
                           query_chunk_layout)
from repro.core.patterns import Aux, Ctx, GroupParallel, Stage


def _run_stage(st: Stage, bufs: dict[str, jnp.ndarray], backend: str,
               geoms: dict[str, Geometry], interpret: bool) -> jnp.ndarray:
    if backend == "pallas" and not isinstance(st, Aux):
        from repro.kernels import ops

        return ops.run_stage(st, bufs, geoms, interpret=interpret)
    return st.run_jnp(bufs)


BASELINE_GEOMS = {"fp": Geometry(1, 8, 128), "gp": Geometry(1, 8, 128),
                  "np": Geometry(1, 8, 128)}


@dataclasses.dataclass
class Program:
    """One compiled decode program, shared by every blob with the same signature.

    ``fn`` decodes a single column's operand dict (leaf buffers + lifted meta
    scalars); ``batched`` decodes a stack of same-signature columns in one launch
    (vmap over the leading axis -- meta operands stack and vmap with the buffers)
    -- built lazily because most programs only ever see one column.
    """

    fn: Callable[[dict[str, jnp.ndarray]], jnp.ndarray]
    raw_fn: Callable[[dict[str, jnp.ndarray]], jnp.ndarray]  # unjitted decode body
    graph: DecodeGraph
    backend: str
    jit: bool = True
    calls: int = 0              # single-column executions (0 => next call traces)
    batched_calls: int = 0      # batched executions
    _batched: Callable | None = dataclasses.field(default=None, repr=False)

    @property
    def signature(self) -> str:
        return self.graph.signature

    @property
    def stages(self) -> list[Stage]:
        return self.graph.stages

    @property
    def n_kernels(self) -> int:
        return len(self.graph.stages)

    def __call__(self, bufs: dict[str, jnp.ndarray]) -> jnp.ndarray:
        self.calls += 1
        return self.fn(bufs)

    def batched(self, stacked: dict[str, jnp.ndarray]) -> jnp.ndarray:
        """Decode K same-signature columns stacked on a new leading axis: one
        launch instead of K (multi-column batched decode)."""
        if self._batched is None:
            vfn = jax.vmap(self.raw_fn)
            self._batched = jax.jit(vfn) if self.jit else vfn
        self.batched_calls += 1
        return self._batched(stacked)


def compile_graph(graph: DecodeGraph, backend: str = "jnp",
                  chip: str = DEFAULT_CHIP,
                  geometry: dict[str, Geometry] | None = None,
                  interpret: bool | None = None,
                  jit: bool = True) -> Program:
    """Compile a DecodeGraph to a Program (no caching -- see ProgramCache)."""
    spec = chip_spec(chip)
    geoms = geometry or {p: native_config(p, spec) for p in ("fp", "gp", "np")}
    if backend == "baseline":
        # fixed library geometry, deliberately not adapted to the chip (paper §5.2)
        geoms = dict(BASELINE_GEOMS)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    stages = graph.stages

    def decode(bufs: dict[str, jnp.ndarray]) -> jnp.ndarray:
        env = dict(bufs)
        out = None
        for st in stages:
            out = _run_stage(st, env, backend, geoms, interpret)
            env[st.out] = out
        return out

    fn = jax.jit(decode) if jit else decode
    return Program(fn=fn, raw_fn=decode, graph=graph, backend=backend, jit=jit)


@dataclasses.dataclass
class ChunkProgram:
    """Per-chunk decode program: one launch decodes output elements
    [out_start, out_start + chunk_elems) from the chunk's buffer slices.

    ``fn(bufs, out_start)`` takes the chunk's tile-leaf slices plus the column's
    whole-resident buffers/meta operands, with ``out_start`` a traced scalar so the
    same program serves every chunk at its offset.  Executed with the stage
    closures' jnp semantics (the fns are backend-agnostic by construction)."""

    fn: Callable[[dict[str, jnp.ndarray], Any], jnp.ndarray]
    graph: DecodeGraph
    chunk_elems: int
    jit: bool = True
    calls: int = 0

    def __call__(self, bufs: dict[str, jnp.ndarray], out_start) -> jnp.ndarray:
        self.calls += 1
        return self.fn(bufs, out_start)


def compile_chunk_graph(graph: DecodeGraph, chunk_elems: int,
                        jit: bool = True) -> ChunkProgram:
    """Compile the per-chunk variant of an element-chunkable graph.

    Every stage is Fully-Parallel (``element_chunk_layout`` guarantees it), so the
    chunk evaluates each stage closure at the chunk's global output indices with
    tile inputs sliced to the chunk window: exactly the addressing the Pallas grid
    tiles use, at transfer-chunk granularity.  Tile origins for operand-driven
    ratios (bitpack's ``bit_width``) are computed from the traced operand, so one
    program serves columns with different widths too."""
    layout = element_chunk_layout(graph)
    if layout is None:
        raise ValueError(f"graph {graph.nesting!r} is not element-chunkable")
    stages = graph.stages

    def decode_chunk(bufs: dict[str, jnp.ndarray], out_start) -> jnp.ndarray:
        out_idx = out_start + jnp.arange(chunk_elems, dtype=jnp.int32)
        env = dict(bufs)
        produced: set[str] = set()
        out = None
        for st in stages:
            starts = []
            for nm, spec in zip(st.inputs, st.specs):
                if nm in produced or spec.kind == "full":
                    starts.append(None)     # positionally aligned / whole-resident
                elif spec.num_op:
                    num = env[spec.num_op][0]
                    starts.append((out_start * num) // spec.den)
                else:
                    starts.append((out_start * spec.num) // spec.den)
            ctx = Ctx(out_idx=out_idx, starts=tuple(starts))
            out = st.fn(ctx, *[env[nm] for nm in st.inputs]).astype(st.out_dtype)
            env[st.out] = out
            produced.add(st.out)
        return out

    fn = jax.jit(decode_chunk) if jit else decode_chunk
    return ChunkProgram(fn=fn, graph=graph, chunk_elems=int(chunk_elems), jit=jit)


@dataclasses.dataclass
class QueryChunkProgram:
    """Per-chunk fused-query program: one launch evaluates scan-filter-aggregate
    over item rows [out_start, out_start + chunk_elems) and returns a PARTIAL
    AGGREGATE vector (``graph.n_out`` accumulator lanes), not decoded rows.
    The executor sums partials across chunks on device; the decompressed
    columns never exist at HBM.  Body and tail chunks share programs per size
    like ``ChunkProgram``."""

    fn: Callable[[dict[str, jnp.ndarray], Any], jnp.ndarray]
    graph: DecodeGraph
    chunk_elems: int
    jit: bool = True
    calls: int = 0

    def __call__(self, bufs: dict[str, jnp.ndarray], out_start) -> jnp.ndarray:
        self.calls += 1
        return self.fn(bufs, out_start)


def compile_query_chunk_graph(graph: DecodeGraph, chunk_elems: int,
                              jit: bool = True) -> QueryChunkProgram:
    """Compile the per-chunk variant of a fused-query (Reduce-terminated) graph.

    Same addressing as ``compile_chunk_graph`` over the Reduce's ITEM axis,
    plus "row" inputs: decoded resident columns ride whole and are gathered at
    the chunk's global row indices (start 0)."""
    layout = query_chunk_layout(graph)
    if layout is None:
        raise ValueError(f"graph {graph.nesting!r} is not query-chunkable")
    stages = graph.stages
    # single-chunk program: the only start ever passed is 0, so bake it in as a
    # Python int -- every input offset folds to a constant and XLA's gather
    # simplifier turns ``block[iota - 0]`` into a plain read, where a traced
    # start forces real gathers through the whole fused body (measurably
    # slower on CPU)
    static0 = int(chunk_elems) >= int(stages[-1].n_in)

    def partial_chunk(bufs: dict[str, jnp.ndarray], out_start) -> jnp.ndarray:
        if static0:
            out_start = 0
        out_idx = out_start + jnp.arange(chunk_elems, dtype=jnp.int32)
        env = dict(bufs)
        produced: set[str] = set()
        out = None
        for st in stages:
            starts = []
            for nm, spec in zip(st.inputs, st.specs):
                if nm in produced or spec.kind == "full":
                    starts.append(None)     # positionally aligned / whole-resident
                elif spec.kind == "row":
                    starts.append(0)        # decoded resident: global gather
                elif static0:
                    starts.append(0)
                elif spec.num_op:
                    num = env[spec.num_op][0]
                    starts.append((out_start * num) // spec.den)
                else:
                    starts.append((out_start * spec.num) // spec.den)
            ctx = Ctx(out_idx=out_idx, starts=tuple(starts))
            out = st.fn(ctx, *[env[nm] for nm in st.inputs]).astype(st.out_dtype)
            env[st.out] = out
            produced.add(st.out)
        return out

    fn = jax.jit(partial_chunk) if jit else partial_chunk
    return QueryChunkProgram(fn=fn, graph=graph, chunk_elems=int(chunk_elems),
                             jit=jit)


# ------------------------------------------------------- group-boundary chunks

@dataclasses.dataclass
class PrologueProgram:
    """One-shot decode of everything upstream of a graph's group stage: presum
    auxes and nested child decodes, over whole-resident leaves.  Returns the
    resident intermediates the per-span launches gather from."""

    fn: Callable[[dict[str, jnp.ndarray]], dict[str, jnp.ndarray]]
    graph: DecodeGraph
    jit: bool = True
    calls: int = 0

    def __call__(self, bufs: dict[str, jnp.ndarray]) -> dict[str, jnp.ndarray]:
        self.calls += 1
        return self.fn(bufs)


def compile_group_prologue(graph: DecodeGraph, jit: bool = True
                           ) -> PrologueProgram | None:
    """Compile the prologue of a group-chunkable graph (None when the group
    stage is first and nothing precedes it, e.g. plain ANS)."""
    layout = group_chunk_layout(graph)
    if layout is None:
        raise ValueError(f"graph {graph.nesting!r} is not group-chunkable")
    if layout.stage_index == 0 or not layout.resident:
        return None
    pro = graph.stages[: layout.stage_index]
    needed = layout.resident

    def run_prologue(bufs: dict[str, jnp.ndarray]) -> dict[str, jnp.ndarray]:
        env = dict(bufs)
        for st in pro:
            env[st.out] = st.run_jnp(env)
        return {nm: env[nm] for nm in needed}

    fn = jax.jit(run_prologue) if jit else run_prologue
    return PrologueProgram(fn=fn, graph=graph, jit=jit)


@dataclasses.dataclass
class GroupChunkProgram:
    """Per-span decode program for group-boundary chunking: one launch decodes
    the ``g_size`` whole groups starting at group ``g_start``, producing
    ``pad_elems`` output elements of which the first ``n_valid`` are real
    (uneven group sizes pad body launches to a shared shape; the executor trims
    before concatenating).  ``out_start``/``g_start``/``n_valid`` are traced
    scalars, so ONE program serves every body span (and a second the tail)."""

    fn: Callable[..., jnp.ndarray]
    graph: DecodeGraph
    g_size: int
    pad_elems: int
    jit: bool = True
    calls: int = 0

    def __call__(self, bufs: dict[str, jnp.ndarray], out_start, g_start,
                 n_valid) -> jnp.ndarray:
        self.calls += 1
        return self.fn(bufs, out_start, g_start, n_valid)


def compile_group_chunk_graph(graph: DecodeGraph, g_size: int, pad_elems: int,
                              jit: bool = True) -> GroupChunkProgram:
    """Compile the per-span variant of a group-chunkable graph.

    The group stage re-evaluates its closures at the span's GLOBAL output
    indices: a Group-Parallel span searches the whole-resident presum (so group
    id and in-group position are exactly the whole-column values) and gathers
    sliced value leaves at span-local offsets; a Non-Parallel span lockstep-
    decodes its own column slice of the stripe.  Trailing Fully-Parallel stages
    use the element path's addressing.  Bitwise equality with whole-column
    decode holds by construction: same closures, same global indices, exact
    group-aligned slices."""
    layout = group_chunk_layout(graph)
    if layout is None:
        raise ValueError(f"graph {graph.nesting!r} is not group-chunkable")
    gst = graph.stages[layout.stage_index]
    post = graph.stages[layout.stage_index + 1:]
    g_size = int(g_size)
    pad_elems = int(pad_elems)

    def decode_span(bufs: dict[str, jnp.ndarray], out_start, g_start,
                    n_valid) -> jnp.ndarray:
        env = dict(bufs)
        j = jnp.arange(pad_elems, dtype=jnp.int32)
        # clamp padding lanes to the last valid element: always in-bounds, and
        # the executor trims [:n_valid] before concatenation
        out_idx = out_start + jnp.minimum(j, jnp.maximum(n_valid - 1, 0))
        if isinstance(gst, GroupParallel):
            presum = env[gst.presum]
            g = jnp.searchsorted(presum, out_idx, side="right").astype(
                jnp.int32) - 1
            pos = out_idx - presum[g]
            # span-time value grafts: re-evaluate the producer closure at the
            # span's global group indices over its sliced primary leaf -- the
            # block then reads exactly like a sliced value input starting at
            # g_start (bitwise the whole-column intermediate at those indices)
            for nm, gi in layout.span_graft.items():
                p = graph.stages[gi]
                gg = g_start + jnp.arange(g_size, dtype=jnp.int32)
                p_starts = []
                for i_nm, i_spec in zip(p.inputs, p.specs):
                    if i_spec.kind == "full":
                        p_starts.append(None)
                    elif i_spec.num_op:
                        p_starts.append(
                            (g_start * env[i_spec.num_op][0]) // i_spec.den)
                    else:
                        p_starts.append((g_start * i_spec.num) // i_spec.den)
                env[nm] = p.fn(Ctx(out_idx=gg, starts=tuple(p_starts)),
                               *[env[i] for i in p.inputs])
            starts = []
            for nm, spec in zip(gst.value_inputs, gst.value_specs):
                if nm in layout.span_graft:
                    starts.append(g_start)   # local block begins at the span
                elif nm not in layout.sliced:
                    starts.append(0)
                elif spec.num_op:
                    # operand-driven ratio (bitpack's bit_width): same floor
                    # formula the schedule builder slices with, traced so one
                    # program serves every span
                    starts.append((g_start * env[spec.num_op][0]) // spec.den)
                else:
                    starts.append((g_start * spec.num) // spec.den)
            starts = tuple(starts)
            ctx = Ctx(out_idx=out_idx, starts=starts)
            gval = gst.value_fn(ctx, g, *[env[nm] for nm in gst.value_inputs])
            extras = [env[nm] for nm in gst.extra_inputs]
            out = gst.map_fn(ctx, gval, pos, g, *extras).astype(gst.out_dtype)
        else:                                   # NonParallel span
            from repro.algos.ans import decode_chunks_jnp  # avoids import cycle

            syms = decode_chunks_jnp(
                env[gst.streams], env[gst.states], env[gst.sym_tab],
                env[gst.freq_tab], env[gst.cum_tab], gst.chunk_size)
            flat = syms.reshape(-1)             # g_size * chunk_size local bytes
            byte0 = g_start * gst.chunk_size
            if gst.out_map is not None:
                bctx = Ctx(out_idx=byte0 + jnp.arange(flat.shape[0],
                                                      dtype=jnp.int32),
                           starts=(None,))
                flat = gst.out_map(bctx, flat)
            out = flat.astype(gst.out_dtype)
            if not post:                        # final out must be pad-shaped
                out = out[jnp.minimum(j, jnp.maximum(n_valid - 1, 0))]
        env[gst.out] = out
        produced = {gst.out}
        for st in post:
            starts = []
            for nm, spec in zip(st.inputs, st.specs):
                if spec.kind == "full":
                    starts.append(None)
                elif nm in produced:
                    # local intermediate whose global origin is the span start
                    starts.append((out_start * spec.num) // spec.den)
                else:
                    starts.append(None)
            ctx = Ctx(out_idx=out_idx, starts=tuple(starts))
            out = st.fn(ctx, *[env[nm] for nm in st.inputs]).astype(st.out_dtype)
            env[st.out] = out
            produced.add(st.out)
        return out

    fn = jax.jit(decode_span) if jit else decode_span
    return GroupChunkProgram(fn=fn, graph=graph, g_size=g_size,
                             pad_elems=pad_elems, jit=jit)


def _geometry_key(geometry: dict[str, Geometry] | None):
    if geometry is None:
        return None
    return tuple(sorted(geometry.items()))


class ProgramCache:
    """Signature-keyed cache of compiled programs: one jit per *structure*.

    The key is (graph signature, backend, chip, geometry override, interpret, jit);
    everything value-dependent is already folded into the signature by the IR layer.
    ``max_programs`` bounds the cache LRU-style (None = unbounded): long-lived
    servers seeing unbounded shape variety (e.g. one signature per prompt length)
    should set it so old programs are evicted instead of retained forever.
    """

    def __init__(self, max_programs: int | None = None):
        self._programs: dict[tuple, Any] = {}   # insertion order = LRU order
        self._lock = threading.Lock()
        self._compiling: dict[tuple, threading.Lock] = {}   # per-key compile guard
        self.max_programs = max_programs
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        with self._lock:
            return len(self._programs)

    @property
    def stats(self) -> dict[str, int]:
        # snapshot under the lock: concurrent submitters share one cache, and a
        # torn read (hits bumped, programs not yet) would miscount reuse
        with self._lock:
            return {"programs": len(self._programs), "hits": self.hits,
                    "misses": self.misses, "evictions": self.evictions}

    def clear(self) -> None:
        with self._lock:
            self._programs.clear()
            self._compiling.clear()
            self.hits = self.misses = self.evictions = 0

    def _lookup(self, key: tuple):
        """Under self._lock: hit bookkeeping + LRU refresh."""
        prog = self._programs.get(key)
        if prog is not None:
            self.hits += 1
            if self.max_programs is not None:       # refresh LRU position
                self._programs[key] = self._programs.pop(key)
        return prog

    def _get(self, key: tuple, build: Callable[[], Any]):
        # Double-checked: fast-path lookup under self._lock, then a per-key
        # compile lock, then a RE-lookup under self._lock before building --
        # a racing thread that lost the key_lock race finds the winner's
        # program on the second check instead of compiling again.  The
        # DispatchEngine relies on this invariant (exactly one trace+compile
        # per signature no matter how many threads hit the cache), and its
        # transfer workers never call into here at all -- only dispatcher
        # threads trace.
        with self._lock:
            prog = self._lookup(key)
            if prog is not None:
                return prog
            key_lock = self._compiling.setdefault(key, threading.Lock())
        # serialize same-key compiles (different keys still compile concurrently)
        # so racing callers never duplicate a trace+XLA compile
        with key_lock:
            try:
                with self._lock:
                    prog = self._lookup(key)
                    if prog is not None:
                        return prog
                prog = build()
                with self._lock:
                    self._programs[key] = prog
                    self.misses += 1
                    while (self.max_programs is not None
                           and len(self._programs) > self.max_programs):
                        self._programs.pop(next(iter(self._programs)))
                        self.evictions += 1
            finally:
                with self._lock:
                    self._compiling.pop(key, None)
        return prog

    def get(self, graph: DecodeGraph, backend: str = "jnp",
            chip: str = DEFAULT_CHIP,
            geometry: dict[str, Geometry] | None = None,
            interpret: bool | None = None, jit: bool = True) -> Program:
        key = (graph.signature, backend, chip, _geometry_key(geometry),
               interpret, jit)
        return self._get(key, lambda: compile_graph(
            graph, backend=backend, chip=chip, geometry=geometry,
            interpret=interpret, jit=jit))

    def get_chunk(self, graph: DecodeGraph, chunk_elems: int,
                  jit: bool = True) -> ChunkProgram:
        """Cached per-chunk program: one per (structure, chunk size), shared by
        every chunk at that size across all same-signature columns."""
        key = (graph.signature, "chunk", int(chunk_elems), jit)
        return self._get(key, lambda: compile_chunk_graph(
            graph, chunk_elems, jit=jit))

    def get_query_chunk(self, graph: DecodeGraph, chunk_elems: int,
                        jit: bool = True) -> QueryChunkProgram:
        """Cached fused-query chunk program: one per (structure, chunk size);
        body chunks share one program, the uneven tail gets a second."""
        key = (graph.signature, "qchunk", int(chunk_elems), jit)
        return self._get(key, lambda: compile_query_chunk_graph(
            graph, chunk_elems, jit=jit))

    def get_group_chunk(self, graph: DecodeGraph, g_size: int, pad_elems: int,
                        jit: bool = True) -> GroupChunkProgram:
        """Cached group-span program: one per (structure, groups-per-span,
        padded output shape) -- every body span of a column (and of every
        same-signature column with the same span geometry) shares one trace."""
        key = (graph.signature, "gchunk", int(g_size), int(pad_elems), jit)
        return self._get(key, lambda: compile_group_chunk_graph(
            graph, g_size, pad_elems, jit=jit))

    def get_group_prologue(self, graph: DecodeGraph,
                           jit: bool = True) -> PrologueProgram | None:
        """Cached prologue program for a group-chunkable graph; None when the
        group stage is first (nothing upstream to decode)."""
        layout = group_chunk_layout(graph)
        if layout is None:
            raise ValueError(f"graph {graph.nesting!r} is not group-chunkable")
        if layout.stage_index == 0 or not layout.resident:
            return None
        key = (graph.signature, "gprologue", jit)
        return self._get(key, lambda: compile_group_prologue(graph, jit=jit))


# Process-wide default cache: the ``compile_decoder`` shim and every executor that
# doesn't bring its own cache share it, so e.g. 100 same-plan columns anywhere in the
# process trace and XLA-compile exactly once.  Deliberately unbounded: analytics and
# benchmark workloads see a bounded set of structures.  A long-lived process decoding
# unbounded shape variety should bring its own ``ProgramCache(max_programs=...)``
# (ServeEngine's default executor does).
DEFAULT_CACHE = ProgramCache()


def build_graph(enc: plan_mod.Encoded, fuse: bool = True) -> DecodeGraph:
    """Lower + (optionally) fuse: the front half of the compile pipeline."""
    graph = plan_mod.lower_graph(enc)
    return fusion_mod.fuse_graph(graph) if fuse else graph


def compile_blob(enc: plan_mod.Encoded, backend: str = "jnp", fuse: bool = True,
                 chip: str = DEFAULT_CHIP,
                 geometry: dict[str, Geometry] | None = None,
                 interpret: bool | None = None, jit: bool = True,
                 cache: ProgramCache | None = None) -> Program:
    """Blob -> cached Program (the modern entry point)."""
    if backend == "baseline":
        fuse = False
    graph = build_graph(enc, fuse=fuse)
    cache = DEFAULT_CACHE if cache is None else cache
    return cache.get(graph, backend=backend, chip=chip, geometry=geometry,
                     interpret=interpret, jit=jit)


# --------------------------------------------------------------- compatibility shim

@dataclasses.dataclass
class CompiledDecoder:
    """Legacy per-blob handle; now a thin view over a cached Program."""

    fn: Callable[[dict[str, jnp.ndarray]], jnp.ndarray]
    stages: list[Stage]
    backend: str
    n_kernels: int
    program: Program | None = None

    def __call__(self, bufs: dict[str, jnp.ndarray]) -> jnp.ndarray:
        if self.program is not None:   # keep Program.calls (cold-detection) honest
            return self.program(bufs)
        return self.fn(bufs)


def compile_decoder(enc: plan_mod.Encoded, backend: str = "jnp", fuse: bool = True,
                    chip: str = DEFAULT_CHIP,
                    geometry: dict[str, Geometry] | None = None,
                    interpret: bool | None = None,
                    jit: bool = True) -> CompiledDecoder:
    prog = compile_blob(enc, backend=backend, fuse=fuse, chip=chip,
                        geometry=geometry, interpret=interpret, jit=jit)
    return CompiledDecoder(fn=prog.fn, stages=prog.stages, backend=backend,
                           n_kernels=prog.n_kernels, program=prog)


def device_buffers(enc: plan_mod.Encoded, device=None,
                   sharding=None) -> dict[str, jnp.ndarray]:
    """Move a blob's operands host->device: leaf buffers (the compressed transfer
    itself) plus the lifted meta operands the program consumes at call time."""
    ops = plan_mod.host_operands(enc)
    put = functools.partial(jax.device_put, device=sharding or device)
    return {k: put(v) for k, v in ops.items()}


def decode_on_device(enc: plan_mod.Encoded, backend: str = "jnp",
                     **kw: Any) -> jnp.ndarray:
    """One-shot helper: transfer + decode."""
    dec = compile_decoder(enc, backend=backend, **kw)
    return dec(device_buffers(enc))
