"""Algorithm registry (paper Fig. 4, Algorithm Layer).

Codecs self-register at import; ``repro.algos`` imports them all.  The registry is what
makes the algorithm pool user-extensible ("Algorithm extensibility" row of Table 1):
a new codec only has to provide host-side ``encode``, a numpy ``decode_np`` oracle and
a ``stages`` lowering onto the three patterns.
"""
from __future__ import annotations

from typing import Any, Protocol, runtime_checkable

import numpy as np


@runtime_checkable
class Codec(Protocol):
    name: str
    pattern: str  # "fp" | "gp" | "np" | "aux" -- dominant pattern family (Table 1)
    # Data-dependent meta keys LIFTED out of program identity into runtime operands,
    # mapped to the operand dtype (e.g. {"bit_width": np.int32}).  Lifted keys are
    # hashed by dtype/shape only in the structural signature; stage closures must
    # read them from traced (1,)-operand inputs, never bake the values.  Keys not
    # listed here are structural: hashed by value and free to close over.
    lifted_meta: dict[str, Any] = {}

    def encode(self, arr: np.ndarray, **params) -> tuple[dict[str, np.ndarray], dict]:
        """-> (buffers, meta).  Buffers may be re-compressed by child plans."""
        ...

    def decode_np(self, bufs: dict[str, np.ndarray], meta: dict, n: int,
                  dtype: Any) -> np.ndarray:
        """Pure-numpy decode given already-decoded child buffers."""
        ...

    def stages(self, enc, buf_names: dict[str, str], out_name: str,
               meta_names: dict[str, str] | None = None) -> list:
        """Lower decode onto pattern stages (repro.core.patterns).

        ``meta_names`` maps each lifted meta key to its operand env name; the
        returned stages list those names among their inputs (BufSpec "full")."""
        ...


_REGISTRY: dict[str, Codec] = {}


def register(codec: Codec) -> Codec:
    _REGISTRY[codec.name] = codec
    return codec


def get(name: str) -> Codec:
    if name not in _REGISTRY:
        import repro.algos  # noqa: F401  -- trigger codec registration
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown codec '{name}'; known: {sorted(_REGISTRY)}") from None


def names() -> list[str]:
    import repro.algos  # noqa: F401

    return sorted(_REGISTRY)
