"""Algorithm registry (paper Fig. 4, Algorithm Layer).

Codecs self-register at import; ``repro.algos`` imports them all.  The registry is what
makes the algorithm pool user-extensible ("Algorithm extensibility" row of Table 1):
a new codec only has to provide host-side ``encode``, a numpy ``decode_np`` oracle and
a ``stages`` lowering onto the three patterns.
"""
from __future__ import annotations

from typing import Any, Protocol, runtime_checkable

import numpy as np


@runtime_checkable
class Codec(Protocol):
    name: str
    pattern: str  # "fp" | "gp" | "np" | "aux" -- dominant pattern family (Table 1)

    def encode(self, arr: np.ndarray, **params) -> tuple[dict[str, np.ndarray], dict]:
        """-> (buffers, meta).  Buffers may be re-compressed by child plans."""
        ...

    def decode_np(self, bufs: dict[str, np.ndarray], meta: dict, n: int,
                  dtype: Any) -> np.ndarray:
        """Pure-numpy decode given already-decoded child buffers."""
        ...

    def stages(self, enc, buf_names: dict[str, str], out_name: str) -> list:
        """Lower decode onto pattern stages (repro.core.patterns)."""
        ...


_REGISTRY: dict[str, Codec] = {}


def register(codec: Codec) -> Codec:
    _REGISTRY[codec.name] = codec
    return codec


def get(name: str) -> Codec:
    if name not in _REGISTRY:
        import repro.algos  # noqa: F401  -- trigger codec registration
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown codec '{name}'; known: {sorted(_REGISTRY)}") from None


def names() -> list[str]:
    import repro.algos  # noqa: F401

    return sorted(_REGISTRY)
