"""Unified cost model for transfer/decode planning (paper §3.3, holistic thesis).

One ``CostModel`` replaces the estimate logic previously duplicated in
``executor._estimate`` and ``loader._measure``: it unifies

  * the **chip-model estimate** -- transfer = compressed bytes / host-link
    bandwidth, decode = (compressed + plain) HBM traffic / HBM bandwidth plus a
    per-kernel launch overhead (the same resource table ``geometry.ChipSpec``
    the kernel configs use), and
  * the executor's **measured** ``(transfer_s, decode_s)`` wall-clock timings,

into per-column *and* per-chunk predictions.  Measurements calibrate the chip
model through an EWMA feedback loop: every ``observe`` updates a transfer and a
decode scale factor (measured / raw-model ratio), so estimates for columns that
have never run are in the same units as wall-clock measurements -- the mixing
problem that previously forced ``measured_jobs`` to throw away partial
measurements.

``ColumnProfile`` is the planner-facing summary of a column: enough static
structure (leaf buffer sizes, chunkability, tile geometry) to predict how many
transfer pieces / decode chunks any candidate ``chunk_bytes`` produces, without
touching the executor.  ``ColumnProfile.n_decode_chunks`` mirrors
``StreamingExecutor._build_schedule`` exactly, so planned chunk counts equal
executed chunk counts.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Mapping, Sequence

import numpy as np

from repro.core import scheduler
from repro.core.geometry import DEFAULT_CHIP, chip as chip_spec


def rows_per_chunk(shape0: int, nbytes: int, chunk_bytes: int) -> int:
    """Rows of an axis-0-split buffer that fit in one transfer chunk -- the ONE
    home of this formula, shared by ``executor.split_chunks`` (which slices) and
    ``ColumnProfile.n_transfer_chunks`` (which predicts)."""
    return max(1, chunk_bytes // max(1, nbytes // max(1, shape0)))


def aligned_chunk_elems(chunk_bytes: int, per_elem_bytes: float,
                        align: int) -> int:
    """Output elements per decode chunk: ~chunk_bytes of compressed tile bytes,
    rounded to the boundary alignment -- the ONE home of this formula, shared by
    ``executor._build_schedule`` (which slices) and
    ``ColumnProfile.decode_chunking`` (which predicts)."""
    elems = int(chunk_bytes / max(per_elem_bytes, 1e-9)) // align * align
    return max(align, elems)


@dataclasses.dataclass(frozen=True)
class ColumnProfile:
    """Planner-facing static summary of one compressed column."""

    name: str
    compressed_nbytes: int
    plain_nbytes: int
    n_kernels: int
    signature: str = ""
    # (shape[0], nbytes) per leaf buffer -- what the transfer actually splits
    leaves: tuple[tuple[int, int], ...] = ()
    # element-chunkable decode (FullyParallel-only graph, see ir.ChunkLayout)
    chunkable: bool = False
    n_out: int = 0
    per_elem_bytes: float = 0.0   # compressed tile bytes per output element
    align: int = 1                # output-element chunk-boundary granularity

    def n_transfer_chunks(self, chunk_bytes: int | None) -> int:
        """Transfer pieces ``split_chunks`` issues for this column's leaves.
        Whole-blob transfer (None) is modeled as ONE piece, matching the
        executor's ``_n_chunks`` accounting."""
        if chunk_bytes is None:
            return 1
        total = 0
        for shape0, nbytes in self.leaves:
            if nbytes <= chunk_bytes or shape0 <= 1:
                total += 1
                continue
            total += math.ceil(shape0 / rows_per_chunk(shape0, nbytes,
                                                       chunk_bytes))
        return max(1, total)

    def decode_chunking(self, chunk_bytes: int | None) -> tuple[int, float]:
        """(n_chunks, tail_frac) the per-chunk decode path produces, mirroring
        ``StreamingExecutor._build_schedule``; (1, 1.0) when the column decodes
        whole (not chunkable, chunking off, or one chunk covers the column)."""
        if (not self.chunkable or chunk_bytes is None or self.n_out <= 0
                or self.per_elem_bytes <= 0):
            return 1, 1.0
        chunk_elems = aligned_chunk_elems(chunk_bytes, self.per_elem_bytes,
                                          self.align)
        if chunk_elems >= self.n_out:
            return 1, 1.0
        k = math.ceil(self.n_out / chunk_elems)
        tail = self.n_out - (k - 1) * chunk_elems
        return k, tail / chunk_elems


def profile_from(name: str, enc, graph) -> ColumnProfile:
    """Build a ColumnProfile from an Encoded blob + its DecodeGraph."""
    from repro.core import plan as plan_mod
    from repro.core.ir import element_chunk_layout

    flat = plan_mod.flat_buffers(enc)
    leaves = tuple((int(v.shape[0]) if v.ndim else 1, int(v.nbytes))
                   for v in flat.values())
    layout = element_chunk_layout(graph)
    per_elem, align = 0.0, 1
    if layout is not None:
        ops = plan_mod.host_operands(enc)
        for nm, spec in layout.tiled.items():
            num = int(ops[spec.num_op][0]) if spec.num_op else int(spec.num)
            per_elem += num / spec.den * np.dtype(ops[nm].dtype).itemsize
        align = int(layout.align)
    return ColumnProfile(
        name=name, compressed_nbytes=int(enc.compressed_nbytes),
        plain_nbytes=int(enc.plain_nbytes), n_kernels=int(graph.n_kernels),
        signature=graph.signature, leaves=leaves,
        chunkable=layout is not None, n_out=int(graph.n_out),
        per_elem_bytes=per_elem, align=align)


class CostModel:
    """Per-column / per-chunk (transfer_s, decode_s) predictor with an
    EWMA-calibrated measured-feedback loop.

    ``measured`` is the authoritative wall-clock store (the executor's
    ``timings`` dict aliases it); ``observe`` additionally folds each
    measurement into the transfer/decode calibration scales so chip-model
    estimates for unmeasured columns land in wall-clock units.
    """

    def __init__(self, chip: str = DEFAULT_CHIP, alpha: float = 0.4):
        self.spec = chip_spec(chip)
        self.alpha = float(alpha)
        self.transfer_scale = 1.0
        self.decode_scale = 1.0
        self.n_observed = 0
        self.profiles: dict[str, ColumnProfile] = {}
        self.measured: dict[str, tuple[float, float]] = {}

    # -------------------------------------------------------------- registry
    def register(self, profile: ColumnProfile) -> None:
        self.profiles[profile.name] = profile

    def forget(self, name: str) -> None:
        self.profiles.pop(name, None)
        self.measured.pop(name, None)

    # ---------------------------------------------------------- predictions
    def raw_estimate(self, name: str) -> tuple[float, float]:
        """Uncalibrated chip-model (transfer_s, decode_s)."""
        p = self.profiles[name]
        transfer = p.compressed_nbytes / (self.spec.host_link_gbps * 1e9)
        traffic = p.compressed_nbytes + p.plain_nbytes
        decode = (traffic / (self.spec.hbm_gbps * 1e9)
                  + p.n_kernels * self.spec.grid_step_overhead_ns * 1e-9)
        return transfer, decode

    def predict(self, name: str) -> tuple[float, float]:
        """Best available (transfer_s, decode_s): measured when we have it,
        EWMA-calibrated chip model otherwise."""
        if name in self.measured:
            return self.measured[name]
        t, d = self.raw_estimate(name)
        return t * self.transfer_scale, d * self.decode_scale

    def launch_overhead_s(self, name: str) -> float:
        """Cost of one *extra* decode launch (per-chunk decode dispatches the
        column's kernels once per chunk instead of once)."""
        p = self.profiles[name]
        return (p.n_kernels * self.spec.grid_step_overhead_ns * 1e-9
                * self.decode_scale)

    # ------------------------------------------------------------- feedback
    def observe(self, name: str, transfer_s: float, decode_s: float) -> None:
        """Feed one measured run back: store it and recalibrate the scales."""
        self.measured[name] = (float(transfer_s), float(decode_s))
        if name not in self.profiles:
            return
        raw_t, raw_d = self.raw_estimate(name)
        a = self.alpha if self.n_observed else 1.0   # first sample snaps
        if raw_t > 0 and transfer_s > 0:
            self.transfer_scale += a * (transfer_s / raw_t - self.transfer_scale)
        if raw_d > 0 and decode_s > 0:
            self.decode_scale += a * (decode_s / raw_d - self.decode_scale)
        self.n_observed += 1

    # ------------------------------------------------------------- job views
    def jobs(self, names: Sequence[str]) -> list[scheduler.Job]:
        """Scheduling jobs in CONSISTENT units.  Once the EWMA loop has been
        calibrated by at least one observation, each column uses its best
        prediction (measured if present, calibrated estimate otherwise) -- the
        same values ``predict`` hands the planner's per-column decisions.
        Before any calibration, mixing microsecond-scale raw estimates with
        millisecond-scale injected measurements would make Johnson's
        transfer-vs-decode comparison arbitrary, so it is all-or-nothing:
        measured only when every column has a measurement."""
        names = list(names)
        if self.n_observed or (names and all(n in self.measured
                                             for n in names)):
            est: Mapping[str, tuple[float, float]] = {
                n: self.predict(n) for n in names}
        else:
            est = {}
            for n in names:
                t, d = self.raw_estimate(n)
                est[n] = (t * self.transfer_scale, d * self.decode_scale)
        return [scheduler.Job(n, est[n][0], est[n][1]) for n in names]
