"""Unified cost model for transfer/decode planning (paper §3.3, holistic thesis).

One ``CostModel`` replaces the estimate logic previously duplicated in
``executor._estimate`` and ``loader._measure``: it unifies

  * the **chip-model estimate** -- transfer = compressed bytes / host-link
    bandwidth, decode = (compressed + plain) HBM traffic / HBM bandwidth plus a
    per-kernel launch overhead (the same resource table ``geometry.ChipSpec``
    the kernel configs use), and
  * the executor's **measured** ``(transfer_s, decode_s)`` wall-clock timings,

into per-column *and* per-chunk predictions.  Measurements calibrate the chip
model through an EWMA feedback loop: every ``observe`` updates a transfer and a
decode scale factor (measured / raw-model ratio), so estimates for columns that
have never run are in the same units as wall-clock measurements -- the mixing
problem that previously forced ``measured_jobs`` to throw away partial
measurements.

``ColumnProfile`` is the planner-facing summary of a column: enough static
structure (leaf buffer sizes, chunkability, tile geometry) to predict how many
transfer pieces / decode chunks any candidate ``chunk_bytes`` produces, without
touching the executor.  ``ColumnProfile.n_decode_chunks`` mirrors
``StreamingExecutor._build_schedule`` exactly, so planned chunk counts equal
executed chunk counts.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import threading
from typing import Mapping, Sequence

import numpy as np

from repro.core import scheduler
from repro.core.geometry import DEFAULT_CHIP, chip as chip_spec, native_subtile


def rows_per_chunk(shape0: int, nbytes: int, chunk_bytes: int) -> int:
    """Rows of an axis-0-split buffer that fit in one transfer chunk -- the ONE
    home of this formula, shared by ``executor.split_chunks`` (which slices) and
    ``ColumnProfile.n_transfer_chunks`` (which predicts)."""
    return max(1, chunk_bytes // max(1, nbytes // max(1, shape0)))


def aligned_chunk_elems(chunk_bytes: int, per_elem_bytes: float,
                        align: int) -> int:
    """Output elements per decode chunk: ~chunk_bytes of compressed tile bytes,
    rounded to the boundary alignment -- the ONE home of this formula, shared by
    ``executor._build_schedule`` (which slices) and
    ``ColumnProfile.decode_chunking`` (which predicts)."""
    elems = int(chunk_bytes / max(per_elem_bytes, 1e-9)) // align * align
    return max(align, elems)


def groups_per_chunk(chunk_bytes: int, bytes_per_group: float,
                     align: int) -> int:
    """Whole groups per decode span: ~chunk_bytes of streamed group bytes,
    rounded to the group-boundary alignment -- the group-path sibling of
    ``aligned_chunk_elems``, shared by ``executor._build_schedule`` and
    ``ColumnProfile.decode_chunking`` so planned span counts equal executed."""
    g = int(chunk_bytes / max(bytes_per_group, 1e-9)) // align * align
    return max(align, g)


# output-pad granularity for uneven group spans: body launches pad to a shared
# lane-aligned shape so ONE compiled program serves every body span
GROUP_PAD_ELEMS = 128

# prior for query predicate selectivity before any fused run has been observed
DEFAULT_SELECTIVITY = 0.5


def pad_group_elems(elems: int) -> int:
    return max(GROUP_PAD_ELEMS,
               -(-int(elems) // GROUP_PAD_ELEMS) * GROUP_PAD_ELEMS)


def group_bytes_per_group(layout, ops: Mapping[str, np.ndarray]) -> float:
    """Streamed (sliced-leaf) compressed bytes per group for a GroupChunkLayout:
    axis-0 leaves contribute ``num/den`` rows per group, axis-1 leaves (the ANS
    stripe) one column per group.  Shared by profile_from (predicts) and the
    executor's schedule builder (slices)."""
    total = 0.0
    for nm, spec in layout.sliced.items():
        arr = np.asarray(ops[nm])
        if layout.axes.get(nm, 0) == 1:
            total += float(arr.shape[0]) * arr.dtype.itemsize
        else:
            num = (int(np.asarray(ops[spec.num_op])[0]) if spec.num_op
                   else spec.num)
            row = arr.dtype.itemsize * (int(np.prod(arr.shape[1:]))
                                        if arr.ndim > 1 else 1)
            total += num / spec.den * row
    return total


def serial_host() -> bool:
    """True when host->device "transfer" and decode share ONE resource (a
    CPU-only backend: device_put is a memcpy on the same cores that decode),
    so the two-machine flow-shop overlap ``simulate_stream`` models does not
    exist and chunked execution can only add launch overhead."""
    import jax

    return jax.default_backend() == "cpu"


@dataclasses.dataclass(frozen=True)
class LinkTopology:
    """Host->device interconnect description for mesh planning.

    One entry per device-facing link: ``link_scale[d]`` multiplies the
    calibrated single-link transfer time on link ``d`` (1.0 = the host link
    the EWMA loop was calibrated against; >1 = a slower link, e.g. a PCIe
    switch shared leg), ``link_latency_s[d]`` is a fixed per-piece issue
    latency, and ``host_window`` bounds the TOTAL number of transferred-but-
    undecoded chunks staged across all links (the shared pinned-host-buffer
    budget ``scheduler.simulate_stream_multi`` models).  Missing entries
    default to (1.0, 0.0): a symmetric topology needs no explicit tables.

    The second tier is the device-to-device fabric (NVLink-class):
    ``d2d_scale`` multiplies the calibrated host-link transfer time for a
    device->device copy of the same byte count (an NVLink 5-10x faster than
    PCIe is ~0.1-0.2), ``d2d_latency_s`` adds a fixed per-copy issue latency.
    ``d2d_scale=None`` means NO fabric is modeled: the planner never proposes
    redistribution and the mesh simulator reduces exactly to the
    single-tier model.
    """

    n_links: int = 1
    link_scale: tuple[float, ...] = ()
    link_latency_s: tuple[float, ...] = ()
    host_window: int | None = None
    d2d_scale: float | None = None
    d2d_latency_s: float = 0.0

    def scale(self, d: int) -> float:
        return float(self.link_scale[d]) if d < len(self.link_scale) else 1.0

    def latency_s(self, d: int) -> float:
        return (float(self.link_latency_s[d])
                if d < len(self.link_latency_s) else 0.0)

    @property
    def has_fabric(self) -> bool:
        return self.d2d_scale is not None

    def d2d_copy_s(self, h2d_equiv_s: float) -> float:
        """Modeled device->device copy time for bytes whose host-link
        transfer would take ``h2d_equiv_s`` (the fabric is priced relative
        to the calibrated host link).  Infinite when no fabric exists, so a
        fabric-less topology can never make redistribution look cheap."""
        if self.d2d_scale is None:
            return float("inf")
        return max(0.0, float(h2d_equiv_s)) * float(self.d2d_scale) \
            + float(self.d2d_latency_s)

    def resized(self, n_links: int) -> "LinkTopology":
        """Same per-link (and fabric) parameters over a different link count
        (elastic re-planning keeps surviving links' characteristics)."""
        return dataclasses.replace(self, n_links=max(1, int(n_links)))

    def to_json(self) -> dict:
        return {"n_links": int(self.n_links),
                "link_scale": [float(x) for x in self.link_scale],
                "link_latency_s": [float(x) for x in self.link_latency_s],
                "host_window": (None if self.host_window is None
                                else int(self.host_window)),
                "d2d_scale": (None if self.d2d_scale is None
                              else float(self.d2d_scale)),
                "d2d_latency_s": float(self.d2d_latency_s)}

    @classmethod
    def from_json(cls, data) -> "LinkTopology":
        """Tolerant parse: known keys only, defaults for anything missing --
        old caches (no topology block, no d2d tier) and future caches (extra
        keys) both load."""
        if not isinstance(data, dict):
            return cls()
        hw = data.get("host_window")
        d2d = data.get("d2d_scale")
        return cls(
            n_links=max(1, int(data.get("n_links", 1))),
            link_scale=tuple(float(x) for x in data.get("link_scale", ())),
            link_latency_s=tuple(float(x)
                                 for x in data.get("link_latency_s", ())),
            host_window=None if hw is None else int(hw),
            d2d_scale=None if d2d is None else float(d2d),
            d2d_latency_s=float(data.get("d2d_latency_s", 0.0)))


@dataclasses.dataclass(frozen=True)
class ColumnProfile:
    """Planner-facing static summary of one compressed column."""

    name: str
    compressed_nbytes: int
    plain_nbytes: int
    n_kernels: int
    signature: str = ""
    # (shape[0], nbytes) per leaf buffer -- what the transfer actually splits
    leaves: tuple[tuple[int, int], ...] = ()
    # element-chunkable decode (FullyParallel-only graph, see ir.ChunkLayout)
    chunkable: bool = False
    n_out: int = 0
    per_elem_bytes: float = 0.0   # compressed tile bytes per output element
    align: int = 1                # output-element chunk-boundary granularity
    # group-chunkable decode (ir.GroupChunkLayout: GP expansions, ANS chunk grids)
    group_chunkable: bool = False
    n_groups: int = 0
    group_bytes: float = 0.0      # streamed (sliced-leaf) bytes per group
    group_align: int = 1          # group-boundary alignment
    pattern: str = "fp"           # dominant stage pattern ("fp" | "gp" | "np")
    # per-group output offsets (len n_groups+1), planning data -- excluded from
    # equality so same-structure profiles with different run data still compare
    group_out_presum: np.ndarray | None = dataclasses.field(
        default=None, compare=False, repr=False)

    def n_transfer_chunks(self, chunk_bytes: int | None) -> int:
        """Transfer pieces ``split_chunks`` issues for this column's leaves.
        Whole-blob transfer (None) is modeled as ONE piece, matching the
        executor's ``_n_chunks`` accounting."""
        if chunk_bytes is None:
            return 1
        total = 0
        for shape0, nbytes in self.leaves:
            if nbytes <= chunk_bytes or shape0 <= 1:
                total += 1
                continue
            total += math.ceil(shape0 / rows_per_chunk(shape0, nbytes,
                                                       chunk_bytes))
        return max(1, total)

    def _group_spans(self, chunk_bytes: int) -> tuple[int, int] | None:
        """(groups_per_span, n_spans) for group-boundary chunking, or None when
        the column decodes whole -- mirrors ``StreamingExecutor._build_schedule``."""
        if (not self.group_chunkable or self.n_groups <= 1
                or self.group_bytes <= 0):
            return None
        G = groups_per_chunk(chunk_bytes, self.group_bytes, self.group_align)
        if G >= self.n_groups:
            return None
        return G, math.ceil(self.n_groups / G)

    def decode_chunking(self, chunk_bytes: int | None) -> tuple[int, float]:
        """(n_chunks, tail_frac) the per-chunk decode path produces, mirroring
        ``StreamingExecutor._build_schedule``; (1, 1.0) when the column decodes
        whole (not chunkable, chunking off, or one chunk covers the column)."""
        if chunk_bytes is None:
            return 1, 1.0
        if self.chunkable and self.n_out > 0 and self.per_elem_bytes > 0:
            chunk_elems = aligned_chunk_elems(chunk_bytes, self.per_elem_bytes,
                                              self.align)
            if chunk_elems >= self.n_out:
                return 1, 1.0
            k = math.ceil(self.n_out / chunk_elems)
            tail = self.n_out - (k - 1) * chunk_elems
            return k, tail / chunk_elems
        spans = self._group_spans(chunk_bytes)
        if spans is None:
            return 1, 1.0
        G, k = spans
        ps = self.group_out_presum
        if ps is None or k <= 1:
            return k, 1.0
        bounds = list(range(0, self.n_groups, G)) + [self.n_groups]
        sizes = np.diff(np.asarray(ps, dtype=np.float64)[bounds])
        body = float(np.mean(sizes[:-1])) if len(sizes) > 1 else float(sizes[0])
        tail = float(sizes[-1]) / max(body, 1e-9)
        return k, float(min(1.0, max(tail, 1e-3)))

    def chunk_weights(self, chunk_bytes: int | None
                      ) -> tuple[tuple[float, float], ...]:
        """Per-chunk (transfer, decode) weight pairs for ``simulate_stream``'s
        uneven-chunk model, or () for the uniform-body + tail default.

        Group spans are genuinely uneven: transfer follows the streamed bytes
        per span (whole-resident leaves all land ahead of span 0), decode
        follows each span's output elements from the group-boundary prefix
        sums.  Element chunks keep the closed-form uniform+tail model."""
        if chunk_bytes is None:
            return ()
        spans = self._group_spans(chunk_bytes)
        if spans is None or self.group_out_presum is None:
            return ()
        G, k = spans
        if k <= 1:
            return ()
        ps = np.asarray(self.group_out_presum, dtype=np.float64)
        bounds = list(range(0, self.n_groups, G)) + [self.n_groups]
        out_sizes = np.diff(ps[bounds])
        g_sizes = np.diff(bounds).astype(np.float64)
        whole_bytes = max(
            0.0, self.compressed_nbytes - self.group_bytes * self.n_groups)
        transfer = g_sizes * self.group_bytes
        transfer[0] += whole_bytes
        t_tot = float(transfer.sum()) or 1.0
        d_tot = float(out_sizes.sum()) or 1.0
        return tuple((float(t) / t_tot, float(d) / d_tot)
                     for t, d in zip(transfer, out_sizes))


def profile_from(name: str, enc, graph) -> ColumnProfile:
    """Build a ColumnProfile from an Encoded blob + its DecodeGraph."""
    from repro.core import plan as plan_mod
    from repro.core.ir import element_chunk_layout, group_chunk_layout
    from repro.core.patterns import GroupParallel, NonParallel

    flat = plan_mod.flat_buffers(enc)
    leaves = tuple((int(v.shape[0]) if v.ndim else 1, int(v.nbytes))
                   for v in flat.values())
    layout = element_chunk_layout(graph)
    per_elem, align = 0.0, 1
    glayout = None
    n_groups, g_bytes, g_align, presum = 0, 0.0, 1, None
    pattern = "fp"
    if layout is not None:
        ops = plan_mod.host_operands(enc)
        for nm, spec in layout.tiled.items():
            num = int(ops[spec.num_op][0]) if spec.num_op else int(spec.num)
            per_elem += num / spec.den * np.dtype(ops[nm].dtype).itemsize
        align = int(layout.align)
    else:
        glayout = group_chunk_layout(graph)
        if glayout is not None:
            ops = plan_mod.host_operands(enc)
            n_groups = int(glayout.n_groups)
            g_bytes = group_bytes_per_group(glayout, ops)
            g_align = int(glayout.align_groups)
            presum = np.asarray(glayout.group_presum, dtype=np.int64)
            pattern = glayout.kind
        else:
            for st in graph.stages:
                if isinstance(st, NonParallel):
                    pattern = "np"
                elif isinstance(st, GroupParallel) and pattern == "fp":
                    pattern = "gp"
    return ColumnProfile(
        name=name, compressed_nbytes=int(enc.compressed_nbytes),
        plain_nbytes=int(enc.plain_nbytes), n_kernels=int(graph.n_kernels),
        signature=graph.signature, leaves=leaves,
        chunkable=layout is not None, n_out=int(graph.n_out),
        per_elem_bytes=per_elem, align=align,
        group_chunkable=glayout is not None, n_groups=n_groups,
        group_bytes=g_bytes, group_align=g_align, pattern=pattern,
        group_out_presum=presum)


class CostModel:
    """Per-column / per-chunk (transfer_s, decode_s) predictor with an
    EWMA-calibrated measured-feedback loop.

    ``measured`` is the authoritative wall-clock store (the executor's
    ``timings`` dict aliases it); ``observe`` additionally folds each
    measurement into the transfer/decode calibration scales so chip-model
    estimates for unmeasured columns land in wall-clock units.
    """

    def __init__(self, chip: str = DEFAULT_CHIP, alpha: float = 0.4):
        self.spec = chip_spec(chip)
        self.alpha = float(alpha)
        self.transfer_scale = 1.0
        self.decode_scale = 1.0
        self.n_observed = 0
        # every read-modify-write feedback path (observe / observe_selectivity
        # / observe_link) runs under this lock: the dispatch engine makes them
        # reachable while transfer workers are live, and torn EWMA updates
        # would silently corrupt calibration
        self._lock = threading.RLock()
        # host->device interconnect description for mesh planning; the default
        # single symmetric link keeps every single-device path unchanged
        self.topology = LinkTopology()
        self.profiles: dict[str, ColumnProfile] = {}
        self.measured: dict[str, tuple[float, float]] = {}
        # per-SIGNATURE running means of measured (transfer_s, decode_s): the
        # persistent half of the feedback loop -- a fresh process planning the
        # same column structures starts from history (``save``/``load``)
        self.sig_stats: dict[str, dict[str, float]] = {}
        # per-SIGNATURE EWMA of observed query selectivity (fused runs report
        # selected_rows / n_rows from the Reduce count lane)
        self.selectivity: dict[str, float] = {}

    # -------------------------------------------------------------- registry
    def register(self, profile: ColumnProfile) -> None:
        self.profiles[profile.name] = profile

    def forget(self, name: str) -> None:
        self.profiles.pop(name, None)
        self.measured.pop(name, None)

    # ---------------------------------------------------------- predictions
    def raw_estimate(self, name: str) -> tuple[float, float]:
        """Uncalibrated chip-model (transfer_s, decode_s)."""
        p = self.profiles[name]
        transfer = p.compressed_nbytes / (self.spec.host_link_gbps * 1e9)
        traffic = p.compressed_nbytes + p.plain_nbytes
        decode = (traffic / (self.spec.hbm_gbps * 1e9)
                  + p.n_kernels * self.spec.grid_step_overhead_ns * 1e-9)
        return transfer, decode

    def predict(self, name: str) -> tuple[float, float]:
        """Best available (transfer_s, decode_s): measured this process when we
        have it, the signature's persisted running mean (same structure = same
        shapes, so the history is directly comparable wall-clock) otherwise,
        EWMA-calibrated chip model as the fallback."""
        if name in self.measured:
            return self.measured[name]
        p = self.profiles.get(name)
        if p is not None and p.signature in self.sig_stats:
            s = self.sig_stats[p.signature]
            return float(s["transfer_s"]), float(s["decode_s"])
        t, d = self.raw_estimate(name)
        return t * self.transfer_scale, d * self.decode_scale

    def selectivity_for(self, name: str) -> float:
        """Learned predicate selectivity for this column's signature, or the
        ``DEFAULT_SELECTIVITY`` prior when no fused run has reported one."""
        p = self.profiles.get(name)
        if p is not None and p.signature in self.selectivity:
            return self.selectivity[p.signature]
        return DEFAULT_SELECTIVITY

    def fused_decode_s(self, name: str, sel: float | None = None) -> float:
        """Decode-fused cost: the fused chunk program still reads every
        compressed byte, but the decoded column is consumed in registers
        instead of being written to (and re-read from) HBM -- only the rows
        the predicate keeps do downstream aggregate arithmetic, so the
        plain-side traffic scales with selectivity."""
        sel = self.selectivity_for(name) if sel is None else float(sel)
        sel = min(1.0, max(0.0, sel))
        p = self.profiles[name]
        _, d = self.predict(name)
        traffic = p.compressed_nbytes + p.plain_nbytes
        return d * (p.compressed_nbytes + sel * p.plain_nbytes) / max(traffic, 1)

    def query_read_s(self, name: str) -> float:
        """What materialize-then-query pays on top of decode: the query
        operator re-reads the full decoded column from HBM."""
        p = self.profiles[name]
        return p.plain_nbytes / (self.spec.hbm_gbps * 1e9) * self.decode_scale

    def launch_overhead_s(self, name: str) -> float:
        """Cost of one *extra* decode launch (per-chunk decode dispatches the
        column's kernels once per chunk instead of once)."""
        p = self.profiles[name]
        return (p.n_kernels * self.spec.grid_step_overhead_ns * 1e-9
                * self.decode_scale)

    # ------------------------------------------------------------- feedback
    def observe(self, name: str, transfer_s: float, decode_s: float) -> None:
        """Feed one measured run back: store it and recalibrate the scales.
        Atomic: concurrent observers cannot tear the incremental means or the
        EWMA read-modify-write."""
        with self._lock:
            self.measured[name] = (float(transfer_s), float(decode_s))
            if name not in self.profiles:
                return
            sig = self.profiles[name].signature
            if sig:
                s = self.sig_stats.setdefault(
                    sig, {"n": 0.0, "transfer_s": 0.0, "decode_s": 0.0})
                s["n"] += 1.0
                s["transfer_s"] += (transfer_s - s["transfer_s"]) / s["n"]
                s["decode_s"] += (decode_s - s["decode_s"]) / s["n"]
            raw_t, raw_d = self.raw_estimate(name)
            a = self.alpha if self.n_observed else 1.0   # first sample snaps
            if raw_t > 0 and transfer_s > 0:
                self.transfer_scale += a * (transfer_s / raw_t
                                            - self.transfer_scale)
            if raw_d > 0 and decode_s > 0:
                self.decode_scale += a * (decode_s / raw_d - self.decode_scale)
            self.n_observed += 1

    def observe_selectivity(self, name: str, sel: float) -> None:
        """Fold a fused run's measured selectivity (Reduce count lane /
        n_rows) into the per-signature EWMA the fused-cost estimate uses."""
        with self._lock:
            p = self.profiles.get(name)
            if p is None or not p.signature:
                return
            sel = min(1.0, max(0.0, float(sel)))
            prev = self.selectivity.get(p.signature)
            if prev is None:
                self.selectivity[p.signature] = sel
            else:
                self.selectivity[p.signature] = prev + self.alpha * (sel - prev)

    def observe_link(self, link: int, ratio: float) -> None:
        """Fold one device leg's measured/predicted transfer ratio into the
        per-link EWMA scale ``topology.link_scale[link]``.

        The ratio is relative to the already-calibrated single-link model
        (``est_transfer_s`` folds ``transfer_scale`` in), so a symmetric mesh
        converges to ~1.0 per link while a slow leg (shared PCIe switch,
        throttled lane) drifts above its siblings and
        ``plan_mesh_execution``'s LPT loads + ``simulate_stream_multi``
        scoring shift bytes away from it.  The frozen ``LinkTopology`` is
        replaced atomically under the lock; persisted via ``save``'s
        "topology" block."""
        link = int(link)
        ratio = float(ratio)
        if not (ratio > 0.0) or not np.isfinite(ratio) or link < 0:
            return
        with self._lock:
            topo = self.topology
            scale = list(topo.link_scale)
            if len(scale) <= link:
                scale.extend([1.0] * (link + 1 - len(scale)))
            scale[link] += self.alpha * (ratio - scale[link])
            self.topology = dataclasses.replace(
                topo, n_links=max(topo.n_links, link + 1),
                link_scale=tuple(scale))

    def h2d_equiv_s(self, nbytes: int) -> float:
        """Calibrated host-link transfer time for ``nbytes`` -- the reference
        unit the D2D fabric tier is priced in (both
        ``LinkTopology.d2d_copy_s``'s argument and the denominator of
        ``observe_d2d`` samples)."""
        return (max(0, int(nbytes)) / (self.spec.host_link_gbps * 1e9)
                * self.transfer_scale)

    def observe_d2d(self, ratio: float) -> None:
        """Fold one device->device copy's measured/H2D-equivalent time ratio
        into the fabric EWMA ``topology.d2d_scale``.

        The ratio prices the D2D fabric relative to the calibrated host link
        for the same byte count: an NVLink-class fabric converges to ~0.1-0.2,
        a PCIe-P2P fabric to ~1.0.  The first valid sample seeds the scale
        (turning the fabric tier ON if the topology had none); later samples
        blend with the usual alpha.  Invalid samples (non-finite, <= 0) are
        dropped.  The frozen ``LinkTopology`` is replaced atomically under
        the lock and persists through ``save``'s "topology" block."""
        ratio = float(ratio)
        if not (ratio > 0.0) or not np.isfinite(ratio):
            return
        with self._lock:
            topo = self.topology
            if topo.d2d_scale is None:
                nxt = ratio
            else:
                nxt = topo.d2d_scale + self.alpha * (ratio - topo.d2d_scale)
            self.topology = dataclasses.replace(topo, d2d_scale=nxt)

    # -------------------------------------------------------- candidate ladder
    def chunk_ladder(self, p: ColumnProfile, max_candidates: int = 12
                     ) -> tuple[int, ...]:
        """Per-column chunk-size candidates (bytes), tied to this column's
        decode geometry instead of a fixed 64KiB-4MiB ladder.

        Element-chunkable columns snap to kernel tile multiples: doublings of
        lcm(boundary alignment, the chip's native <L,S,C> sub-tile S*C), so
        every decode launch covers whole kernel tiles.  Group-chunkable columns
        snap to group-boundary prefix sums: doublings of the group alignment,
        priced through the streamed bytes per group.  Both ladders are pruned
        with the CALIBRATED launch-overhead estimate -- a candidate whose
        per-chunk decode would be dominated by launch overhead is dropped, so
        the ladder tightens per pattern as the EWMA loop warms up."""
        if p.name not in self.profiles:
            self.register(p)
        _, d_est = self.predict(p.name)
        overhead = (p.n_kernels * self.spec.grid_step_overhead_ns * 1e-9
                    * self.decode_scale)
        cands: list[tuple[int, float]] = []   # (bytes, decode-work fraction)
        if p.chunkable and p.per_elem_bytes > 0 and p.n_out > 1:
            base = math.lcm(max(1, p.align),
                            native_subtile(p.pattern, self.spec.name))
            elems = base
            while elems < p.n_out and len(cands) < max_candidates:
                cands.append((max(1, math.ceil(elems * p.per_elem_bytes)),
                              elems / p.n_out))
                elems *= 2
        elif p.group_chunkable and p.group_bytes > 0 and p.n_groups > 1:
            g = max(1, p.group_align)
            while g < p.n_groups and len(cands) < max_candidates:
                cands.append((max(1, math.ceil(g * p.group_bytes)),
                              g / p.n_groups))
                g *= 2
        if not cands:
            return ()
        kept = [cb for cb, frac in cands
                if d_est <= 0 or d_est * frac >= 2.0 * overhead]
        return tuple(sorted(set(kept or [cands[-1][0]])))

    # ------------------------------------------------------------ persistence
    def save(self, path: str) -> None:
        """Serialize the calibration state (EWMA scales + per-signature timing
        summaries) as JSON, so a fresh process plans from history -- the
        per-chip profile role the paper's per-GPU tuning plays."""
        data = {
            "chip": self.spec.name, "alpha": self.alpha,
            "transfer_scale": self.transfer_scale,
            "decode_scale": self.decode_scale,
            "n_observed": self.n_observed,
            "signatures": self.sig_stats,
            "selectivity": self.selectivity,
            "topology": self.topology.to_json(),
        }
        tmp = f"{path}.tmp"
        with open(tmp, "w") as f:
            json.dump(data, f, indent=2, sort_keys=True)
            f.write("\n")
        os.replace(tmp, path)

    @classmethod
    def load(cls, path: str) -> "CostModel":
        """Rebuild a CostModel from ``save`` output.  Profiles and per-column
        measurements are process-local and start empty; the calibration scales
        and signature histories carry over, so the very first plan of a fresh
        process is already in wall-clock units."""
        with open(path) as f:
            data = json.load(f)
        cm = cls(chip=data.get("chip", DEFAULT_CHIP),
                 alpha=float(data.get("alpha", 0.4)))
        cm.transfer_scale = float(data.get("transfer_scale", 1.0))
        cm.decode_scale = float(data.get("decode_scale", 1.0))
        cm.n_observed = int(data.get("n_observed", 0))
        cm.sig_stats = {
            sig: {"n": float(s.get("n", 0.0)),
                  "transfer_s": float(s.get("transfer_s", 0.0)),
                  "decode_s": float(s.get("decode_s", 0.0))}
            for sig, s in data.get("signatures", {}).items()}
        cm.selectivity = {sig: float(s)
                          for sig, s in data.get("selectivity", {}).items()}
        # tolerant topology parse: absent in old caches (-> single link),
        # unknown keys in future caches are ignored
        cm.topology = LinkTopology.from_json(data.get("topology"))
        return cm

    # ------------------------------------------------------------- job views
    def jobs(self, names: Sequence[str]) -> list[scheduler.Job]:
        """Scheduling jobs in CONSISTENT units.  Once the EWMA loop has been
        calibrated by at least one observation, each column uses its best
        prediction (measured if present, calibrated estimate otherwise) -- the
        same values ``predict`` hands the planner's per-column decisions.
        Before any calibration, mixing microsecond-scale raw estimates with
        millisecond-scale injected measurements would make Johnson's
        transfer-vs-decode comparison arbitrary, so it is all-or-nothing:
        measured only when every column has a measurement."""
        names = list(names)
        if self.n_observed or (names and all(n in self.measured
                                             for n in names)):
            est: Mapping[str, tuple[float, float]] = {
                n: self.predict(n) for n in names}
        else:
            est = {}
            for n in names:
                t, d = self.raw_estimate(n)
                est[n] = (t * self.transfer_scale, d * self.decode_scale)
        return [scheduler.Job(n, est[n][0], est[n][1]) for n in names]
