"""Pipelining Layer (paper §3.3): Johnson's-rule ordering of transfer/decompress.

Each data block i is a job with two sequential operations on two "machines":
  machine 1 = host->device link (transfer time a_i),
  machine 2 = on-device decompression (time b_i),
and blocks are independent -- a classic two-machine flow shop.  Johnson (1954) gives
the makespan-optimal order:  jobs with a_i <= b_i first, ascending a_i; then the rest,
descending b_i.  (The paper reports O(n); the textbook bound is O(n log n) for the
sort -- we note the discrepancy and implement the optimal rule.)

The same module simulates a pipeline's makespan for any order, which the tests use to
verify optimality against brute force and the benchmarks use for the Fig. 8 / Fig. 20
"Z vs C" ablation.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Sequence


@dataclasses.dataclass(frozen=True)
class Job:
    name: str
    transfer_s: float    # machine-1 time (PCIe/host-link)
    decompress_s: float  # machine-2 time (GPU/TPU kernel)


def johnson_order(jobs: Sequence[Job]) -> list[int]:
    """Return indices into ``jobs`` in Johnson-optimal execution order."""
    first = sorted((i for i, j in enumerate(jobs) if j.transfer_s <= j.decompress_s),
                   key=lambda i: jobs[i].transfer_s)
    second = sorted((i for i, j in enumerate(jobs) if j.transfer_s > j.decompress_s),
                    key=lambda i: -jobs[i].decompress_s)
    return first + second


def makespan(jobs: Sequence[Job], order: Sequence[int] | None = None) -> float:
    """Simulate the two-stage pipeline: transfer is serial on the link; decompression
    of block k starts when both its transfer and block k-1's decompression finish."""
    order = list(range(len(jobs))) if order is None else list(order)
    t_link = 0.0   # when the link frees up
    t_dev = 0.0    # when the device frees up
    for i in order:
        t_link += jobs[i].transfer_s
        t_dev = max(t_dev, t_link) + jobs[i].decompress_s
    return t_dev


def serial_time(jobs: Sequence[Job]) -> float:
    """No pipelining: every block transfers then decompresses exclusively."""
    return sum(j.transfer_s + j.decompress_s for j in jobs)


def brute_force_best(jobs: Sequence[Job]) -> tuple[float, tuple[int, ...]]:
    """Exhaustive optimum (testing only; factorial)."""
    best = (float("inf"), tuple(range(len(jobs))))
    for perm in itertools.permutations(range(len(jobs))):
        m = makespan(jobs, perm)
        if m < best[0]:
            best = (m, perm)
    return best


def schedule(names: Sequence[str], transfer_s: Sequence[float],
             decompress_s: Sequence[float]) -> list[str]:
    """Convenience wrapper used by the data loader: returns block names in optimal
    issue order."""
    jobs = [Job(n, a, b) for n, a, b in zip(names, transfer_s, decompress_s)]
    return [jobs[i].name for i in johnson_order(jobs)]


# ----------------------------------------------------------- chunk-level jobs

def fifo_order(jobs: Sequence[Job]) -> list[int]:
    """Submission order (the no-scheduler baseline)."""
    return list(range(len(jobs)))


def chunk_jobs(jobs: Sequence[Job], n_chunks: Sequence[int]) -> list[Job]:
    """Split each column job into its chunk-level jobs.

    The streaming executor transfers column ``j`` as ``n_chunks[j]`` fixed-size
    pieces; chunk ``i`` of column ``name`` is named ``name#i``, with machine-1
    (link) and machine-2 (decode) time divided evenly across the chunks.  Finer
    jobs let the two-machine pipeline overlap *within* a column, which whole-column
    jobs cannot: makespan(chunked, Johnson) <= makespan(whole, Johnson).

    Note the model is chunk-granular on BOTH machines, while the current executor
    chunks only the transfer (each column still decodes in one launch after its
    chunks reassemble) -- so the chunked makespan is the bound a chunk-granular
    decoder would reach, not what ``StreamingExecutor.run`` delivers today.
    """
    out: list[Job] = []
    for j, k in zip(jobs, n_chunks):
        k = max(1, int(k))
        out.extend(Job(f"{j.name}#{i}", j.transfer_s / k, j.decompress_s / k)
                   for i in range(k))
    return out


def column_of(chunk_name: str) -> str:
    """Invert ``chunk_jobs`` naming: 'L_ORDERKEY#3' -> 'L_ORDERKEY'."""
    return chunk_name.rsplit("#", 1)[0]


def column_order(chunk_names: Sequence[str]) -> list[str]:
    """Column issue order induced by a chunk-level schedule (first appearance).

    Johnson's rule keys only on (transfer, decompress), which are identical for every
    chunk of one column, so a column's chunks stay contiguous and the induced order is
    the order their first chunks hit the link.
    """
    seen: set[str] = set()
    out: list[str] = []
    for cn in chunk_names:
        col = column_of(cn)
        if col not in seen:
            seen.add(col)
            out.append(col)
    return out
