"""Pipelining Layer (paper §3.3): scheduling policies over a two-machine flow shop.

Each data block i is a job with two sequential operations on two "machines":
  machine 1 = host->device link (transfer time a_i),
  machine 2 = on-device decompression (time b_i),
and blocks are independent -- a classic two-machine flow shop.  Johnson (1954) gives
the makespan-optimal order:  jobs with a_i <= b_i first, ascending a_i; then the rest,
descending b_i.  (The paper reports O(n); the textbook bound is O(n log n) for the
sort -- we note the discrepancy and implement the optimal rule.)

The module has three parts:

  * primitive orders and simulators (``johnson_order``, ``fifo_order``,
    ``makespan``, ``simulate_stream``) -- ``simulate_stream`` is the generalized
    simulator that models what the streaming executor actually does: transfer is
    always chunk-granular, decode is chunk-granular (body launches plus an uneven
    tail launch) only for columns running per-chunk decode;
  * chunk-level job expansion (``chunk_jobs`` / ``column_of`` /
    ``column_order``) used to derive column issue orders from chunk-granular
    Johnson schedules;
  * pluggable **policy objects** (``FifoPolicy``, ``JohnsonPolicy``,
    ``ChunkJohnsonPolicy``, ``AdaptivePolicy``) sharing the one simulator -- the
    planner (``core/planner.py``) scores and picks among them instead of the old
    hard-coded executor heuristics.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Sequence


@dataclasses.dataclass(frozen=True)
class Job:
    name: str
    transfer_s: float    # machine-1 time (PCIe/host-link)
    decompress_s: float  # machine-2 time (GPU/TPU kernel)


def johnson_order(jobs: Sequence[Job]) -> list[int]:
    """Return indices into ``jobs`` in Johnson-optimal execution order."""
    first = sorted((i for i, j in enumerate(jobs) if j.transfer_s <= j.decompress_s),
                   key=lambda i: jobs[i].transfer_s)
    second = sorted((i for i, j in enumerate(jobs) if j.transfer_s > j.decompress_s),
                    key=lambda i: -jobs[i].decompress_s)
    return first + second


def makespan(jobs: Sequence[Job], order: Sequence[int] | None = None) -> float:
    """Simulate the two-stage pipeline: transfer is serial on the link; decompression
    of block k starts when both its transfer and block k-1's decompression finish."""
    order = list(range(len(jobs))) if order is None else list(order)
    t_link = 0.0   # when the link frees up
    t_dev = 0.0    # when the device frees up
    for i in order:
        t_link += jobs[i].transfer_s
        t_dev = max(t_dev, t_link) + jobs[i].decompress_s
    return t_dev


def serial_time(jobs: Sequence[Job]) -> float:
    """No pipelining: every block transfers then decompresses exclusively."""
    return sum(j.transfer_s + j.decompress_s for j in jobs)


def brute_force_best(jobs: Sequence[Job]) -> tuple[float, tuple[int, ...]]:
    """Exhaustive optimum (testing only; factorial)."""
    best = (float("inf"), tuple(range(len(jobs))))
    for perm in itertools.permutations(range(len(jobs))):
        m = makespan(jobs, perm)
        if m < best[0]:
            best = (m, perm)
    return best


def schedule(names: Sequence[str], transfer_s: Sequence[float],
             decompress_s: Sequence[float]) -> list[str]:
    """Convenience wrapper used by the data loader: returns block names in optimal
    issue order."""
    jobs = [Job(n, a, b) for n, a, b in zip(names, transfer_s, decompress_s)]
    return [jobs[i].name for i in johnson_order(jobs)]


# ----------------------------------------------------------- chunk-level jobs

def fifo_order(jobs: Sequence[Job]) -> list[int]:
    """Submission order (the no-scheduler baseline)."""
    return list(range(len(jobs)))


CHUNK_SEP = "#"


def _escape(name: str) -> str:
    """Escape the chunk separator in a column name (``#`` -> ``##``)."""
    return name.replace(CHUNK_SEP, CHUNK_SEP * 2)


def _unescape(name: str) -> str:
    return name.replace(CHUNK_SEP * 2, CHUNK_SEP)


def chunk_jobs(jobs: Sequence[Job], n_chunks: Sequence[int],
               tail_frac: Sequence[float] | None = None) -> list[Job]:
    """Split each column job into its chunk-level jobs.

    The streaming executor transfers column ``j`` as ``n_chunks[j]`` pieces and
    -- for element-chunkable columns under per-chunk decode -- launches one
    decode per transferred chunk, so the model here is chunk-granular on BOTH
    machines: it is what ``StreamingExecutor.run(chunk_decode=True)`` executes,
    not merely an unreachable bound.  Chunk ``i`` of column ``name`` is named
    ``escape(name)#i`` (``#`` in column names is escaped as ``##`` so
    ``column_of`` inverts the naming unambiguously).

    ``tail_frac[j]`` in (0, 1] models the uneven final chunk the executor's
    aligned chunk layout produces: chunks ``0..k-2`` carry one full share each
    and the tail carries ``tail_frac`` of a share (total time is preserved).
    Default is an even split.  Finer jobs let the two-machine pipeline overlap
    *within* a column, which whole-column jobs cannot:
    makespan(chunked, Johnson) <= makespan(whole, Johnson).
    """
    out: list[Job] = []
    tails = [1.0] * len(jobs) if tail_frac is None else list(tail_frac)
    for j, k, tf in zip(jobs, n_chunks, tails):
        k = max(1, int(k))
        tf = min(1.0, max(tf, 1e-9)) if k > 1 else 1.0
        denom = (k - 1) + tf
        base = _escape(j.name)
        for i in range(k):
            w = (tf if i == k - 1 else 1.0) / denom
            out.append(Job(f"{base}{CHUNK_SEP}{i}",
                           j.transfer_s * w, j.decompress_s * w))
    return out


def column_of(chunk_name: str) -> str:
    """Invert ``chunk_jobs`` naming: 'L_ORDERKEY#3' -> 'L_ORDERKEY' (unescaping
    any ``##`` the column name's own ``#`` characters became)."""
    return _unescape(chunk_name.rsplit(CHUNK_SEP, 1)[0])


def column_order(chunk_names: Sequence[str]) -> list[str]:
    """Column issue order induced by a chunk-level schedule (first appearance).

    Johnson's rule keys only on (transfer, decompress), which are identical for every
    full chunk of one column, so a column's chunks stay (near-)contiguous and the
    induced order is the order their first chunks hit the link.
    """
    seen: set[str] = set()
    out: list[str] = []
    for cn in chunk_names:
        col = column_of(cn)
        if col not in seen:
            seen.add(col)
            out.append(col)
    return out


# ----------------------------------------------------- generalized simulator

@dataclasses.dataclass(frozen=True)
class ChunkInfo:
    """Per-column chunking configuration for ``simulate_stream``.

    ``n_chunks`` transfer pieces; ``chunk_decode`` selects per-chunk decode
    (one body launch per chunk plus the uneven ``tail_frac`` tail launch)
    versus one whole-column launch after the last chunk arrives;
    ``launch_overhead_s`` is the cost of each decode launch beyond the first.
    ``weights`` optionally replaces the uniform-body + tail split with explicit
    per-chunk (transfer, decode) fractions -- group-boundary chunks are
    genuinely uneven (data-dependent group sizes, whole-resident prologue bytes
    all ahead of span 0), so the simulator models per-chunk byte counts rather
    than assuming even splits.  Fractions are normalized per machine; ignored
    unless ``len(weights) == n_chunks``.
    """

    n_chunks: int = 1
    chunk_decode: bool = False
    tail_frac: float = 1.0
    launch_overhead_s: float = 0.0
    weights: tuple[tuple[float, float], ...] = ()


def _chunk_fractions(info: ChunkInfo, k: int) -> tuple[list[float], list[float]]:
    """Per-chunk (transfer, decode) fractions, each summing to 1."""
    w = info.weights
    if w and len(w) == k:
        ts = sum(x[0] for x in w) or 1.0
        ds = sum(x[1] for x in w) or 1.0
        return [x[0] / ts for x in w], [x[1] / ds for x in w]
    tf = min(1.0, max(info.tail_frac, 1e-9)) if k > 1 else 1.0
    denom = (k - 1) + tf
    frac = [1.0 / denom] * (k - 1) + [tf / denom]
    return frac, list(frac)


def simulate_stream(jobs: Sequence[Job],
                    infos: Sequence[ChunkInfo] | None = None,
                    order: Sequence[int] | None = None,
                    window: int | None = None) -> float:
    """Makespan of the streaming executor's actual pipeline shape.

    Transfer is serial on the link and always chunk-granular.  Decode of a
    per-chunk column launches per transferred chunk (body launches + uneven
    tail, or explicit per-chunk weights for group-boundary spans); a
    whole-decode column's single launch waits for its *last* chunk.  With
    default infos this reduces exactly to ``makespan``.

    ``window`` bounds the number of transferred-but-undecoded chunks in
    flight (the staging-buffer budget): transfer of a new per-chunk-decode
    chunk stalls until the chunk ``window`` places ahead of it has decoded
    and freed its slot (FIFO -- decode completions are monotone).  Only
    per-chunk-decode chunks hold slots; a whole-decode column's pieces go
    straight into its reassembly buffer.  ``None`` keeps the link free-running
    (unbounded staging), matching the historical model.
    """
    return simulate_stream_finish(jobs, infos, order, window)[0]


def simulate_stream_finish(jobs: Sequence[Job],
                           infos: Sequence[ChunkInfo] | None = None,
                           order: Sequence[int] | None = None,
                           window: int | None = None
                           ) -> tuple[float, list[float]]:
    """``simulate_stream`` plus per-JOB decode-completion times.

    Returns ``(makespan, finish)`` where ``finish[i]`` is the simulated time
    job ``i``'s last decode launch completes (indexed like ``jobs``, not like
    ``order``).  This is what multi-query planning needs: N interleaved
    queries share one link, and a query is done when the *latest* of its
    columns finishes -- the per-job completion vector turns one shared-link
    simulation into per-query latency estimates, so issue orders can be
    scored on tail latency as well as aggregate makespan.
    """
    order = list(range(len(jobs))) if order is None else list(order)
    infos = [ChunkInfo()] * len(jobs) if infos is None else list(infos)
    w = None if window is None else max(1, int(window))
    t_link = 0.0
    t_dev = 0.0
    job_finish = [0.0] * len(jobs)
    finish: list[float] = []  # decode completion per held chunk, transfer order
    for idx in order:
        j, info = jobs[idx], infos[idx]
        k = max(1, int(info.n_chunks))
        tw, dw = _chunk_fractions(info, k)
        if info.chunk_decode and k > 1:
            for i in range(k):
                m = len(finish)
                if w is not None and m >= w:
                    t_link = max(t_link, finish[m - w])
                t_link += j.transfer_s * tw[i]
                t_dev = (max(t_dev, t_link) + j.decompress_s * dw[i]
                         + (info.launch_overhead_s if i else 0.0))
                finish.append(t_dev)
        else:
            t_link += j.transfer_s
            t_dev = max(t_dev, t_link) + j.decompress_s
        job_finish[idx] = t_dev
    return t_dev, job_finish


def simulate_stream_multi(jobs: Sequence[Job],
                          infos: Sequence[ChunkInfo] | None = None,
                          assignment: Sequence[int] | None = None,
                          n_links: int | None = None,
                          order: Sequence[int] | None = None,
                          window: int | None = None,
                          link_scale: Sequence[float] = (),
                          link_latency_s: Sequence[float] = (),
                          host_window: int | None = None,
                          serial_issue: bool = False,
                          d2d_copies: Sequence[tuple[int, float]] | None = None
                          ) -> tuple[float, list[float]]:
    """``simulate_stream_finish`` over N independent host->device links.

    ``assignment[i]`` is the link (= device) job ``i`` streams over; every
    link is an independent machine-1 feeding its own device's machine-2, so
    the mesh pipeline is N two-machine flow shops coupled only through the
    HOST side: one staging pool (``host_window`` caps the total number of
    transferred-but-undecoded per-chunk-decode chunks in flight across ALL
    links, the shared pinned-host-buffer budget) plus per-link FIFO windows
    (``window``, same meaning as ``simulate_stream``).

    Per-link heterogeneity: ``link_scale[d]`` multiplies transfer times on
    link ``d`` (1.0 = the cost model's calibrated host link) and
    ``link_latency_s[d]`` adds a fixed per-piece issue latency -- the
    topology parameters ``CostModel.topology`` carries.

    The host issues greedily to whichever link frees up first (ties to the
    lowest link id), each link draining its jobs in ``order``'s induced
    suborder.  With one default link this reduces EXACTLY to
    ``simulate_stream_finish``.  Returns ``(makespan, finish)`` where the
    makespan is the latest device-side completion across links.

    ``serial_issue=True`` instead models the legacy one-host-thread loop the
    pre-async executor ran: link ``d``'s first piece issues only after link
    ``d-1``'s leg has fully decoded (devices serviced strictly one at a
    time), so the N flow shops degenerate into a chain.  Comparing the two
    modes on the SAME assignment prices exactly what concurrent per-device
    issuance (``run_sharded(concurrent=True)``) buys.

    ``d2d_copies`` models the REBALANCE phase of a two-tier topology: each
    ``(job_idx, copy_s)`` is a device->device copy of job ``job_idx``'s
    decoded output over the D2D fabric, ready the moment that job's decode
    finishes.  The fabric is one serial machine (NVLink-class links are
    full-duplex but a single engine drives the copies here, matching the
    executor's one-``device_put``-at-a-time issuance per leg): copies are
    processed in ready order, each extending that job's finish time, and
    they OVERLAP all remaining H2D transfers and decodes on other jobs --
    only the copied job's completion (and hence possibly the makespan)
    moves.  ``None``/empty reduces exactly to the single-tier model.
    """
    order = list(range(len(jobs))) if order is None else list(order)
    infos = [ChunkInfo()] * len(jobs) if infos is None else list(infos)
    assignment = [0] * len(jobs) if assignment is None else list(assignment)
    L = max(1, int(n_links)) if n_links is not None else \
        (max(assignment) + 1 if assignment else 1)
    scale = [float(link_scale[d]) if d < len(link_scale) else 1.0
             for d in range(L)]
    lat = [float(link_latency_s[d]) if d < len(link_latency_s) else 0.0
           for d in range(L)]
    w = None if window is None else max(1, int(window))
    hw = None if host_window is None else max(1, int(host_window))

    def rebalance(makespan: float, job_finish: list[float]
                  ) -> tuple[float, list[float]]:
        # D2D rebalance phase: one serial fabric machine, copies ready at
        # their job's decode completion, processed earliest-ready first.
        if not d2d_copies:
            return makespan, job_finish
        pend = sorted(((job_finish[i], k) for k, (i, _) in
                       enumerate(d2d_copies) if 0 <= i < len(job_finish)))
        t_fab = 0.0
        for ready, k in pend:
            i, copy_s = d2d_copies[k]
            t_fab = max(t_fab, ready) + max(0.0, float(copy_s))
            job_finish[i] = max(job_finish[i], t_fab)
        return max([makespan] + job_finish), job_finish

    # expand jobs into per-link chunk queues (transfer_s, decode_s, holds_slot)
    queues: list[list[tuple[int, float, float, bool]]] = [[] for _ in range(L)]
    for idx in order:
        j, info = jobs[idx], infos[idx]
        d = assignment[idx] % L
        k = max(1, int(info.n_chunks))
        tw, dw = _chunk_fractions(info, k)
        if info.chunk_decode and k > 1:
            for i in range(k):
                queues[d].append(
                    (idx, j.transfer_s * tw[i],
                     j.decompress_s * dw[i]
                     + (info.launch_overhead_s if i else 0.0), True))
        else:
            queues[d].append((idx, j.transfer_s, j.decompress_s, False))

    if serial_issue:
        # legacy host loop: one link at a time, chained on full decode
        t_prev = 0.0
        held_s: list[float] = []
        dev_done = [0.0] * L
        job_finish = [0.0] * len(jobs)
        for d in range(L):
            t_l = t_prev
            t_d = t_prev
            lf: list[float] = []
            for idx, ts, ds, holds in queues[d]:
                start = t_l
                if holds and w is not None and len(lf) >= w:
                    start = max(start, lf[len(lf) - w])
                if holds and hw is not None:
                    while len(held_s) >= hw:
                        start = max(start, heapq.heappop(held_s))
                t_l = start + ts * scale[d] + lat[d]
                t_d = max(t_d, t_l) + ds
                if holds:
                    lf.append(t_d)
                    if hw is not None:
                        heapq.heappush(held_s, t_d)
                job_finish[idx] = t_d
            dev_done[d] = t_d
            if queues[d]:
                t_prev = t_d
        return rebalance(max(dev_done), job_finish)

    t_link = [0.0] * L
    t_dev = [0.0] * L
    ptr = [0] * L
    # per-link decode completions of held chunks (FIFO per-link window), plus
    # one global min-heap for the shared host staging budget
    link_finish: list[list[float]] = [[] for _ in range(L)]
    held: list[float] = []
    job_finish = [0.0] * len(jobs)
    while True:
        # the host services whichever link can start its next piece earliest
        # (per-link window stalls included; the shared budget is applied after
        # the pick -- it frees in global decode-completion order either way)
        best_d, best_t = -1, float("inf")
        for d in range(L):
            if ptr[d] >= len(queues[d]):
                continue
            start = t_link[d]
            holds = queues[d][ptr[d]][3]
            if holds and w is not None:
                m = len(link_finish[d])
                if m >= w:
                    start = max(start, link_finish[d][m - w])
            if start < best_t - 1e-18:
                best_d, best_t = d, start
        if best_d < 0:
            break
        d = best_d
        idx, ts, ds, holds = queues[d][ptr[d]]
        ptr[d] += 1
        start = best_t
        if holds and hw is not None:
            # shared staging pool: stall until enough held chunks have decoded
            # (slots free at decode completion, earliest-finishing first)
            while len(held) >= hw:
                start = max(start, heapq.heappop(held))
        t_link[d] = start + ts * scale[d] + lat[d]
        t_dev[d] = max(t_dev[d], t_link[d]) + ds
        if holds:
            link_finish[d].append(t_dev[d])
            if hw is not None:
                heapq.heappush(held, t_dev[d])
        job_finish[idx] = t_dev[d]
    return rebalance(max(t_dev), job_finish)


# ------------------------------------------------------- scheduling policies

class SchedulingPolicy:
    """Order + makespan model for a set of column jobs.

    ``order`` returns column indices; ``modeled_makespan`` scores the policy's
    order under the shared ``simulate_stream`` simulator, so every policy is
    judged by the same per-chunk pipeline model.
    """

    name = "base"

    def order(self, jobs: Sequence[Job],
              infos: Sequence[ChunkInfo] | None = None) -> list[int]:
        raise NotImplementedError

    def modeled_makespan(self, jobs: Sequence[Job],
                         infos: Sequence[ChunkInfo] | None = None) -> float:
        return simulate_stream(jobs, infos, self.order(jobs, infos))


class FifoPolicy(SchedulingPolicy):
    """Submission order -- the no-scheduler baseline."""

    name = "fifo"

    def order(self, jobs, infos=None):
        return fifo_order(jobs)


class JohnsonPolicy(SchedulingPolicy):
    """Whole-column Johnson's rule (paper §3.3)."""

    name = "johnson"

    def order(self, jobs, infos=None):
        return johnson_order(jobs)


class ChunkJohnsonPolicy(SchedulingPolicy):
    """Johnson's rule at chunk granularity; the induced column order issues
    decode-heavy columns' first chunks ahead of transfer-heavy ones."""

    name = "chunk-johnson"

    def order(self, jobs, infos=None):
        if infos is None:
            return johnson_order(jobs)
        cjobs = chunk_jobs(jobs, [i.n_chunks for i in infos],
                           [i.tail_frac for i in infos])
        corder = johnson_order(cjobs)
        cols = column_order([cjobs[i].name for i in corder])
        index = {j.name: i for i, j in enumerate(jobs)}
        return [index[c] for c in cols]


class AdaptivePolicy(SchedulingPolicy):
    """Pick the best of the fixed policies *for this job set* by simulated
    makespan -- never worse than any single one under the shared model."""

    name = "adaptive"

    def __init__(self):
        self.candidates: tuple[SchedulingPolicy, ...] = (
            FifoPolicy(), JohnsonPolicy(), ChunkJohnsonPolicy())

    def order(self, jobs, infos=None):
        best, best_mk = list(range(len(jobs))), float("inf")
        for pol in self.candidates:
            order = pol.order(jobs, infos)
            mk = simulate_stream(jobs, infos, order)
            if mk < best_mk:
                best, best_mk = order, mk
        return best


POLICIES: dict[str, type[SchedulingPolicy]] = {
    p.name: p for p in (FifoPolicy, JohnsonPolicy, ChunkJohnsonPolicy,
                        AdaptivePolicy)}


def get_policy(policy: str | SchedulingPolicy) -> SchedulingPolicy:
    """Resolve a policy name (or pass an instance through)."""
    if isinstance(policy, SchedulingPolicy):
        return policy
    try:
        return POLICIES[policy]()
    except KeyError:
        raise ValueError(f"unknown scheduling policy {policy!r}; "
                         f"known: {sorted(POLICIES)}") from None
