"""Offline geometry tuning (paper §5.5, Table 3).

Two searchers over the per-pattern <L,S,C> spaces of ``repro.core.geometry``:

  * ``brute_force``  -- evaluate every valid tuple (the paper's "B.F. Search").
  * ``pruned_search``-- the paper's "R.L. Search": exploit the (empirically monotone /
    unimodal) performance structure along each axis with a per-coordinate hill walk on
    the powers-of-two grid.  Probe counts land in the paper's reported regime
    (~3+4+0 for F.P. on a chip with fixed C).

Both take an arbitrary ``measure`` callable so the same machinery runs against the
analytic model offline (this container) or wall-clock kernels on real hardware.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterable

from repro.core.geometry import ChipSpec, Geometry, SPACES, analytic_cost_ns


@dataclasses.dataclass
class TuneResult:
    best: Geometry
    cost: float
    probes: int
    history: list[tuple[Geometry, float]]


def brute_force(pattern: str, spec: ChipSpec, measure: Callable[[Geometry], float],
                itemsize: int = 4) -> TuneResult:
    history = []
    best, best_cost = None, float("inf")
    for g in SPACES[pattern](spec, itemsize):
        c = measure(g)
        history.append((g, c))
        if c < best_cost:
            best, best_cost = g, c
    return TuneResult(best, best_cost, probes=len(history), history=history)


def _axis_values(pattern: str, spec: ChipSpec, itemsize: int) -> dict[str, list[int]]:
    space = list(SPACES[pattern](spec, itemsize))
    return {ax: sorted({getattr(g, ax) for g in space}) for ax in ("L", "S", "C")}


def pruned_search(pattern: str, spec: ChipSpec, measure: Callable[[Geometry], float],
                  itemsize: int = 4) -> TuneResult:
    """Coordinate descent with monotone early-exit per axis.

    For each axis in turn, walk the powers-of-two ladder upward from the current value
    and stop the first time cost worsens (unimodality).  Cache measurements so a config
    is never probed twice.  One pass over (L, S, C) suffices on the modelled landscape;
    we iterate to fixpoint for safety on noisy measurements.
    """
    axes = _axis_values(pattern, spec, itemsize)
    valid = set(SPACES[pattern](spec, itemsize))
    cache: dict[Geometry, float] = {}

    def probe(g: Geometry) -> float | None:
        if g not in valid:
            return None
        if g not in cache:
            cache[g] = measure(g)
        return cache[g]

    # start at the smallest valid tuple
    cur = Geometry(axes["L"][0], axes["S"][0], axes["C"][0])
    if cur not in valid:
        cur = next(iter(sorted(valid, key=lambda g: g.tile)))
    cur_cost = probe(cur)
    assert cur_cost is not None
    improved = True
    while improved:
        improved = False
        for ax in ("L", "S", "C"):
            ladder = axes[ax]
            start = ladder.index(getattr(cur, ax))
            # walk up, then down, stopping on first regression (unimodal assumption)
            for direction in (1, -1):
                k = start + direction
                while 0 <= k < len(ladder):
                    g = dataclasses.replace(cur, **{ax: ladder[k]})
                    c = probe(g)
                    if c is None or c >= cur_cost:
                        break
                    cur, cur_cost, improved = g, c, True
                    k += direction
    history = sorted(cache.items(), key=lambda kv: kv[1])
    return TuneResult(cur, cur_cost, probes=len(cache), history=history)


def analytic_measure(pattern: str, spec: ChipSpec, n_elems: int = 1 << 24,
                     itemsize: int = 4) -> Callable[[Geometry], float]:
    return lambda g: analytic_cost_ns(pattern, g, n_elems, itemsize, spec)
