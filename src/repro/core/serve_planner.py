"""Multi-query serving planner: one transfer queue, N concurrent requests.

The per-query planner (``core/planner.py``) optimizes one column set's
compress->transfer->decode flow in isolation.  A serving system has many
concurrent requests contending for ONE host->device link, and that contention
is where holistic scheduling dominates per-query tuning: the link is a shared
machine-1, the decode device a shared machine-2, and every request's columns
are jobs in one big two-machine flow shop.

``ServePlanner`` composes per-query ``ExecutionPlan``s under that contention:

  * **Shared transfer queue** -- ``submit`` registers a request's columns
    under rid-namespaced names (``"<rid>/<col>"``); ``drain`` plans ONE
    execution over the union of all pending requests' columns and runs it as
    a single ``StreamingExecutor.run`` -- cross-column pipelining spans
    request boundaries instead of stopping at them.  Identical ``Encoded``
    blobs submitted by different requests decode once and fan out.
  * **Cross-query batching** -- structural signatures are request-agnostic
    (operand-lifted meta, PR 2), so same-signature columns from DIFFERENT
    requests mark ``batched`` and decode in one vmap launch through the one
    shared ProgramCache program.  Shared issue orders additionally cluster
    same-signature columns adjacently (the executor batches only adjacent
    plan-marked columns), which per-query FIFO composition cannot do.
  * **Admission / issue ordering** -- candidate orders (union-adaptive,
    naive per-query FIFO composition, greedy marginal-makespan over request
    permutations, SLO hoisting, batched-clustered variants of each) are all
    scored with ``scheduler.simulate_stream_finish`` -- the chunk-granular
    shared-link simulator extended to return per-JOB completion times, so N
    interleaved queries on one link yield per-REQUEST latency estimates.
    The naive composition is itself a candidate, so the shared plan's
    simulated makespan is <= the per-query FIFO baseline BY CONSTRUCTION.
  * **Latency-vs-throughput knobs** -- ``policy="shared"`` minimizes
    aggregate makespan; ``policy="slo"`` minimizes point-class tail latency
    first (hoisting point requests' columns to the front) and additionally
    lets a point query PREEMPT a bulk scan at the next chunk/unit boundary:
    the executor's ``preempt`` hook calls back into the planner, which runs
    newly-arrived point requests as a nested wave while the bulk column's
    remaining chunks are still in flight.  ``policy="fifo-per-query"`` is
    the naive baseline, kept runnable for measured A/B comparisons.

Measured actuals feed the shared ``CostModel`` exactly like single-query
runs; per-request names are unregistered after each wave, but per-signature
timing history survives, so wave N+1 plans from wave N's calibration.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Mapping, Sequence

from repro.core import plan as plan_mod, planner as planner_mod, scheduler
from repro.core.executor import ColumnExec, StreamingExecutor
from repro.core.planner import ColumnDecision, ExecutionPlan
from repro.core.scheduler import ChunkInfo

SEP = "/"           # rid-namespace separator: "<rid>/<col>"

POINT, BULK = "point", "bulk"


def qualify(rid, col: str) -> str:
    """Namespaced executor name for one request's column."""
    return f"{rid}{SEP}{col}"


def rid_of(qname: str) -> str:
    """Invert ``qualify`` (rids must not contain ``/``; column names may)."""
    return qname.split(SEP, 1)[0]


@dataclasses.dataclass
class ServeRequest:
    """One submitted request: a set of compressed columns wanted on device."""

    rid: str
    encs: dict[str, plan_mod.Encoded]
    klass: str = BULK                   # "bulk" | "point" (SLO class)
    submitted_at: float = 0.0           # perf_counter at submit
    results: dict[str, ColumnExec] = dataclasses.field(default_factory=dict)
    done: bool = False
    latency_s: float = 0.0              # submit -> last column materialized
    modeled_finish_s: float = 0.0       # simulated finish under the chosen plan
    preempted_in: bool = False          # serviced by a preemptive nested wave
    # a wave failure lands HERE (per-request), not in whatever thread happened
    # to be draining -- submitters poll done/error or block on wait()
    error: BaseException | None = None
    _done_evt: threading.Event = dataclasses.field(
        default_factory=threading.Event, repr=False, compare=False)

    @property
    def arrays(self) -> dict[str, object]:
        return {c: r.array for c, r in self.results.items()}

    def wait(self, timeout: float | None = None) -> bool:
        """Block until this request is serviced (or its wave failed); True
        once ``done``.  The completion signal for the background drain loop,
        where no ``drain()`` return value hands the request back."""
        return self._done_evt.wait(timeout)

    def _finish(self, error: BaseException | None = None) -> None:
        if error is not None and self.error is None:
            self.error = error
        self.done = True
        self._done_evt.set()


@dataclasses.dataclass
class WaveReport:
    """Accounting for one drained wave (one shared ``executor.run``)."""

    rids: tuple[str, ...]
    policy: str
    chosen: str                          # winning candidate's label
    order: tuple[str, ...]
    window: int
    shared_makespan_s: float             # chosen plan, shared simulator
    naive_makespan_s: float              # per-query FIFO composition, same model
    candidates: dict[str, float]         # label -> simulated makespan
    modeled_finish_s: dict[str, float]   # rid -> simulated completion
    naive_finish_s: dict[str, float]     # rid -> completion under naive order
    wall_s: float = 0.0
    decode_launches: int = 0
    cross_batched_saved: int = 0         # launches removed by cross-rid batching
    preempted: int = 0                   # point requests serviced mid-wave
    devices: tuple[int, ...] = ()        # mesh waves: device ids spanned
    device_launches: dict[int, int] = dataclasses.field(default_factory=dict)
    # mesh waves with a D2D fabric: executed redistribution legs,
    # item -> (src physical device, dst physical device, copy seconds)
    d2d_copies: dict[str, tuple[int, int, float]] = dataclasses.field(
        default_factory=dict)


class ServePlanner:
    """Shared-resource planner over one ``StreamingExecutor``.

    ``submit`` is thread-safe (concurrent producers share one queue and one
    ProgramCache); ``drain`` runs waves until the queue is empty and returns
    every serviced request.  ``max_wave`` bounds how many requests one wave
    composes (None = all pending).
    """

    def __init__(self, executor: StreamingExecutor | None = None,
                 policy: str = "shared", max_wave: int | None = None,
                 mesh: int | None = None, placement: str | None = None):
        if policy not in ("shared", "slo", "fifo-per-query"):
            raise ValueError(f"unknown serve policy {policy!r}; known: "
                             "shared, slo, fifo-per-query")
        self.executor = executor or StreamingExecutor()
        self.policy = policy
        self.max_wave = max_wave
        # mesh=N: waves span N devices -- the union plan re-partitions through
        # plan_mesh_execution and runs via run_sharded (per-device launch
        # accounting lands in WaveReport.device_launches).  placement="sharded"
        # additionally pins each column shard's FINAL device, letting the
        # planner land bytes on fast links and rebalance over the D2D fabric
        # (executed legs land in WaveReport.d2d_copies)
        self.mesh = mesh
        self.placement = placement
        self._lock = threading.Lock()
        self._pending: deque[ServeRequest] = deque()
        self._served: deque[ServeRequest] = deque()   # preemptive completions
        self._in_wave = False
        self._last_preempted = 0
        self.reports: list[WaveReport] = []
        # always-on drain loop (start()/stop()): _wave_mutex serializes wave
        # execution between the background thread and explicit drain() callers
        # -- the executor's registries and jit tracing are single-threaded
        self._wave_mutex = threading.RLock()
        self._arrival = threading.Event()
        self._stop_evt = threading.Event()
        self._drain_thread: threading.Thread | None = None

    # ------------------------------------------------------------- admission
    @property
    def pending(self) -> int:
        with self._lock:
            return len(self._pending)

    def submit(self, rid, encs: Mapping[str, plan_mod.Encoded],
               klass: str = BULK) -> ServeRequest:
        """Enqueue a request (thread-safe).  Decode happens at ``drain``."""
        rid = str(rid)
        if SEP in rid:
            raise ValueError(f"rid {rid!r} must not contain {SEP!r}")
        req = ServeRequest(rid=rid, encs=dict(encs), klass=klass,
                           submitted_at=time.perf_counter())
        with self._lock:
            if any(r.rid == rid for r in self._pending):
                raise ValueError(f"rid {rid!r} already pending")
            self._pending.append(req)
        self._arrival.set()     # wake the background drain loop, if running
        return req

    # ----------------------------------------------------------------- drain
    def drain(self) -> dict[str, ServeRequest]:
        """Service every pending request; returns ``{rid: request}``.

        Serialized against the background drain loop under ``_wave_mutex``
        (one wave runs at a time; tracing and the executor's name registry
        are not re-entrant across threads).  A wave that raises attaches the
        exception to each of its requests (``req.error``) and keeps draining
        the rest -- submitters see failures per-request, never a dead drain
        thread."""
        done: dict[str, ServeRequest] = {}
        with self._wave_mutex:
            while True:
                with self._lock:
                    # requests completed by a preemptive nested wave surface
                    # here too, including when nothing is left pending
                    while self._served:
                        req = self._served.popleft()
                        done[req.rid] = req
                    if not self._pending:
                        break
                    n = len(self._pending) if self.max_wave is None \
                        else min(self.max_wave, len(self._pending))
                    wave = [self._pending.popleft() for _ in range(n)]
                try:
                    report = self._run_wave(wave)
                except Exception as e:
                    for req in wave:
                        req._finish(e)
                        done[req.rid] = req
                    continue
                self.reports.append(report)
                for req in wave:
                    done[req.rid] = req
        return done

    # ------------------------------------------------------ always-on drain
    def start(self, poll_s: float = 0.05) -> "ServePlanner":
        """Start the always-on drain loop: a background thread services
        arrivals continuously, forming a wave from whatever is queued each
        time the executor goes idle -- ``submit()`` alone completes requests
        (block on ``req.wait()``); explicit ``drain()`` keeps working and
        simply runs the next wave on the caller's thread.  Idempotent."""
        with self._lock:
            if self._drain_thread is not None and self._drain_thread.is_alive():
                return self
            self._stop_evt.clear()
            self._drain_thread = threading.Thread(
                target=self._drain_loop, args=(poll_s,),
                name="zipflow-serve-drain", daemon=True)
            self._drain_thread.start()
        return self

    def stop(self, wait: bool = True) -> None:
        """Stop the drain loop.  In-flight waves complete; anything submitted
        before ``stop`` is still serviced (one final sweep), so a clean stop
        strands no request."""
        t = self._drain_thread
        self._stop_evt.set()
        self._arrival.set()
        if wait and t is not None and t is not threading.current_thread():
            t.join(timeout=120.0)
        self._drain_thread = None

    def _drain_loop(self, poll_s: float = 0.05) -> None:
        while not self._stop_evt.is_set():
            self._arrival.wait(timeout=poll_s)
            self._arrival.clear()
            if self._stop_evt.is_set():
                break
            if self.pending:
                self.drain()
        if self.pending:        # final sweep: pre-stop submissions complete
            self.drain()

    # ------------------------------------------------------------ preemption
    def _preempt(self) -> None:
        """Executor yield-point hook (``policy="slo"``): newly-arrived point
        requests cut in at the next chunk/unit boundary of the running wave
        via a nested run on the same executor."""
        if self._in_wave:
            urgent: list[ServeRequest] = []
            with self._lock:
                for req in list(self._pending):
                    if req.klass == POINT:
                        self._pending.remove(req)
                        urgent.append(req)
            if urgent:
                self._in_wave = False          # nested waves must not recurse
                try:
                    report = self._run_wave(urgent, preemptive=True)
                finally:
                    self._in_wave = True
                self.reports.append(report)
                with self._lock:
                    for req in urgent:
                        req.preempted_in = True
                        self._served.append(req)
                self._last_preempted += len(urgent)

    # ------------------------------------------------------------- wave core
    def _run_wave(self, reqs: Sequence[ServeRequest],
                  preemptive: bool = False) -> WaveReport:
        ex = self.executor
        t_wave0 = time.perf_counter()
        # register the union, deduplicating identical Encoded objects: two
        # requests shipping the SAME blob share one decode (the results fan
        # out), which no per-query execution can do
        primary: dict[int, str] = {}
        encs: dict[str, plan_mod.Encoded] = {}
        owners: dict[str, list[tuple[ServeRequest, str]]] = {}
        req_names: dict[str, list[str]] = {r.rid: [] for r in reqs}
        for req in reqs:
            for col, enc in req.encs.items():
                qn = qualify(req.rid, col)
                p = primary.get(id(enc))
                if p is None:
                    primary[id(enc)] = p = qn
                    encs[qn] = enc
                    owners[qn] = []
                owners[p].append((req, col))
                if p not in req_names[req.rid]:
                    req_names[req.rid].append(p)
        for qn, enc in encs.items():
            if qn in ex._encoded:
                raise ValueError(
                    f"{qn!r} is already registered (in-flight wave?) -- "
                    "rids must be unique across concurrent waves")
            ex.compile(qn, enc)

        try:
            ep, report = self._plan_wave(reqs, list(encs), req_names)
            ready_at: dict[str, float] = {}

            def on_ready(name: str) -> None:
                ready_at[name] = time.perf_counter()

            use_mesh = (self.mesh or 0) > 1 and not preemptive
            # mesh waves trade chunk-boundary preemption for per-link
            # parallelism: urgent point requests still cut in BETWEEN waves
            use_preempt = (self.policy == "slo" and not preemptive
                           and not use_mesh)
            if not preemptive:       # nested waves must not clobber the count
                self._last_preempted = 0
            self._in_wave = use_preempt
            try:
                if use_mesh:
                    profiles = {n: ex.column_profile(n) for n in encs}
                    mesh_ep = planner_mod.plan_mesh_execution(
                        profiles, ex.cost_model, n_devices=int(self.mesh),
                        window=ep.window, placement=self.placement)
                    report.chosen = f"mesh:{mesh_ep.policy}"
                    report.candidates["mesh"] = mesh_ep.modeled_makespan_s
                    report.shared_makespan_s = mesh_ep.modeled_makespan_s
                    report.devices = tuple(sorted(mesh_ep.device_ids))
                    mres = ex.run_sharded(mesh_ep, on_ready=on_ready)
                    results = mres.columns
                    report.device_launches = dict(mres.device_launches)
                    report.d2d_copies = dict(mres.d2d_copies)
                else:
                    results = ex.run(
                        encs, plan=ep,
                        preempt=self._preempt if use_preempt else None,
                        on_ready=on_ready)
            finally:
                self._in_wave = False
            report.wall_s = time.perf_counter() - t_wave0
            report.preempted = 0 if preemptive else self._last_preempted

            # fan results out (aliased columns share the decoded array)
            for qn, rec in results.items():
                for req, col in owners[qn]:
                    req.results[col] = rec
            for req in reqs:
                t_ready = max((ready_at[p] for p in req_names[req.rid]
                               if p in ready_at), default=time.perf_counter())
                req.latency_s = t_ready - req.submitted_at
                req.modeled_finish_s = report.modeled_finish_s.get(
                    req.rid, report.shared_makespan_s)
                req._finish()

            # launch accounting: a batched group of k columns is ONE launch;
            # cross_batched_saved counts launches a per-query execution would
            # have needed on top (one per rid present in each cross-rid group)
            seen: set[frozenset] = set()
            launches = saved = 0
            for qn, rec in results.items():
                if rec.batched_with:
                    g = frozenset((qn,) + rec.batched_with)
                    if g in seen:
                        continue
                    seen.add(g)
                    launches += 1
                    rids = {rid_of(n) for n in g}
                    if len(rids) > 1:
                        saved += len(rids) - 1
                else:
                    launches += rec.decode_launches
            report.decode_launches = launches
            report.cross_batched_saved = saved
            return report
        finally:
            for qn in encs:
                ex.unregister(qn)

    # ---------------------------------------------------------- wave planning
    def _plan_wave(self, reqs: Sequence[ServeRequest], names: list[str],
                   req_names: dict[str, list[str]]
                   ) -> tuple[ExecutionPlan, WaveReport]:
        """Score candidate issue orders under the shared-link simulator and
        build the winning ``ExecutionPlan``.  The naive per-query FIFO
        composition is always among the candidates, so the chosen makespan
        never exceeds it (except under ``slo``, which trades makespan for
        point-class tail latency -- both numbers are reported)."""
        ex = self.executor
        cm = ex.cost_model
        idx = {n: i for i, n in enumerate(names)}
        sig_of = {n: ex.graph(n).signature for n in names}

        # union-adaptive plan: chunk configs x fifo/johnson/chunk-johnson
        # searched over ALL requests' columns at once
        ep_u = ex.plan(names, policy="adaptive")
        jobs = cm.jobs(names)
        overhead = {n: cm.launch_overhead_s(n) for n in names}

        def infos_of(decisions: Mapping[str, ColumnDecision]) -> list[ChunkInfo]:
            return [ChunkInfo(
                n_chunks=max(1, decisions[n].n_chunks),
                chunk_decode=decisions[n].decode_mode == planner_mod.CHUNK,
                tail_frac=decisions[n].tail_frac,
                launch_overhead_s=overhead[n],
                weights=decisions[n].weights) for n in names]

        # per-request plans: what each query would do for itself -- their
        # concatenation in submission order IS the naive per-query FIFO server
        per_req_order: dict[str, list[str]] = {}
        merged_dec: dict[str, ColumnDecision] = {}
        for req in reqs:
            rnames = req_names[req.rid]
            if not rnames:               # fully deduplicated against earlier reqs
                per_req_order[req.rid] = []
                continue
            ep_r = ex.plan(rnames, policy="adaptive")
            per_req_order[req.rid] = [n for n in ep_r.order if n in idx]
            merged_dec.update({n: ep_r.decisions[n] for n in rnames})
        naive_order = [n for req in reqs for n in per_req_order[req.rid]]

        def cluster(order: Sequence[str],
                    decisions: Mapping[str, ColumnDecision]) -> list[str]:
            """Pull same-signature batched columns adjacent (stable): the
            executor only merges ADJACENT batched columns into one vmap
            launch, and same-signature jobs have interchangeable times."""
            placed: set[str] = set()
            out: list[str] = []
            for n in order:
                if n in placed:
                    continue
                out.append(n)
                placed.add(n)
                if decisions[n].decode_mode == planner_mod.BATCHED:
                    for m in order:
                        if (m not in placed and sig_of[m] == sig_of[n]
                                and decisions[m].decode_mode
                                == planner_mod.BATCHED):
                            out.append(m)
                            placed.add(m)
            return out

        def mark_batched(decisions: dict[str, ColumnDecision]) -> None:
            """Cross-REQUEST batching marks: whole-mode columns sharing a
            structural signature (request-agnostic by construction) decode in
            one vmap launch when adjacent."""
            by_sig: dict[str, list[str]] = {}
            for n, d in decisions.items():
                if d.decode_mode in (planner_mod.WHOLE, planner_mod.BATCHED) \
                        and not d.fused:
                    by_sig.setdefault(sig_of[n], []).append(n)
            for ns in by_sig.values():
                mode = planner_mod.BATCHED if len(ns) > 1 else planner_mod.WHOLE
                for n in ns:
                    decisions[n] = dataclasses.replace(decisions[n],
                                                       decode_mode=mode)

        union_dec = dict(ep_u.decisions)
        mark_batched(union_dec)
        mark_batched(merged_dec)

        # greedy marginal-makespan request permutation: place next the request
        # whose addition grows the composed makespan least (admission ordering
        # by marginal cost over the shared model)
        merged_infos = infos_of(merged_dec)

        def composed_mk(prefix: list[str]) -> float:
            return scheduler.simulate_stream(
                jobs, merged_infos, [idx[n] for n in prefix], ep_u.window)

        remaining = list(reqs)
        greedy_order: list[str] = []
        while remaining:
            best_req, best_mk = None, float("inf")
            for req in remaining:
                mk = composed_mk(greedy_order + per_req_order[req.rid])
                if mk < best_mk - 1e-15:
                    best_req, best_mk = req, mk
            greedy_order += per_req_order[best_req.rid]
            remaining.remove(best_req)

        # SLO hoisting: point requests' columns first (submission order), bulk
        # after -- bounds point tail latency at some makespan cost
        points = [r for r in reqs if r.klass == POINT]
        bulks = [r for r in reqs if r.klass != POINT]
        slo_order = [n for r in points + bulks for n in per_req_order[r.rid]]

        candidates: dict[str, tuple[list[str], dict[str, ColumnDecision]]] = {
            "shared-union": (list(ep_u.order), union_dec),
            "shared-union-clustered": (cluster(ep_u.order, union_dec),
                                       union_dec),
            "fifo-per-query": (naive_order, merged_dec),
            "greedy-marginal": (greedy_order, merged_dec),
            "greedy-clustered": (cluster(greedy_order, merged_dec), merged_dec),
        }
        if points and bulks:
            candidates["slo-hoist"] = (slo_order, merged_dec)

        scored: dict[str, tuple[float, list[float]]] = {}
        for label, (order, dec) in candidates.items():
            mk, fin = scheduler.simulate_stream_finish(
                jobs, infos_of(dec), [idx[n] for n in order], ep_u.window)
            scored[label] = (mk, fin)

        def req_finish(fin: list[float]) -> dict[str, float]:
            return {r.rid: max((fin[idx[n]] for n in req_names[r.rid]),
                               default=0.0) for r in reqs}

        naive_mk, naive_fin = scored["fifo-per-query"]
        if self.policy == "fifo-per-query":
            chosen = "fifo-per-query"
        elif self.policy == "slo" and points:
            # lexicographic: minimize the worst point-class finish, then the
            # aggregate makespan -- the latency-vs-throughput knob
            def key(label):
                mk, fin = scored[label]
                rf = req_finish(fin)
                tail = max((rf[r.rid] for r in points), default=0.0)
                return (tail, mk)
            chosen = min(scored, key=key)
        else:
            chosen = min(scored, key=lambda kv: scored[kv][0])

        order, decisions = candidates[chosen]
        mk, fin = scored[chosen]
        plan = ExecutionPlan(
            order=tuple(order), decisions=dict(decisions),
            policy=f"serve-{self.policy}:{chosen}", window=ep_u.window,
            modeled_makespan_s=mk,
            baselines={lbl: s[0] for lbl, s in scored.items()})
        report = WaveReport(
            rids=tuple(r.rid for r in reqs), policy=self.policy, chosen=chosen,
            order=tuple(order), window=ep_u.window,
            shared_makespan_s=mk, naive_makespan_s=naive_mk,
            candidates={lbl: s[0] for lbl, s in scored.items()},
            modeled_finish_s=req_finish(fin),
            naive_finish_s=req_finish(naive_fin))
        return plan, report
