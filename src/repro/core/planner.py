"""Holistic execution planner (the paper's central thesis as a subsystem).

``plan_execution`` turns per-column ``ColumnProfile``s + a ``CostModel`` + a
scheduling policy into an ``ExecutionPlan``: per column a chunk size (per-column,
not one global knob), a decode mode (whole-column / per-chunk / batched-by-
signature), plus a global issue order and in-flight window -- all chosen by
minimizing the modeled makespan under ``scheduler.simulate_stream``, the same
per-chunk simulator every policy is scored with.

The executor *consumes* plans (``StreamingExecutor.run(plan=...)``): planning is
fully separated from execution, and measured actuals flow back into the
``CostModel`` so the next plan is built from calibrated predictions.

With ``policy="adaptive"`` the planner searches chunk configurations
{per-column auto, all whole-column, global fixed} crossed with the candidate
issue orders, so its simulated makespan is by construction <= min(FIFO,
whole-column Johnson, fixed-chunk Johnson) under the shared model -- those
baselines are also reported in ``ExecutionPlan.baselines`` for benchmarks.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Mapping, Sequence

import numpy as np

from repro.core import costmodel as costmodel_mod, scheduler
from repro.core.costmodel import ColumnProfile, CostModel, LinkTopology
from repro.core.scheduler import ChunkInfo, SchedulingPolicy, get_policy

DEFAULT_CHUNK_BYTES = 1 << 20
# legacy fixed ladder (64 KiB .. 4 MiB), kept only as the fallback when a
# column's geometry-tied ladder is empty (e.g. profiles with no tile info);
# ``CostModel.chunk_ladder`` supplies the real candidates: element chunks
# snapped to kernel tile multiples, group chunks snapped to group-boundary
# prefix sums, both pruned by the calibrated launch-overhead estimate
CHUNK_CANDIDATES = (1 << 16, 1 << 18, 1 << 20, 1 << 22)
MIN_CHUNK_BYTES = 1 << 12

WHOLE, CHUNK, BATCHED = "whole", "chunk", "batched"


@dataclasses.dataclass(frozen=True)
class ColumnDecision:
    """Planned treatment of one column."""

    name: str
    chunk_bytes: int | None       # transfer/decode chunk size for THIS column
    n_chunks: int                 # decode chunks (chunk mode) / transfer pieces
    decode_mode: str              # "whole" | "chunk" | "batched"
    tail_frac: float = 1.0
    est_transfer_s: float = 0.0
    est_decode_s: float = 0.0
    # per-chunk (transfer, decode) fractions for uneven group spans; () = uniform
    weights: tuple[tuple[float, float], ...] = ()
    # decode-fused query execution: operators ride the decode launch and only
    # partial aggregates reach HBM (vs. materialize-then-query)
    fused: bool = False
    selectivity: float = 1.0


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """The explainable artifact the executor consumes: order + per-column
    decisions + in-flight window + the modeled makespan they were chosen by."""

    order: tuple[str, ...]
    decisions: Mapping[str, ColumnDecision]
    policy: str
    window: int
    modeled_makespan_s: float
    baselines: Mapping[str, float] = dataclasses.field(default_factory=dict)

    def explain(self) -> str:
        """Human-readable plan: why each column is treated the way it is."""
        lines = [f"plan: policy={self.policy} window={self.window} "
                 f"modeled_makespan={self.modeled_makespan_s * 1e3:.3f}ms"]
        for ref, mk in sorted(self.baselines.items()):
            lines.append(f"  baseline {ref:14s} {mk * 1e3:.3f}ms")
        for i, name in enumerate(self.order):
            d = self.decisions[name]
            cb = "whole" if d.chunk_bytes is None else f"{d.chunk_bytes >> 10}KiB"
            mode = f"{d.decode_mode}+fused" if d.fused else d.decode_mode
            lines.append(
                f"  {i:2d}. {name:20s} mode={mode:13s} chunk={cb:>8s} "
                f"n_chunks={d.n_chunks:3d} "
                f"pred=({d.est_transfer_s * 1e3:.3f}ms,"
                f"{d.est_decode_s * 1e3:.3f}ms)"
                + (f" sel={d.selectivity:.3f}" if d.fused else ""))
        return "\n".join(lines)


def _chunk_info(d: ColumnDecision, overhead_s: float) -> ChunkInfo:
    return ChunkInfo(n_chunks=max(1, d.n_chunks),
                     chunk_decode=d.decode_mode == CHUNK,
                     tail_frac=d.tail_frac, launch_overhead_s=overhead_s,
                     weights=d.weights)


def _chunk_decision(p: ColumnProfile, t: float, d: float,
                    chunk_bytes: int) -> ColumnDecision | None:
    """CHUNK-mode decision at one candidate size, or None when the column would
    decode whole anyway (covers both element- and group-chunkable graphs; the
    per-chunk weights carry the uneven group-span byte counts to the model)."""
    k, tail = p.decode_chunking(chunk_bytes)
    if k <= 1:
        return None
    return ColumnDecision(p.name, chunk_bytes, k, CHUNK, tail, t, d,
                          weights=p.chunk_weights(chunk_bytes))


def _decide_fixed(p: ColumnProfile, t: float, d: float,
                  chunk_bytes: int | None, chunk_decode: bool) -> ColumnDecision:
    """Legacy-shaped decision: one global chunk size, decode mode from the
    chunk_decode flag (per-chunk only where the graph supports it).  ``t``/``d``
    are the same per-column times the makespan simulator scores with."""
    if chunk_decode and chunk_bytes is not None:
        cand = _chunk_decision(p, t, d, chunk_bytes)
        if cand is not None:
            return cand
    return ColumnDecision(p.name, chunk_bytes,
                          p.n_transfer_chunks(chunk_bytes), WHOLE, 1.0, t, d)


def _decide_auto(p: ColumnProfile, t: float, d: float, overhead: float,
                 fixed_chunk_bytes: int | None,
                 cost_model: CostModel) -> ColumnDecision:
    """Per-column chunk size + decode mode minimizing the column's own modeled
    pipeline time (ties break toward fewer launches).

    Candidates come from ``CostModel.chunk_ladder``: element-chunk sizes
    snapped to kernel tile multiples (core/geometry.py), group-chunk sizes
    snapped to group-boundary prefix sums, both tuned by the calibrated cost
    model; the legacy fixed ladder only backstops profiles without geometry."""
    job = scheduler.Job(p.name, t, d)
    whole_cb = fixed_chunk_bytes or DEFAULT_CHUNK_BYTES
    best = ColumnDecision(p.name, whole_cb, p.n_transfer_chunks(whole_cb),
                          WHOLE, 1.0, t, d)
    best_mk = scheduler.simulate_stream([job], [_chunk_info(best, overhead)])
    cands = set(cost_model.chunk_ladder(p))
    if not cands:
        cands = set(CHUNK_CANDIDATES)
        if p.chunkable and p.per_elem_bytes > 0 and p.n_out > 0:
            tile_bytes = p.per_elem_bytes * p.n_out
            cands |= {max(MIN_CHUNK_BYTES, int(tile_bytes / k))
                      for k in (2, 4, 8)}
    cands.add(whole_cb)
    for cb in sorted(cands, reverse=True):
        cand = _chunk_decision(p, t, d, cb)
        if cand is None:
            continue
        mk = scheduler.simulate_stream([job], [_chunk_info(cand, overhead)])
        if mk < best_mk - 1e-12:
            best, best_mk = cand, mk
    return best


def _mark_batched(decisions: dict[str, ColumnDecision],
                  profiles: Mapping[str, ColumnProfile]) -> None:
    """Whole-mode columns sharing a structural signature decode in one vmap
    launch; mark them so the executor groups them."""
    by_sig: dict[str, list[str]] = {}
    for name, d in decisions.items():
        if d.decode_mode == WHOLE and not d.fused:
            by_sig.setdefault(profiles[name].signature, []).append(name)
    for names in by_sig.values():
        if len(names) > 1:
            for n in names:
                decisions[n] = dataclasses.replace(decisions[n],
                                                   decode_mode=BATCHED)


def _window_for(decisions: Mapping[str, ColumnDecision],
                jobs: Sequence[scheduler.Job] | None = None,
                infos: Sequence[ChunkInfo] | None = None,
                order: Sequence[int] | None = None) -> int:
    """In-flight staging window (transferred-but-undecoded chunks held at once).

    Cost-driven: the smallest window whose simulated makespan matches the
    unbounded pipeline -- the staging buffer stops paying for itself beyond
    that.  Columns with no per-chunk decode get classic double buffering."""
    ks = [d.n_chunks for d in decisions.values() if d.decode_mode == CHUNK]
    if not ks:
        return 2
    if jobs is None:
        return min(8, max(2, max(ks) // 8 + 2))
    base = scheduler.simulate_stream(jobs, infos, order)
    for w in (2, 3, 4, 6, 8):
        if scheduler.simulate_stream(jobs, infos, order,
                                     window=w) <= base * (1 + 1e-9):
            return w
    return 8


def plan_execution(profiles: Mapping[str, ColumnProfile] | Sequence[ColumnProfile],
                   cost_model: CostModel,
                   policy: str | SchedulingPolicy = "adaptive",
                   chunk_bytes: int | None | str = "auto",
                   chunk_decode: bool = False,
                   window: int | None = None,
                   batch_columns: bool = True,
                   fused_columns: Mapping[str, float | None] | None = None
                   ) -> ExecutionPlan:
    """Choose, per column, chunk size / decode mode / issue order / window.

    ``chunk_bytes`` may be an int (global fixed size), None (whole-blob
    transfer) or "auto" (per-column sizing).  ``policy="adaptive"`` searches
    chunk configurations x issue orders and keeps the modeled-makespan minimum;
    fixed policies order the configuration implied by ``chunk_bytes``/
    ``chunk_decode`` directly (the executor's legacy behaviour, now explicit).

    ``fused_columns`` maps columns a pending query could decode-fuse to a
    selectivity estimate (None = the cost model's learned per-signature EWMA).
    Fusion is decided per column AFTER the order search: fuse iff the
    selectivity-scaled fused decode beats decode + the query's re-read of the
    materialized column, then the makespan is re-simulated with the fused
    decode times so the reported number stays honest.  Baselines are computed
    before the adjustment (they model materialize-then-query).
    """
    if not isinstance(profiles, Mapping):
        profiles = {p.name: p for p in profiles}
    names = list(profiles)
    for p in profiles.values():
        if p.name not in cost_model.profiles:
            cost_model.register(p)
    pol = get_policy(policy)
    jobs = cost_model.jobs(names)
    # decisions are priced with the SAME per-column times the simulator scores
    # with (predict() can disagree with jobs() before calibration)
    times = {j.name: (j.transfer_s, j.decompress_s) for j in jobs}
    overheads = [cost_model.launch_overhead_s(n) for n in names]

    fixed_cb = chunk_bytes if isinstance(chunk_bytes, int) else \
        (None if chunk_bytes is None else DEFAULT_CHUNK_BYTES)
    auto = chunk_bytes == "auto"
    executed_kind = "auto" if auto else \
        ("fixed-chunk" if chunk_decode else "whole")

    def decisions_of(kind: str) -> dict[str, ColumnDecision]:
        # "fixed-chunk" honours chunk_bytes=None (whole-blob transfer stays
        # whole-blob even with chunk_decode=True -- _decide_fixed degrades to
        # whole mode)
        if kind == "auto":
            return {n: _decide_auto(profiles[n], *times[n],
                                    cost_model.launch_overhead_s(n), fixed_cb,
                                    cost_model)
                    for n in names}
        return {n: _decide_fixed(profiles[n], *times[n], fixed_cb,
                                 kind == "fixed-chunk") for n in names}

    def infos_of(decisions: dict[str, ColumnDecision]) -> list[ChunkInfo]:
        return [_chunk_info(decisions[n], o) for n, o in zip(names, overheads)]

    if len(names) <= 1:
        # trivial plan: one (or zero) columns has exactly one order and no
        # meaningful baselines -- skip the search (the per-request serve path)
        decisions = decisions_of(executed_kind)
        order = list(range(len(names)))
        makespan_s = scheduler.simulate_stream(jobs, infos_of(decisions), order)
        baselines: dict[str, float] = {}
    else:
        # shared-model baselines (whole-column FIFO/Johnson, fixed-chunk
        # Johnson).  Every baseline is a configuration the search below may
        # also pick, so the adaptive plan's makespan is <= min(baselines) by
        # construction -- in particular the chunk-johnson baseline honours
        # chunk_bytes=None (where it degrades to whole-column decode) rather
        # than substituting a chunk size the caller forbade.
        whole_dec = decisions_of("whole")
        whole_infos = infos_of(whole_dec)
        fixedc_dec = decisions_of("fixed-chunk")
        baselines = {
            "fifo": scheduler.simulate_stream(
                jobs, whole_infos, scheduler.fifo_order(jobs)),
            "johnson": scheduler.simulate_stream(
                jobs, whole_infos, scheduler.johnson_order(jobs)),
            "chunk-johnson": scheduler.ChunkJohnsonPolicy().modeled_makespan(
                jobs, infos_of(fixedc_dec)),
        }
        if pol.name == "adaptive":
            # global search: chunk configurations x candidate orders; includes
            # the baseline configs, so the makespan is <= min(baselines)
            search = [decisions_of("auto")] if auto else []
            search += [whole_dec, fixedc_dec]
            best_dec, best_order, best_mk = None, None, float("inf")
            for dec in search:
                infos = infos_of(dec)
                order = pol.order(jobs, infos)
                mk = scheduler.simulate_stream(jobs, infos, order)
                if mk < best_mk - 1e-15:
                    best_dec, best_order, best_mk = dec, order, mk
            decisions, order, makespan_s = best_dec, best_order, best_mk
        else:
            decisions = decisions_of(executed_kind)
            infos = infos_of(decisions)
            order = pol.order(jobs, infos)
            makespan_s = scheduler.simulate_stream(jobs, infos, order)

    if fused_columns:
        # fused-vs-materialize is a per-column comparison, independent of the
        # issue order, so it composes with (and runs after) the order search
        idx = {n: i for i, n in enumerate(names)}
        jobs = list(jobs)
        for n, sel in fused_columns.items():
            if n not in decisions:
                continue
            s = cost_model.selectivity_for(n) if sel is None else float(sel)
            fd = cost_model.fused_decode_s(n, s)
            t, d = times[n]
            if fd < d + cost_model.query_read_s(n) - 1e-15:
                decisions[n] = dataclasses.replace(
                    decisions[n], fused=True, selectivity=s, est_decode_s=fd)
                jobs[idx[n]] = scheduler.Job(n, t, fd)
        makespan_s = scheduler.simulate_stream(jobs, infos_of(decisions), order)

    if batch_columns:
        _mark_batched(decisions, profiles)
    return ExecutionPlan(
        order=tuple(names[i] for i in order), decisions=dict(decisions),
        policy=pol.name, window=window if window is not None
        else _window_for(decisions, jobs, infos_of(decisions), order),
        modeled_makespan_s=makespan_s, baselines=baselines)


# ------------------------------------------------------------ mesh planning

SHARD_SEP = "::shard"


def shard_name(column: str, index: int) -> str:
    return f"{column}{SHARD_SEP}{index}"


def shard_column_of(item: str) -> str:
    """Parent column of a shard item name (identity for whole columns)."""
    return item.rsplit(SHARD_SEP, 1)[0] if SHARD_SEP in item else item


@dataclasses.dataclass(frozen=True)
class ShardSpec:
    """One contiguous group-span shard of a column, bound for one device."""

    column: str
    index: int
    g_lo: int                     # first group (inclusive, GLOBAL group id)
    g_hi: int                     # past-last group
    out_lo: int                   # output element range [out_lo, out_hi)
    out_hi: int

    @property
    def name(self) -> str:
        return shard_name(self.column, self.index)

    @property
    def n_groups(self) -> int:
        return self.g_hi - self.g_lo

    @property
    def n_out(self) -> int:
        return self.out_hi - self.out_lo


@dataclasses.dataclass(frozen=True)
class MeshExecutionPlan:
    """Topology-aware plan over a device mesh: per-device ``ExecutionPlan``s
    plus the item->device assignment and the group-span shards of any column
    too large for one device.  The modeled makespan comes from
    ``scheduler.simulate_stream_multi`` (N links, shared host staging budget)
    and -- mirroring the single-device planner's dominance contract -- is
    <= the naive round-robin AND single-device baselines by construction:
    both are candidates the assignment search scores.

    Two-tier topologies split LANDING from PLACEMENT: ``assignment`` is
    where each item's bytes stream and decode (minimizing H2D makespan over
    the measured per-link scales), ``placement`` is where its decoded output
    must finally reside (the consumer's desired sharding), and
    ``redistribution`` lists the ``(item, src, dst)`` device->device copy
    legs that bridge the two over the D2D fabric.  Without a fabric (or
    without a placement constraint) the three coincide and the plan is
    exactly the single-tier one."""

    n_devices: int
    device_ids: tuple[int, ...]           # logical link -> physical device index
    plans: tuple[ExecutionPlan, ...]      # one per logical device
    assignment: Mapping[str, int]         # item name -> LANDING logical device
    shards: Mapping[str, tuple[ShardSpec, ...]]   # column -> its shards
    policy: str                           # winning assignment candidate
    window: int
    modeled_makespan_s: float
    baselines: Mapping[str, float] = dataclasses.field(default_factory=dict)
    topology: LinkTopology = dataclasses.field(default_factory=LinkTopology)
    # item name -> FINAL logical device (== assignment unless redistributed)
    placement: Mapping[str, int] = dataclasses.field(default_factory=dict)
    # (item, src logical, dst logical) D2D copy legs, in plan item order
    redistribution: tuple[tuple[str, int, int], ...] = ()
    # the placement constraint the plan was built under (None = unconstrained);
    # elastic re-planning re-applies it to the suffix
    placement_policy: str | None = None

    def final_device(self, item: str) -> int:
        """FINAL logical device of ``item`` (landing device when no
        redistribution moves it)."""
        return int(self.placement.get(item, self.assignment.get(item, 0)))

    @property
    def items(self) -> tuple[str, ...]:
        return tuple(self.assignment)

    def columns(self) -> tuple[str, ...]:
        """Distinct parent columns covered by the plan."""
        seen: list[str] = []
        for item in self.assignment:
            col = shard_column_of(item)
            if col not in seen:
                seen.append(col)
        return tuple(seen)

    def explain(self) -> str:
        lines = [f"mesh plan: devices={self.n_devices} policy={self.policy} "
                 f"window={self.window} "
                 f"modeled_makespan={self.modeled_makespan_s * 1e3:.3f}ms"]
        for ref, mk in sorted(self.baselines.items()):
            lines.append(f"  baseline {ref:14s} {mk * 1e3:.3f}ms")
        for item, src, dst in self.redistribution:
            lines.append(f"  redistribute {item}: device {src} -> {dst} "
                         f"(d2d_scale={self.topology.d2d_scale})")
        for d, plan in enumerate(self.plans):
            dev = self.device_ids[d] if d < len(self.device_ids) else d
            lines.append(f"  device {d} (jax device {dev}): "
                         f"{len(plan.order)} items, "
                         f"local makespan {plan.modeled_makespan_s * 1e3:.3f}ms")
            for item in plan.order:
                dd = plan.decisions[item]
                lines.append(f"    {item:28s} mode={dd.decode_mode:8s} "
                             f"n_chunks={dd.n_chunks:3d} "
                             f"pred=({dd.est_transfer_s * 1e3:.3f}ms,"
                             f"{dd.est_decode_s * 1e3:.3f}ms)")
        return "\n".join(lines)


def _shard_bounds(p: ColumnProfile, n_shards: int) -> list[int]:
    """Contiguous group boundaries splitting ``p`` into ``n_shards`` spans of
    near-equal decoded output, snapped to group-boundary prefix sums."""
    ps = np.asarray(p.group_out_presum, dtype=np.int64)
    total = int(ps[-1])
    bounds = [0]
    for k in range(1, n_shards):
        g = int(np.searchsorted(ps, round(total * k / n_shards), side="left"))
        g = min(max(g, bounds[-1] + 1), p.n_groups - (n_shards - k))
        bounds.append(g)
    bounds.append(p.n_groups)
    return bounds


def _shard_decision(p: ColumnProfile, parent: ColumnDecision, spec: ShardSpec,
                    t_col: float, d_col: float) -> ColumnDecision:
    """Plan one shard the way the executor's range schedule will run it:
    spans of ``groups_per_chunk`` whole groups inside [g_lo, g_hi), the
    whole-resident prologue bytes replicated ahead of each shard's span 0."""
    whole_bytes = max(0.0, p.compressed_nbytes - p.group_bytes * p.n_groups)
    span_bytes = spec.n_groups * p.group_bytes
    t = t_col * (whole_bytes + span_bytes) / max(p.compressed_nbytes, 1)
    d = d_col * spec.n_out / max(p.n_out if p.chunkable else
                                 int(np.asarray(p.group_out_presum)[-1]), 1)
    cb = parent.chunk_bytes
    k, tail, weights = 1, 1.0, ()
    if cb is not None and p.group_bytes > 0:
        G = costmodel_mod.groups_per_chunk(cb, p.group_bytes, p.group_align)
        k = math.ceil(spec.n_groups / G)
        if k > 1:
            ps = np.asarray(p.group_out_presum, dtype=np.float64)
            bnds = list(range(spec.g_lo, spec.g_hi, G)) + [spec.g_hi]
            out_sizes = np.diff(ps[bnds])
            g_sizes = np.diff(bnds).astype(np.float64)
            transfer = g_sizes * p.group_bytes
            transfer[0] += whole_bytes
            t_tot = float(transfer.sum()) or 1.0
            d_tot = float(out_sizes.sum()) or 1.0
            weights = tuple((float(a) / t_tot, float(b) / d_tot)
                            for a, b in zip(transfer, out_sizes))
            body = float(np.mean(out_sizes[:-1]))
            tail = float(min(1.0, max(out_sizes[-1] / max(body, 1e-9), 1e-3)))
    return ColumnDecision(spec.name, cb, k, CHUNK if k > 1 else WHOLE,
                          tail, t, d, weights=weights)


def plan_mesh_execution(
        profiles: Mapping[str, ColumnProfile] | Sequence[ColumnProfile],
        cost_model: CostModel,
        n_devices: int,
        policy: str | SchedulingPolicy = "adaptive",
        chunk_bytes: int | None | str = "auto",
        chunk_decode: bool = True,
        window: int | None = None,
        batch_columns: bool = True,
        shard_threshold_bytes: int | None = None,
        device_ids: Sequence[int] | None = None,
        topology: LinkTopology | None = None,
        placement: str | None = None) -> MeshExecutionPlan:
    """Assign columns (and group-span shards of oversized columns) to the
    devices of a mesh, minimizing the ``simulate_stream_multi`` makespan.

    Per-column chunking / decode-mode decisions come from the single-device
    planner (``plan_execution``) -- the mesh layer only decides WHERE each
    item streams and decodes.  Columns whose compressed bytes exceed
    ``shard_threshold_bytes`` (default: the per-device fair share of the
    total) and whose graphs are group-chunkable split into ``n_devices``
    contiguous group-span shards balanced by decoded output; each shard
    decodes shard-local on its device with GLOBAL group/output offsets, so
    outputs land already laid out for a sharded consumer.

    The assignment search is greedy LPT (longest processing time first onto
    the least-loaded device) followed by local exchange; the naive
    round-robin and single-device assignments are ALWAYS scored too, so the
    chosen makespan is <= both baselines by construction -- the same
    dominance contract ``plan_execution`` gives over FIFO/Johnson.

    ``placement="sharded"`` constrains shard ``i`` of every sharded column
    to FINALLY reside on logical device ``i`` (the canonical layout
    ``_assemble_shards`` emits as a ``NamedSharding``).  When the topology
    carries a D2D fabric (``topo.d2d_scale``), the search then decouples
    landing from placement: free-landing candidates stream each shard over
    the cheapest host link, decode it where it landed, and pay a modeled
    fabric copy (priced by ``LinkTopology.d2d_copy_s`` on the shard's
    DECODED bytes) to reach its required device -- with the pinned
    decode-in-place assignment ("no-redistribution") always among the
    scored candidates, so the chosen makespan never exceeds today's plan.
    Without a fabric the shard items are simply pinned in place and no
    redistribution is emitted.
    """
    if not isinstance(profiles, Mapping):
        profiles = {p.name: p for p in profiles}
    N = max(1, int(n_devices))
    topo = (topology if topology is not None
            else cost_model.topology.resized(N))
    base = plan_execution(profiles, cost_model, policy=policy,
                          chunk_bytes=chunk_bytes, chunk_decode=chunk_decode,
                          window=window, batch_columns=False)
    names = list(base.order)
    overheads = {n: cost_model.launch_overhead_s(n) for n in names}

    # ------------------------------------------------- item sets (whole/shard)
    total_bytes = sum(profiles[n].compressed_nbytes for n in names)
    threshold = (shard_threshold_bytes if shard_threshold_bytes is not None
                 else max(1, total_bytes // N))
    shards: dict[str, tuple[ShardSpec, ...]] = {}
    if N > 1:
        for n in names:
            p = profiles[n]
            if (p.group_chunkable and p.group_out_presum is not None
                    and p.n_groups >= 2 * N
                    and p.compressed_nbytes > threshold):
                ps = np.asarray(p.group_out_presum, dtype=np.int64)
                bounds = _shard_bounds(p, N)
                shards[n] = tuple(
                    ShardSpec(column=n, index=i, g_lo=lo, g_hi=hi,
                              out_lo=int(ps[lo]), out_hi=int(ps[hi]))
                    for i, (lo, hi) in enumerate(zip(bounds, bounds[1:])))

    def build_items(use_shards: bool):
        """-> (item names, jobs, infos, decisions) in base order, shards
        replacing their parent column in place."""
        items, jobs, infos, decs = [], [], [], {}
        for n in names:
            d = base.decisions[n]
            if use_shards and n in shards:
                for spec in shards[n]:
                    sd = _shard_decision(profiles[n], d, spec,
                                         d.est_transfer_s, d.est_decode_s)
                    items.append(spec.name)
                    jobs.append(scheduler.Job(spec.name, sd.est_transfer_s,
                                              sd.est_decode_s))
                    infos.append(_chunk_info(sd, overheads[n]))
                    decs[spec.name] = sd
            else:
                items.append(n)
                jobs.append(scheduler.Job(n, d.est_transfer_s,
                                          d.est_decode_s))
                infos.append(_chunk_info(d, overheads[n]))
                decs[n] = d
        return items, jobs, infos, decs

    whole_set = build_items(False)
    item_sets = {"whole": whole_set}
    if shards:
        item_sets["sharded"] = build_items(True)

    # ------------------------------------------------ placement / redistribution
    # placement="sharded": shard i of every sharded column must FINALLY sit on
    # logical device i.  required maps sharded-set job index -> that device;
    # d2d_equiv prices the shard's DECODED bytes as host-link-equivalent
    # seconds (the unit LinkTopology.d2d_copy_s converts to fabric time).
    place_shards = placement == "sharded" and "sharded" in item_sets
    required: dict[int, int] = {}
    d2d_equiv: dict[int, float] = {}
    if place_shards:
        s_items = item_sets["sharded"][0]
        specs_by_name = {s.name: s for ss in shards.values() for s in ss}
        for i, it in enumerate(s_items):
            spec = specs_by_name.get(it)
            if spec is None:
                continue
            required[i] = spec.index % N
            p = profiles[spec.column]
            total_out = int(np.asarray(p.group_out_presum)[-1]) or 1
            dec_bytes = p.plain_nbytes * spec.n_out / total_out
            d2d_equiv[i] = (base.decisions[spec.column].est_transfer_s
                            * dec_bytes / max(p.compressed_nbytes, 1))

    def copies_for(key: str, assign: list[int]) -> list[tuple[int, float]]:
        """D2D copy jobs an assignment implies: one fabric copy per shard
        whose landing device differs from its required placement."""
        if not (place_shards and key == "sharded" and topo.has_fabric):
            return []
        return [(i, topo.d2d_copy_s(d2d_equiv[i]))
                for i, r in required.items() if assign[i] != r]

    def score(key: str, assign: list[int], serial_issue: bool = False
              ) -> float:
        _, jobs, infos, _ = item_sets[key]
        mk, _ = scheduler.simulate_stream_multi(
            jobs, infos, assign, n_links=N, window=base.window,
            link_scale=topo.link_scale, link_latency_s=topo.link_latency_s,
            host_window=topo.host_window, serial_issue=serial_issue,
            d2d_copies=copies_for(key, assign))
        return mk

    def lpt(key: str, pinned: Mapping[int, int] | None = None) -> list[int]:
        """Greedy longest-processing-time-first onto the least-loaded link
        (loads in link-scaled time so slow links get less work); ``pinned``
        items are pre-placed and only contribute load."""
        _, jobs, _, _ = item_sets[key]
        load = [0.0] * N
        assign = [0] * len(jobs)
        for i, d in (pinned or {}).items():
            assign[i] = d
            load[d] += jobs[i].transfer_s * topo.scale(d) + jobs[i].decompress_s
        order = sorted((i for i in range(len(jobs))
                        if not pinned or i not in pinned),
                       key=lambda i: -(jobs[i].transfer_s
                                       + jobs[i].decompress_s))
        for i in order:
            d = min(range(N), key=lambda x: (load[x], x))
            assign[i] = d
            load[d] += jobs[i].transfer_s * topo.scale(d) + jobs[i].decompress_s
        return assign

    def exchange(key: str, assign: list[int],
                 frozen: Mapping[int, int] | None = None) -> list[int]:
        """Local move/swap refinement: accept any single-item move or pairwise
        swap that lowers the simulated makespan; bounded passes.  ``frozen``
        items never move (pinned decode-in-place shards)."""
        best = list(assign)
        best_mk = score(key, best)
        n_items = len(best)
        fro = frozen or {}
        for _ in range(3):                       # passes; usually converges in 1
            improved = False
            for i in range(n_items):
                if i in fro:
                    continue
                for d in range(N):
                    if d == best[i]:
                        continue
                    cand = list(best)
                    cand[i] = d
                    mk = score(key, cand)
                    if mk < best_mk - 1e-15:
                        best, best_mk, improved = cand, mk, True
            for i in range(n_items):
                if i in fro:
                    continue
                for j in range(i + 1, n_items):
                    if j in fro or best[i] == best[j]:
                        continue
                    cand = list(best)
                    cand[i], cand[j] = cand[j], cand[i]
                    mk = score(key, cand)
                    if mk < best_mk - 1e-15:
                        best, best_mk, improved = cand, mk, True
            if not improved:
                break
        return best

    # --------------------------------------------------- candidate assignments
    candidates: dict[str, tuple[str, list[int]]] = {}   # label -> (set key, assign)
    n_whole = len(whole_set[0])
    candidates["round-robin"] = ("whole", [i % N for i in range(n_whole)])
    candidates["single-device"] = ("whole", [0] * n_whole)
    for key in item_sets:
        if place_shards and key == "sharded":
            # decode-in-place baseline: shards pinned to their required
            # device (exactly today's plan) -- ALWAYS scored, so a
            # redistribute candidate wins only when its modeled makespan,
            # fabric copies included, beats it
            a = lpt(key, pinned=required)
            candidates["no-redistribution"] = (key, a)
            candidates["no-redistribution+exchange"] = (
                key, exchange(key, a, frozen=required))
            if topo.has_fabric:
                f = lpt(key)
                candidates[f"lpt-{key}+redistribute"] = (key, f)
                candidates[f"lpt-{key}+redistribute+exchange"] = (
                    key, exchange(key, f))
        else:
            a = lpt(key)
            candidates[f"lpt-{key}"] = (key, a)
            candidates[f"lpt-{key}+exchange"] = (key, exchange(key, a))

    scored = {label: score(key, a)
              for label, (key, a) in candidates.items()}
    chosen = min(scored, key=lambda lbl: (scored[lbl], lbl))
    set_key, assign = candidates[chosen]
    # price the legacy serialized host loop on the CHOSEN assignment: the
    # overlapped-issue makespan the executor now delivers vs. what the same
    # plan cost when one host thread walked devices sequentially -- recorded
    # as a baseline so fig21's async_overlap rows have a modeled counterpart
    scored["serial-issue"] = score(set_key, assign, serial_issue=True)
    items, jobs, infos, decisions = item_sets[set_key]
    chosen_shards = shards if set_key == "sharded" else {}
    copies = copies_for(set_key, assign)
    redistribution = tuple((items[i], int(assign[i]), int(required[i]))
                           for i, _ in copies)

    # ------------------------------------------------------- per-device plans
    assignment = dict(zip(items, assign))
    plans = []
    for d in range(N):
        d_items = [it for it in items if assignment[it] == d]
        d_dec = {it: decisions[it] for it in d_items}
        if batch_columns:
            # same-signature whole columns CO-LOCATED on one device still
            # batch into a single vmap launch; shard items have no profile
            # and stay unbatched
            d_profiles = {it: profiles[it] for it in d_items if it in profiles}
            batch_view = {it: d_dec[it] for it in d_profiles}
            _mark_batched(batch_view, d_profiles)
            d_dec.update(batch_view)
        d_jobs = [jobs[items.index(it)] for it in d_items]
        d_infos = [infos[items.index(it)] for it in d_items]
        local_mk = scheduler.simulate_stream(
            d_jobs, d_infos, window=base.window) if d_items else 0.0
        plans.append(ExecutionPlan(
            order=tuple(d_items), decisions=d_dec,
            policy=f"mesh:{chosen}", window=base.window,
            modeled_makespan_s=local_mk))
    dev_ids = (tuple(int(x) for x in device_ids) if device_ids is not None
               else tuple(range(N)))
    placement_map = dict(assignment)
    for it, _src, dst in redistribution:
        placement_map[it] = dst
    return MeshExecutionPlan(
        n_devices=N, device_ids=dev_ids, plans=tuple(plans),
        assignment=assignment, shards=chosen_shards, policy=chosen,
        window=base.window, modeled_makespan_s=scored[chosen],
        baselines=dict(scored), topology=topo,
        placement=placement_map, redistribution=redistribution,
        placement_policy=placement)
