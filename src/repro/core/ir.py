"""Decode-graph IR: the explicit program representation between Plan and executor.

``plan.lower_graph`` produces a ``DecodeGraph`` from a compressed blob and
``fusion.fuse_graph`` rewrites it; the compiler consumes graphs instead of ad-hoc
``list[Stage]`` threading.  The graph carries four things a bare stage list cannot:

  * **buffer defs** -- name/shape/dtype of every leaf buffer that moves host->device,
    which is what the streaming executor chunks and schedules;
  * **meta specs** -- the *lifted* data-dependent metadata (bitpack ``bit_width``/
    ``base``, delta ``base``, ...) that enters the program as runtime operands.  A
    ``MetaSpec`` is identified by name/dtype/shape only -- its VALUE is not program
    identity, so two blobs differing only in such a scalar share one jitted program;
  * **output spec** -- final buffer name, length, dtype;
  * **structural signature** -- a digest of the codec tree, per-node *structural*
    metadata (shape-determining counts: group counts, chunk geometry, ...), leaf
    shapes/dtypes, and the lifted-operand specs.  Two blobs with equal signatures
    lower to byte-identical programs, so one jitted executable (and one XLA compile)
    serves all of them -- the launch/geometry reuse CODAG-style decoders rely on.

Structural meta values (which fix shapes and loop bounds) remain baked into programs
and are hashed by value; meta *arrays* that are not lifted are hashed by content.
Lifted meta is hashed by dtype/shape only and extracted per blob by
``plan.meta_operands``.
"""
from __future__ import annotations

import dataclasses
import hashlib
import math
from typing import Any, Iterator, TYPE_CHECKING

import numpy as np

from repro.core import registry
from repro.core.patterns import (CHUNK_ELEMENT, CHUNK_GROUP, CHUNK_NONE,
                                 FullyParallel, Stage)

if TYPE_CHECKING:  # avoid a hard import cycle with repro.core.plan
    from repro.core.plan import Encoded


@dataclasses.dataclass(frozen=True)
class BufferDef:
    """One leaf buffer of a compressed blob (what actually transfers)."""

    name: str                 # hierarchical name, e.g. "root/index.packed"
    shape: tuple[int, ...]
    dtype: str                # numpy dtype string

    @property
    def nbytes(self) -> int:
        n = 1
        for d in self.shape:
            n *= int(d)
        return n * np.dtype(self.dtype).itemsize


@dataclasses.dataclass(frozen=True)
class MetaSpec:
    """One lifted meta operand: program identity is (name, shape, dtype) -- never
    the value.  The value rides along at call time as a tiny device buffer."""

    name: str                 # hierarchical operand name, e.g. "root.@bit_width"
    shape: tuple[int, ...]
    dtype: str


@dataclasses.dataclass
class DecodeGraph:
    """A lowered (possibly fused) decode program: stages over named buffers."""

    stages: list[Stage]
    buffers: tuple[BufferDef, ...]   # leaf inputs, in lowering order
    out: str                         # final output buffer name
    n_out: int
    out_dtype: str
    signature: str                   # structural digest (see module docstring)
    meta_specs: tuple[MetaSpec, ...] = ()   # lifted runtime operands
    nesting: str = ""                # human-readable codec nesting, e.g. "rle[bp]"
    fused: bool = False

    @property
    def compressed_nbytes(self) -> int:
        return sum(b.nbytes for b in self.buffers)

    @property
    def plain_nbytes(self) -> int:
        return int(self.n_out) * np.dtype(self.out_dtype).itemsize

    @property
    def n_kernels(self) -> int:
        return len(self.stages)

    def buffer_names(self) -> list[str]:
        return [b.name for b in self.buffers]

    @property
    def chunkability(self) -> str:
        """Finest output boundary every stage supports: CHUNK_ELEMENT if all stages
        split anywhere, CHUNK_GROUP if the coarsest constraint is group boundaries,
        CHUNK_NONE if any stage needs the whole buffer."""
        levels = {st.chunkability for st in self.stages}
        if CHUNK_NONE in levels or not levels:
            return CHUNK_NONE
        return CHUNK_GROUP if CHUNK_GROUP in levels else CHUNK_ELEMENT


# ------------------------------------------------------------------- signature

def _meta_tokens(meta: dict[str, Any], lifted: dict[str, Any]) -> Iterator[str]:
    for k in sorted(meta):
        if k in lifted:
            # lifted meta is a runtime operand: dtype/shape are identity, the value
            # is not -- this is what lets N blobs differing only in a scalar share
            # one compiled program
            yield f"{k}~operand:{np.dtype(lifted[k]).str}:(1,)"
            continue
        v = meta[k]
        if isinstance(v, np.ndarray):
            # arrays in meta become closure constants -> content is program identity
            digest = hashlib.sha1(np.ascontiguousarray(v).tobytes()).hexdigest()[:12]
            yield f"{k}=nd{v.shape}{v.dtype}:{digest}"
        elif isinstance(v, (bool, int, float, str, np.integer, np.floating)):
            yield f"{k}={v!r}"
        elif isinstance(v, (tuple, list)):
            yield f"{k}={type(v).__name__}{tuple(v)!r}"
        else:
            # unknown meta types cannot be content-hashed; refusing beats a silent
            # signature collision that would share a program with wrong constants
            raise TypeError(
                f"cannot signature meta value {k!r} of type {type(v).__name__}; "
                "use scalars, strings, tuples/lists, or ndarrays")


def _encoded_tokens(enc: "Encoded") -> Iterator[str]:
    yield f"codec={enc.codec};n={enc.n};dtype={np.dtype(enc.dtype).str}"
    lifted = getattr(registry.get(enc.codec), "lifted_meta", {})
    yield from _meta_tokens(enc.meta, lifted)
    for name in sorted(enc.buffers):
        b = enc.buffers[name]
        yield f"buf:{name}:{tuple(b.shape)}:{np.dtype(b.dtype).str}"
    for slot in sorted(enc.children):
        yield f"child:{slot}("
        yield from _encoded_tokens(enc.children[slot])
        yield ")"


def structural_signature(enc: "Encoded") -> str:
    """Digest of codec tree + structural metadata + leaf shapes/dtypes + lifted
    operand specs.

    Equal signatures <=> the lowered stage lists are interchangeable programs, so a
    single jitted executable can decode every blob with the signature (feeding each
    blob's own meta operands at call time).
    """
    h = hashlib.sha1()
    for tok in _encoded_tokens(enc):
        h.update(tok.encode())
        h.update(b"\x00")
    return h.hexdigest()


def describe_encoded(enc: "Encoded") -> str:
    """Nesting string in the paper's Table-2 notation, from the blob side."""
    if not enc.children:
        return enc.codec
    inner = ", ".join(f"{k}={describe_encoded(v)}" for k, v in enc.children.items())
    return f"{enc.codec}[{inner}]"


def graph_from_encoded(enc: "Encoded", stages: list[Stage]) -> DecodeGraph:
    """Assemble a DecodeGraph around an already-lowered stage list."""
    from repro.core import plan as plan_mod

    flat = plan_mod.flat_buffers(enc)
    buffers = tuple(BufferDef(name=k, shape=tuple(v.shape),
                              dtype=np.dtype(v.dtype).str)
                    for k, v in flat.items())
    ops = plan_mod.meta_operands(enc)
    meta_specs = tuple(MetaSpec(name=k, shape=tuple(v.shape),
                                dtype=np.dtype(v.dtype).str)
                       for k, v in ops.items())
    final = stages[-1]
    return DecodeGraph(
        stages=list(stages), buffers=buffers, out=final.out,
        n_out=int(final.n_out), out_dtype=np.dtype(final.out_dtype).str,
        signature=structural_signature(enc), meta_specs=meta_specs,
        nesting=describe_encoded(enc))


# ------------------------------------------------------- element-chunk analysis

@dataclasses.dataclass(frozen=True)
class ChunkLayout:
    """Static slicing recipe for element-chunkable graphs.

    ``align`` is the output-element granularity every chunk boundary must be a
    multiple of (lcm of the tile denominators, so every input slice is integral and
    bitpack word boundaries line up).  ``tiled`` maps each tile leaf buffer to its
    BufSpec; ``whole`` lists buffers every chunk shares (full-resident metadata and
    lifted meta operands)."""

    align: int
    tiled: dict[str, Any]      # leaf name -> BufSpec  (ratio may be operand-driven)
    whole: tuple[str, ...]


def element_chunk_layout(graph: DecodeGraph) -> ChunkLayout | None:
    """Derive the coordinated slicing recipe for per-chunk decode, or None.

    A graph takes the per-chunk decode path iff every stage is Fully-Parallel (the
    CHUNK_ELEMENT declaration), every stage produces the full output length (so a
    chunk of the final output maps to the same element range at every stage), every
    tile input is either a leaf buffer sliced proportionally or an intermediate
    consumed positionally, and all leaves are 1-D.  Group-boundary chunking
    (CHUNK_GROUP) is declared by the IR but not yet exploited by the executor --
    those graphs fall back to one whole-column launch.
    """
    if graph.chunkability != CHUNK_ELEMENT:
        return None
    produced: set[str] = set()
    tiled: dict[str, Any] = {}
    whole: list[str] = []
    buf_shapes = {b.name: b.shape for b in graph.buffers}
    align = 1
    for st in graph.stages:
        if not isinstance(st, FullyParallel) or int(st.n_out) != int(graph.n_out):
            return None
        for name, spec in zip(st.inputs, st.specs):
            if name in produced:
                # intermediate: must be consumed positionally (1:1) to stay aligned
                if spec.kind == "tile" and (spec.num, spec.den) != (1, 1):
                    return None
                continue
            if spec.kind == "full":
                if name not in whole:
                    whole.append(name)
                continue
            if name in tiled:
                if tiled[name] != spec:   # two inconsistent ratios on one leaf
                    return None
                continue
            if len(buf_shapes.get(name, (0, 0))) != 1:
                return None               # only 1-D leaves slice along axis 0
            tiled[name] = spec
            align = math.lcm(align, int(spec.den))
        produced.add(st.out)
    if not tiled:
        return None
    # meta operands always ride whole (they are (1,) scalars)
    for ms in graph.meta_specs:
        if ms.name not in whole and ms.name not in tiled:
            whole.append(ms.name)
    return ChunkLayout(align=align, tiled=dict(tiled), whole=tuple(whole))
