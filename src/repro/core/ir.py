"""Decode-graph IR: the explicit program representation between Plan and executor.

``plan.lower_graph`` produces a ``DecodeGraph`` from a compressed blob and
``fusion.fuse_graph`` rewrites it; the compiler consumes graphs instead of ad-hoc
``list[Stage]`` threading.  The graph carries four things a bare stage list cannot:

  * **buffer defs** -- name/shape/dtype of every leaf buffer that moves host->device,
    which is what the streaming executor chunks and schedules;
  * **meta specs** -- the *lifted* data-dependent metadata (bitpack ``bit_width``/
    ``base``, delta ``base``, ...) that enters the program as runtime operands.  A
    ``MetaSpec`` is identified by name/dtype/shape only -- its VALUE is not program
    identity, so two blobs differing only in such a scalar share one jitted program;
  * **output spec** -- final buffer name, length, dtype;
  * **structural signature** -- a digest of the codec tree, per-node *structural*
    metadata (shape-determining counts: group counts, chunk geometry, ...), leaf
    shapes/dtypes, and the lifted-operand specs.  Two blobs with equal signatures
    lower to byte-identical programs, so one jitted executable (and one XLA compile)
    serves all of them -- the launch/geometry reuse CODAG-style decoders rely on.

Structural meta values (which fix shapes and loop bounds) remain baked into programs
and are hashed by value; meta *arrays* that are not lifted are hashed by content.
Lifted meta is hashed by dtype/shape only and extracted per blob by
``plan.meta_operands``.
"""
from __future__ import annotations

import dataclasses
import hashlib
import math
from typing import Any, Iterator, TYPE_CHECKING

import numpy as np

from repro.core import registry
from repro.core.patterns import (CHUNK_ELEMENT, CHUNK_GROUP, CHUNK_NONE,
                                 FullyParallel, GroupParallel, NonParallel, Reduce,
                                 Stage)

if TYPE_CHECKING:  # avoid a hard import cycle with repro.core.plan
    from repro.core.plan import Encoded


@dataclasses.dataclass(frozen=True)
class BufferDef:
    """One leaf buffer of a compressed blob (what actually transfers)."""

    name: str                 # hierarchical name, e.g. "root/index.packed"
    shape: tuple[int, ...]
    dtype: str                # numpy dtype string

    @property
    def nbytes(self) -> int:
        n = 1
        for d in self.shape:
            n *= int(d)
        return n * np.dtype(self.dtype).itemsize


@dataclasses.dataclass(frozen=True)
class MetaSpec:
    """One lifted meta operand: program identity is (name, shape, dtype) -- never
    the value.  The value rides along at call time as a tiny device buffer."""

    name: str                 # hierarchical operand name, e.g. "root.@bit_width"
    shape: tuple[int, ...]
    dtype: str


@dataclasses.dataclass
class DecodeGraph:
    """A lowered (possibly fused) decode program: stages over named buffers."""

    stages: list[Stage]
    buffers: tuple[BufferDef, ...]   # leaf inputs, in lowering order
    out: str                         # final output buffer name
    n_out: int
    out_dtype: str
    signature: str                   # structural digest (see module docstring)
    meta_specs: tuple[MetaSpec, ...] = ()   # lifted runtime operands
    nesting: str = ""                # human-readable codec nesting, e.g. "rle[bp]"
    fused: bool = False

    @property
    def compressed_nbytes(self) -> int:
        return sum(b.nbytes for b in self.buffers)

    @property
    def plain_nbytes(self) -> int:
        return int(self.n_out) * np.dtype(self.out_dtype).itemsize

    @property
    def n_kernels(self) -> int:
        return len(self.stages)

    def buffer_names(self) -> list[str]:
        return [b.name for b in self.buffers]

    @property
    def chunkability(self) -> str:
        """Finest output boundary the EXECUTOR can split this graph at:
        CHUNK_ELEMENT if every stage splits anywhere, CHUNK_GROUP when the graph
        admits a group-boundary streaming recipe (``group_chunk_layout``: a final
        Group-Parallel / Non-Parallel stage with group-sliceable leaves, everything
        upstream decoded once as a whole-resident prologue), CHUNK_NONE
        otherwise."""
        levels = {st.chunkability for st in self.stages}
        if not levels:
            return CHUNK_NONE
        if levels == {CHUNK_ELEMENT}:
            return CHUNK_ELEMENT
        return CHUNK_GROUP if group_chunk_layout(self) is not None else CHUNK_NONE


# ------------------------------------------------------------------- signature

def _meta_tokens(meta: dict[str, Any], lifted: dict[str, Any],
                 host: tuple[str, ...] = ()) -> Iterator[str]:
    for k in sorted(meta):
        if k in lifted:
            # lifted meta is a runtime operand: dtype/shape are identity, the value
            # is not -- this is what lets N blobs differing only in a scalar share
            # one compiled program
            yield f"{k}~operand:{np.dtype(lifted[k]).str}:(1,)"
            continue
        if k in host:
            # host planning meta (per-group offsets): operand-style identity --
            # dtype/shape only, never the values.  The shape is already pinned by
            # structural meta (n_groups / n_chunks), so two blobs differing only
            # in run structure DATA still share one compiled program; unlike a
            # lifted operand it never transfers (the device derives it itself).
            v = np.asarray(meta[k])
            yield f"{k}~host:{v.dtype.str}:{tuple(v.shape)}"
            continue
        v = meta[k]
        if isinstance(v, np.ndarray):
            # arrays in meta become closure constants -> content is program identity
            digest = hashlib.sha1(np.ascontiguousarray(v).tobytes()).hexdigest()[:12]
            yield f"{k}=nd{v.shape}{v.dtype}:{digest}"
        elif isinstance(v, (bool, int, float, str, np.integer, np.floating)):
            yield f"{k}={v!r}"
        elif isinstance(v, (tuple, list)):
            yield f"{k}={type(v).__name__}{tuple(v)!r}"
        else:
            # unknown meta types cannot be content-hashed; refusing beats a silent
            # signature collision that would share a program with wrong constants
            raise TypeError(
                f"cannot signature meta value {k!r} of type {type(v).__name__}; "
                "use scalars, strings, tuples/lists, or ndarrays")


def _encoded_tokens(enc: "Encoded") -> Iterator[str]:
    yield f"codec={enc.codec};n={enc.n};dtype={np.dtype(enc.dtype).str}"
    codec = registry.get(enc.codec)
    lifted = getattr(codec, "lifted_meta", {})
    host = tuple(getattr(codec, "host_meta", ()))
    yield from _meta_tokens(enc.meta, lifted, host)
    for name in sorted(enc.buffers):
        b = enc.buffers[name]
        yield f"buf:{name}:{tuple(b.shape)}:{np.dtype(b.dtype).str}"
    for slot in sorted(enc.children):
        yield f"child:{slot}("
        yield from _encoded_tokens(enc.children[slot])
        yield ")"


def structural_signature(enc: "Encoded") -> str:
    """Digest of codec tree + structural metadata + leaf shapes/dtypes + lifted
    operand specs.

    Equal signatures <=> the lowered stage lists are interchangeable programs, so a
    single jitted executable can decode every blob with the signature (feeding each
    blob's own meta operands at call time).
    """
    h = hashlib.sha1()
    for tok in _encoded_tokens(enc):
        h.update(tok.encode())
        h.update(b"\x00")
    return h.hexdigest()


def describe_encoded(enc: "Encoded") -> str:
    """Nesting string in the paper's Table-2 notation, from the blob side."""
    if not enc.children:
        return enc.codec
    inner = ", ".join(f"{k}={describe_encoded(v)}" for k, v in enc.children.items())
    return f"{enc.codec}[{inner}]"


def graph_from_encoded(enc: "Encoded", stages: list[Stage]) -> DecodeGraph:
    """Assemble a DecodeGraph around an already-lowered stage list."""
    from repro.core import plan as plan_mod

    flat = plan_mod.flat_buffers(enc)
    buffers = tuple(BufferDef(name=k, shape=tuple(v.shape),
                              dtype=np.dtype(v.dtype).str)
                    for k, v in flat.items())
    ops = plan_mod.meta_operands(enc)
    meta_specs = tuple(MetaSpec(name=k, shape=tuple(v.shape),
                                dtype=np.dtype(v.dtype).str)
                       for k, v in ops.items())
    final = stages[-1]
    return DecodeGraph(
        stages=list(stages), buffers=buffers, out=final.out,
        n_out=int(final.n_out), out_dtype=np.dtype(final.out_dtype).str,
        signature=structural_signature(enc), meta_specs=meta_specs,
        nesting=describe_encoded(enc))


# ------------------------------------------------------- element-chunk analysis

@dataclasses.dataclass(frozen=True)
class ChunkLayout:
    """Static slicing recipe for element-chunkable graphs.

    ``align`` is the output-element granularity every chunk boundary must be a
    multiple of (lcm of the tile denominators, so every input slice is integral and
    bitpack word boundaries line up).  ``tiled`` maps each tile leaf buffer to its
    BufSpec; ``whole`` lists buffers every chunk shares (full-resident metadata and
    lifted meta operands)."""

    align: int
    tiled: dict[str, Any]      # leaf name -> BufSpec  (ratio may be operand-driven)
    whole: tuple[str, ...]


def element_chunk_layout(graph: DecodeGraph) -> ChunkLayout | None:
    """Derive the coordinated slicing recipe for per-chunk decode, or None.

    A graph takes the per-chunk decode path iff every stage is Fully-Parallel (the
    CHUNK_ELEMENT declaration), every stage produces the full output length (so a
    chunk of the final output maps to the same element range at every stage), every
    tile input is either a leaf buffer sliced proportionally or an intermediate
    consumed positionally, and all leaves are 1-D.  Graphs with a Group-Parallel /
    Non-Parallel stage take the group-boundary path instead (``group_chunk_layout``).
    """
    if graph.chunkability != CHUNK_ELEMENT:
        return None
    produced: set[str] = set()
    tiled: dict[str, Any] = {}
    whole: list[str] = []
    buf_shapes = {b.name: b.shape for b in graph.buffers}
    align = 1
    for st in graph.stages:
        if not isinstance(st, FullyParallel) or int(st.n_out) != int(graph.n_out):
            return None
        for name, spec in zip(st.inputs, st.specs):
            if name in produced:
                # intermediate: must be consumed positionally (1:1) to stay aligned
                if spec.kind == "tile" and (spec.num, spec.den) != (1, 1):
                    return None
                continue
            if spec.kind == "full":
                if name not in whole:
                    whole.append(name)
                continue
            if name in tiled:
                if tiled[name] != spec:   # two inconsistent ratios on one leaf
                    return None
                continue
            if len(buf_shapes.get(name, (0, 0))) != 1:
                return None               # only 1-D leaves slice along axis 0
            tiled[name] = spec
            align = math.lcm(align, int(spec.den))
        produced.add(st.out)
    if not tiled:
        return None
    # meta operands always ride whole (they are (1,) scalars)
    for ms in graph.meta_specs:
        if ms.name not in whole and ms.name not in tiled:
            whole.append(ms.name)
    return ChunkLayout(align=align, tiled=dict(tiled), whole=tuple(whole))


# --------------------------------------------------------- query-chunk analysis

@dataclasses.dataclass(frozen=True)
class QueryChunkLayout:
    """Static slicing recipe for fused-query (``Reduce``-terminated) graphs.

    The item axis being chunked is the terminal Reduce's ``n_in`` (rows, or RLE
    runs) -- NOT ``graph.n_out``, which is the tiny accumulator.  ``tiled`` and
    ``whole`` follow ``ChunkLayout`` semantics over that axis; ``resident``
    lists "row"-kind inputs: decoded fallback columns kept whole on device and
    gathered at the global item index by every chunk launch."""

    align: int
    tiled: dict[str, Any]       # leaf name -> BufSpec over the item axis
    whole: tuple[str, ...]
    resident: tuple[str, ...]
    n_rows: int                 # item-axis length partial launches cover


def query_chunk_layout(graph: DecodeGraph) -> QueryChunkLayout | None:
    """Derive the per-chunk partial-aggregate recipe for a fused query graph.

    Eligible iff the final stage is a ``Reduce`` and every earlier stage is
    Fully-Parallel producing the full item axis (``n_out == reduce.n_in``), so
    a chunk of items maps to the same element range at every stage.  Memoized
    like ``group_chunk_layout`` (graphs are immutable after lowering)."""
    cached = graph.__dict__.get("_query_layout", False)
    if cached is not False:
        return cached
    layout = _query_chunk_layout(graph)
    graph.__dict__["_query_layout"] = layout
    return layout


def _query_chunk_layout(graph: DecodeGraph) -> QueryChunkLayout | None:
    stages = graph.stages
    if not stages or not isinstance(stages[-1], Reduce):
        return None
    red = stages[-1]
    n_rows = int(red.n_in)
    if n_rows <= 0:
        return None
    produced: set[str] = set()
    tiled: dict[str, Any] = {}
    whole: list[str] = []
    resident: list[str] = []
    buf_shapes = {b.name: b.shape for b in graph.buffers}
    align = 1
    for st in stages:
        if st is not red and (not isinstance(st, FullyParallel)
                              or int(st.n_out) != n_rows):
            return None
        for name, spec in zip(st.inputs, st.specs):
            if name in produced:
                if spec.kind == "tile" and (spec.num, spec.den) != (1, 1):
                    return None
                continue
            if spec.kind == "row":
                if name not in resident:
                    resident.append(name)
                continue
            if spec.kind == "full":
                if name not in whole:
                    whole.append(name)
                continue
            if name in tiled:
                if tiled[name] != spec:
                    return None
                continue
            if len(buf_shapes.get(name, (0, 0))) != 1:
                return None
            tiled[name] = spec
            align = math.lcm(align, int(spec.den))
        produced.add(st.out)
    if not tiled:
        return None
    for ms in graph.meta_specs:
        if ms.name not in whole and ms.name not in tiled:
            whole.append(ms.name)
    return QueryChunkLayout(align=align, tiled=dict(tiled), whole=tuple(whole),
                            resident=tuple(resident), n_rows=n_rows)


# --------------------------------------------------------- group-chunk analysis

@dataclasses.dataclass(frozen=True)
class GroupChunkLayout:
    """Static recipe for group-boundary chunked streaming decode.

    The graph is split at its LAST group-bearing stage (Group-Parallel or
    Non-Parallel): every stage before it is the **prologue** -- decoded once,
    whole, from whole-resident leaves (presum auxes, nested child decodes) --
    and the group stage (plus any trailing Fully-Parallel stages consumed
    positionally) relaunches per span of whole groups.  ``sliced`` maps each
    leaf buffer consumed per-group (RLE values at ``num/den`` rows per group,
    ANS states at one row per group, ANS stream stripes at one *column* per
    group -- see ``axes``) to its BufSpec; those are the bytes that stream
    chunk-by-chunk while earlier spans decode.  ``resident`` names prologue
    intermediates the span launches gather from at global group indices.

    ``group_presum`` is the host-side per-group output offset table (len
    ``n_groups + 1``, ``group_presum[-1] == n_out``) the encoders emit
    (operand-style identity: dtype/shape, never value); span boundaries snap to
    it.  ``elems_per_group > 0`` marks uniform groups (ANS chunk grids), where
    the table is affine and body spans share one compiled program without
    padding.
    """

    kind: str                     # "gp" | "np"
    stage_index: int              # index of the group stage in graph.stages
    n_groups: int
    elems_per_group: int          # uniform output elems per group (np); 0 = data-dep
    sliced: dict[str, Any]        # leaf -> BufSpec (per-GROUP tiling ratio)
    axes: dict[str, int]          # leaf -> slice axis (ANS stripes slice axis 1)
    whole: tuple[str, ...]        # leaves + meta operands transferred whole
    resident: tuple[str, ...]     # prologue intermediates span launches consume
    align_groups: int             # group-boundary alignment (lcm of sliced dens)
    group_presum: Any = dataclasses.field(default=None, compare=False)
    # host-sourced whole buffers: name -> host array staged with the whole
    # leaves instead of being computed by a prologue (the encoder-emitted
    # presum table, pushed when the on-device presum scan would force the
    # value leaf whole-resident -- see the stringdict note in the builder)
    host_push: dict[str, Any] = dataclasses.field(default_factory=dict,
                                                  compare=False)
    # span-time value graft: GP value input -> producer stage index.  The
    # producer (a gather-capable Fully-Parallel, e.g. bitpack) re-evaluates
    # inside each span over its SLICED primary leaf instead of materializing
    # whole in a prologue -- the fusion rule-2 graft, applied late when the
    # intermediate has a second consumer only the skipped prologue needs
    span_graft: dict[str, int] = dataclasses.field(default_factory=dict)


def _post_stages_ok(graph: DecodeGraph, g_idx: int) -> bool:
    """Trailing stages must be Fully-Parallel over the full output, consuming
    the group stage's output positionally (static tile ratio) and everything
    else whole-resident -- the addressing the span programs can reproduce."""
    produced = {st.out for st in graph.stages[: g_idx + 1]}
    for st in graph.stages[g_idx + 1:]:
        if not isinstance(st, FullyParallel) or int(st.n_out) != int(graph.n_out):
            return False
        for name, spec in zip(st.inputs, st.specs):
            if name in produced:
                if spec.kind != "tile" or spec.num_op:
                    return False
            elif spec.kind != "full":
                return False
        produced.add(st.out)
    return True


def group_chunk_layout(graph: DecodeGraph) -> GroupChunkLayout | None:
    """Derive the group-boundary streaming recipe, or None (whole-column decode).

    Eligibility is deliberately conservative: one group stage (the last
    Group-Parallel / Non-Parallel in the list), trailing stages positional
    Fully-Parallel, host group metadata present, and at least one leaf that is
    actually group-sliceable -- a layout with nothing to stream would only add
    launch overhead, so such graphs report CHUNK_NONE and decode whole.

    Memoized per graph: the analysis allocates an O(n_groups) presum and is
    reached from ``chunkability``, the profile builder, the schedule builder
    and every span-program cache lookup -- once per graph is enough.  Safe
    because graphs are never mutated after lowering/fusion, and
    ``dataclasses.replace`` (how fusion rewrites) does not copy the cache.
    """
    cached = graph.__dict__.get("_group_layout", False)
    if cached is not False:
        return cached
    layout = _group_chunk_layout(graph)
    if layout is None:
        # second pass: allow span-time value grafts (re-evaluate a gather-
        # capable producer inside each span) -- only tried when the plain
        # layout fails, so eligible-today graphs are byte-for-byte unchanged
        layout = _group_chunk_layout(graph, graft=True)
    graph.__dict__["_group_layout"] = layout
    return layout


def _group_chunk_layout(graph: DecodeGraph,
                        graft: bool = False) -> GroupChunkLayout | None:
    stages = graph.stages
    g_idx = -1
    for i, st in enumerate(stages):
        if isinstance(st, (GroupParallel, NonParallel)):
            g_idx = i
    if g_idx < 0 or int(graph.n_out) <= 0:
        return None
    gst = stages[g_idx]
    if not _post_stages_ok(graph, g_idx):
        return None
    leaf_shapes = {b.name: b.shape for b in graph.buffers}
    produced_before = {st.out for st in stages[:g_idx]}

    sliced: dict[str, Any] = {}
    axes: dict[str, int] = {}
    align = 1
    resident: list[str] = []
    span_graft: dict[str, int] = {}

    def _resident(name: str) -> None:
        if name in produced_before and name not in resident:
            resident.append(name)

    if isinstance(gst, GroupParallel):
        n_groups = int(gst.n_groups)
        presum = getattr(gst, "host_group_presum", None)
        if presum is None or n_groups <= 0:
            return None
        presum = np.asarray(presum)
        if presum.shape != (n_groups + 1,) or int(presum[-1]) != int(gst.n_out):
            return None
        if int(gst.n_out) != int(graph.n_out):
            return None          # trailing stages must preserve the length
        _resident(gst.presum)
        if gst.presum not in produced_before and gst.presum not in leaf_shapes:
            return None          # presum neither computed upstream nor a leaf
        meta_names = {ms.name for ms in graph.meta_specs}
        producer = {st.out: i for i, st in enumerate(stages[:g_idx])}

        def _graft_idx(name: str) -> int | None:
            """Producer stage index when ``name`` can be re-evaluated inside
            each span over a sliced leaf: a Fully-Parallel at group
            granularity whose primary input is a 1-D tiled leaf and whose
            remaining inputs are whole-resident metadata.  FP closures are
            gather-capable by contract (the same property fusion rule 2
            relies on), so evaluating one at the span's group indices over an
            exactly-sliced leaf is bitwise the whole-column value."""
            gi = producer.get(name)
            if gi is None:
                return None
            p = stages[gi]
            if not isinstance(p, FullyParallel) or int(p.n_out) != n_groups:
                return None
            if (not p.inputs or p.inputs[0] not in leaf_shapes
                    or len(leaf_shapes[p.inputs[0]]) != 1
                    or p.specs[0].kind != "tile"):
                return None
            if any(sp.kind != "full" for sp in p.specs[1:]):
                return None
            if any(i not in leaf_shapes and i not in meta_names
                   for i in p.inputs[1:]):
                return None
            return gi

        for name, spec in zip(gst.value_inputs, gst.value_specs):
            # operand-driven ratios (bitpack's bit_width) slice too: the
            # schedule builder resolves the operand's value host-side, and
            # lcm'ing the den into the alignment keeps body-span slices one
            # shared shape (den=32 word-aligns every 32-group boundary)
            if (name in leaf_shapes and spec.kind == "tile"
                    and len(leaf_shapes[name]) == 1):
                sliced[name] = spec
                axes[name] = 0
                align = math.lcm(align, int(spec.den))
                continue
            gi = None
            if (graft and spec.kind == "tile" and not spec.num_op
                    and int(spec.num) == 1 and int(spec.den) == 1):
                gi = _graft_idx(name)
            if gi is not None:
                p = stages[gi]
                leaf = p.inputs[0]
                sliced[leaf] = p.specs[0]
                axes[leaf] = 0
                align = math.lcm(align, int(p.specs[0].den))
                span_graft[name] = gi
            else:
                _resident(name)
        for name in gst.extra_inputs:
            _resident(name)
        elems_per_group = 0
    else:                        # NonParallel: groups are the ANS chunks
        n_groups = int(gst.n_chunks)
        cs = int(gst.chunk_size)
        if n_groups <= 0 or cs <= 0:
            return None
        if len(leaf_shapes.get(gst.streams, ())) != 2 \
                or len(leaf_shapes.get(gst.states, ())) != 1:
            return None
        # bytes -> final elements: trailing reassemble widens by its tile num
        itemsize = 1
        for st in stages[g_idx + 1:]:
            for name, spec in zip(st.inputs, st.specs):
                if name == gst.out:
                    itemsize = int(spec.num) // max(1, int(spec.den))
        if itemsize <= 0 or cs % itemsize:
            return None
        from repro.core.patterns import BufSpec
        sliced[gst.streams] = BufSpec("tile")
        axes[gst.streams] = 1    # stripe: one column per group
        sliced[gst.states] = BufSpec("tile")
        axes[gst.states] = 0
        elems_per_group = cs // itemsize
        presum = np.minimum(
            np.arange(n_groups + 1, dtype=np.int64) * elems_per_group,
            int(graph.n_out))
        if int(presum[-1]) != int(graph.n_out):
            return None
    if n_groups <= 1 or not sliced:
        return None
    # trailing-FP full inputs that are prologue intermediates ride resident too
    for st in stages[g_idx + 1:]:
        for name in st.inputs:
            _resident(name)
    # prologue stages may consume anything EXCEPT a sliced leaf (they run before
    # chunk 0, over whole buffers); un-slice on conflict -- UNLESS the prologue
    # exists only to recompute the presum table the encoder already emitted
    # host-side (stringdict: the word-length scan reads the index leaf whole to
    # feed the presum cumsum).  There the host table is pushed with the whole
    # buffers instead, the prologue never runs, and the leaf stays sliced.
    pro_inputs: set[str] = set()
    for st in stages[:g_idx]:
        if isinstance(st, GroupParallel):
            pro_inputs.update((st.presum,) + st.value_inputs + st.extra_inputs)
        elif isinstance(st, NonParallel):
            pro_inputs.update((st.streams, st.states, st.sym_tab, st.freq_tab,
                               st.cum_tab))
        else:                    # FullyParallel / Aux
            pro_inputs.update(getattr(st, "inputs", ()))
    host_push: dict[str, Any] = {}
    conflict = [name for name in sliced if name in pro_inputs]
    if (conflict and isinstance(gst, GroupParallel)
            and resident == [gst.presum]):
        prod = next(st for st in stages[:g_idx] if st.out == gst.presum)
        # cast to the on-device producer's dtype so downstream arithmetic is
        # bitwise identical to the prologue path it replaces
        host_push[gst.presum] = np.asarray(presum).astype(
            np.dtype(prod.out_dtype))
        resident = []
    else:
        for name in conflict:
            del sliced[name]
            axes.pop(name, None)
    if not sliced:
        return None
    # a graft is only sound when its leaf survived conflict resolution and the
    # intermediate is not ALSO needed resident (a trailing stage consumes it)
    for nm, gi in span_graft.items():
        if stages[gi].inputs[0] not in sliced or nm in resident:
            return None
    whole = tuple([b.name for b in graph.buffers if b.name not in sliced]
                  + [ms.name for ms in graph.meta_specs] + list(host_push))
    return GroupChunkLayout(
        kind="gp" if isinstance(gst, GroupParallel) else "np",
        stage_index=g_idx, n_groups=n_groups, elems_per_group=elems_per_group,
        sliced=dict(sliced), axes=dict(axes), whole=whole,
        resident=tuple(resident), align_groups=align,
        group_presum=np.asarray(presum, dtype=np.int64), host_push=host_push,
        span_graft=dict(span_graft))
