"""Decode-graph IR: the explicit program representation between Plan and executor.

``plan.lower_graph`` produces a ``DecodeGraph`` from a compressed blob and
``fusion.fuse_graph`` rewrites it; the compiler consumes graphs instead of ad-hoc
``list[Stage]`` threading.  The graph carries three things a bare stage list cannot:

  * **buffer defs** -- name/shape/dtype of every leaf buffer that moves host->device,
    which is what the streaming executor chunks and schedules;
  * **output spec** -- final buffer name, length, dtype;
  * **structural signature** -- a digest of the codec tree, per-node static metadata,
    and leaf shapes/dtypes.  Two blobs with equal signatures lower to byte-identical
    programs, so one jitted executable (and one XLA compile) serves all of them --
    the launch/geometry reuse CODAG-style decoders rely on.

Meta scalars (bit widths, bases, chunk counts, ...) are closed over by the stage
lowering and baked into the jitted program as constants, so they are part of program
identity and must be hashed; meta arrays are hashed by content for the same reason.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Iterator, TYPE_CHECKING

import numpy as np

from repro.core.patterns import Stage

if TYPE_CHECKING:  # avoid a hard import cycle with repro.core.plan
    from repro.core.plan import Encoded


@dataclasses.dataclass(frozen=True)
class BufferDef:
    """One leaf buffer of a compressed blob (what actually transfers)."""

    name: str                 # hierarchical name, e.g. "root/index.packed"
    shape: tuple[int, ...]
    dtype: str                # numpy dtype string

    @property
    def nbytes(self) -> int:
        n = 1
        for d in self.shape:
            n *= int(d)
        return n * np.dtype(self.dtype).itemsize


@dataclasses.dataclass
class DecodeGraph:
    """A lowered (possibly fused) decode program: stages over named buffers."""

    stages: list[Stage]
    buffers: tuple[BufferDef, ...]   # leaf inputs, in lowering order
    out: str                         # final output buffer name
    n_out: int
    out_dtype: str
    signature: str                   # structural digest (see module docstring)
    nesting: str = ""                # human-readable codec nesting, e.g. "rle[bp]"
    fused: bool = False

    @property
    def compressed_nbytes(self) -> int:
        return sum(b.nbytes for b in self.buffers)

    @property
    def plain_nbytes(self) -> int:
        return int(self.n_out) * np.dtype(self.out_dtype).itemsize

    @property
    def n_kernels(self) -> int:
        return len(self.stages)

    def buffer_names(self) -> list[str]:
        return [b.name for b in self.buffers]


# ------------------------------------------------------------------- signature

def _meta_tokens(meta: dict[str, Any]) -> Iterator[str]:
    for k in sorted(meta):
        v = meta[k]
        if isinstance(v, np.ndarray):
            # arrays in meta become closure constants -> content is program identity
            digest = hashlib.sha1(np.ascontiguousarray(v).tobytes()).hexdigest()[:12]
            yield f"{k}=nd{v.shape}{v.dtype}:{digest}"
        elif isinstance(v, (bool, int, float, str, np.integer, np.floating)):
            yield f"{k}={v!r}"
        elif isinstance(v, (tuple, list)):
            yield f"{k}={type(v).__name__}{tuple(v)!r}"
        else:
            # unknown meta types cannot be content-hashed; refusing beats a silent
            # signature collision that would share a program with wrong constants
            raise TypeError(
                f"cannot signature meta value {k!r} of type {type(v).__name__}; "
                "use scalars, strings, tuples/lists, or ndarrays")


def _encoded_tokens(enc: "Encoded") -> Iterator[str]:
    yield f"codec={enc.codec};n={enc.n};dtype={np.dtype(enc.dtype).str}"
    yield from _meta_tokens(enc.meta)
    for name in sorted(enc.buffers):
        b = enc.buffers[name]
        yield f"buf:{name}:{tuple(b.shape)}:{np.dtype(b.dtype).str}"
    for slot in sorted(enc.children):
        yield f"child:{slot}("
        yield from _encoded_tokens(enc.children[slot])
        yield ")"


def structural_signature(enc: "Encoded") -> str:
    """Digest of codec tree + static metadata + leaf shapes/dtypes.

    Equal signatures <=> the lowered stage lists are interchangeable programs, so a
    single jitted executable can decode every blob with the signature.
    """
    h = hashlib.sha1()
    for tok in _encoded_tokens(enc):
        h.update(tok.encode())
        h.update(b"\x00")
    return h.hexdigest()


def describe_encoded(enc: "Encoded") -> str:
    """Nesting string in the paper's Table-2 notation, from the blob side."""
    if not enc.children:
        return enc.codec
    inner = ", ".join(f"{k}={describe_encoded(v)}" for k, v in enc.children.items())
    return f"{enc.codec}[{inner}]"


def graph_from_encoded(enc: "Encoded", stages: list[Stage]) -> DecodeGraph:
    """Assemble a DecodeGraph around an already-lowered stage list."""
    from repro.core import plan as plan_mod

    flat = plan_mod.flat_buffers(enc)
    buffers = tuple(BufferDef(name=k, shape=tuple(v.shape),
                              dtype=np.dtype(v.dtype).str)
                    for k, v in flat.items())
    final = stages[-1]
    return DecodeGraph(
        stages=list(stages), buffers=buffers, out=final.out,
        n_out=int(final.n_out), out_dtype=np.dtype(final.out_dtype).str,
        signature=structural_signature(enc), nesting=describe_encoded(enc))
