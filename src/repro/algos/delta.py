"""Delta encoding (paper §2.1, Fully-Parallel family + cumsum auxiliary).

Encode: d[i] = arr[i] - arr[i-1] (d[0] = 0, base = arr[0]); deltas are zigzag-mapped to
non-negative ints so a child bit-packing plan applies (the Parquet-style
delta|bit-packing nesting).  Decode: un-zigzag (F.P.) -> prefix sum + base (Aux; the
paper uses PyTorch's cumsum for exactly this role, Fig. 7(a)).
"""
from __future__ import annotations

from typing import Any

import jax.numpy as jnp
import numpy as np

from repro.core.patterns import Aux, BufSpec, Ctx, FullyParallel, primary
from repro.core.registry import register


_MASK32 = np.int64(0xFFFFFFFF)


def zigzag32_np(d: np.ndarray) -> np.ndarray:
    """32-bit zigzag of *wrapped* int32 deltas -> values in [0, 2^32).

    Deltas of int32 data can span 33 bits; working mod 2^32 keeps every delta a
    32-bit word and the mod-2^32 prefix sum still reconstructs exactly."""
    d32 = (d.astype(np.int64) & _MASK32).astype(np.uint32).astype(np.int32) \
        .astype(np.int64)
    return ((d32 << 1) ^ (d32 >> 63)) & _MASK32


def unzigzag32_np(z: np.ndarray) -> np.ndarray:
    z = z.astype(np.uint64)
    return ((z >> np.uint64(1)) ^ (np.uint64(0) - (z & np.uint64(1)))) \
        .astype(np.uint32).astype(np.int64)


class DeltaCodec:
    name = "delta"
    pattern = "fp"
    # the start value is data-dependent but shape-free: a runtime operand
    lifted_meta = {"base": np.int32}

    def encode(self, arr: np.ndarray, **_: Any) -> tuple[dict[str, np.ndarray], dict]:
        flat = np.asarray(arr).reshape(-1).astype(np.int64)
        base = int(flat[0]) if flat.size else 0
        d = np.diff(flat, prepend=flat[:1] if flat.size else np.zeros(1, np.int64))
        return {"deltas": zigzag32_np(d)}, {"base": base}

    def decode_np(self, bufs: dict[str, np.ndarray], meta: dict, n: int,
                  dtype: Any) -> np.ndarray:
        d = unzigzag32_np(np.asarray(bufs["deltas"]))
        vals = (np.cumsum(d) + meta["base"]) & _MASK32
        return vals.astype(np.uint32).astype(np.int32).astype(dtype)

    def stages(self, enc, buf_names: dict[str, str], out_name: str,
               meta_names: dict[str, str] | None = None) -> list:
        base_name = meta_names["base"]
        out_dt = jnp.dtype(enc.dtype) if np.dtype(enc.dtype).itemsize <= 4 else jnp.int32
        mid = f"{out_name}.unzig"

        def unzig(ctx: Ctx, z: jnp.ndarray) -> jnp.ndarray:
            zu = primary(ctx, z).astype(jnp.uint32)
            return ((zu >> 1) ^ (jnp.uint32(0) - (zu & 1))).astype(jnp.int32)

        def prefix(d: jnp.ndarray, base_op: jnp.ndarray) -> jnp.ndarray:
            return jnp.cumsum(d) + base_op[0]

        return [
            FullyParallel(fn=unzig, inputs=(buf_names["deltas"],),
                          specs=(BufSpec("tile"),), out=mid, n_out=enc.n,
                          out_dtype=jnp.int32, elementwise=True, name="unzigzag"),
            Aux(fn=prefix, inputs=(mid, base_name), out=out_name, n_out=enc.n,
                out_dtype=out_dt, name="delta-cumsum"),
        ]


register(DeltaCodec())
