"""Float2Int (paper §2.1, Fully-Parallel family; the ALP/G-ALP idea).

Encode: find the smallest decimal scale 10^d such that round(x * 10^d) reconstructs x
exactly; store the integers (bit-packable child slot) plus a sparse exception list for
values that do not round-trip.  Decode: ints * 10^-d (F.P.), then scatter-patch
exceptions (Aux; rare -> cheap).
"""
from __future__ import annotations

from typing import Any

import jax.numpy as jnp
import numpy as np

from repro.core.patterns import Aux, BufSpec, Ctx, FullyParallel, primary
from repro.core.registry import register

_MAX_DECIMALS = 9


class Float2IntCodec:
    name = "float2int"
    pattern = "fp"

    def encode(self, arr: np.ndarray, decimals: int | None = None,
               **_: Any) -> tuple[dict[str, np.ndarray], dict]:
        flat = np.asarray(arr).reshape(-1).astype(np.float64)
        flat32 = flat.astype(np.float32)

        def attempt(d: int):
            scaled = np.round(flat * 10.0**d)
            ok = np.abs(scaled) < 2**31 - 1
            # exactness is verified with the *decoder's* arithmetic: a float32
            # division by the exactly-representable 10^d.  Division is correctly
            # rounded, so every integer k < 2^24 reconstructs float32(k/10^d)
            # bit-exactly -- near-zero exceptions on true decimal data (G-ALP style).
            recon = scaled.astype(np.float32) / np.float32(10.0 ** d)
            return scaled, ok & (recon == flat32)

        best_d, best_exc = None, None
        cand = range(_MAX_DECIMALS + 1) if decimals is None else [decimals]
        for d in cand:
            _, exact = attempt(d)
            n_exc = int((~exact).sum())
            if best_exc is None or n_exc < best_exc:
                best_d, best_exc = d, n_exc
            if n_exc == 0:
                break
        d = best_d
        scaled, exact = attempt(d)
        exc_idx = np.flatnonzero(~exact).astype(np.int32)
        ints = np.where(exact, scaled, 0).astype(np.int64)
        # the scale ships as a (1,) runtime buffer: XLA rewrites division by a
        # *constant* into multiply-by-reciprocal (1-ulp divergence); division by a
        # runtime value stays a correctly-rounded divide on CPU, GPU and TPU.
        return ({"ints": ints,
                 "exc_idx": exc_idx,
                 "exc_val": flat[exc_idx].astype(np.float32),
                 "scale": np.asarray([10.0 ** d], np.float32)},
                {"decimals": int(d), "n_exc": int(exc_idx.size)})

    def decode_np(self, bufs: dict[str, np.ndarray], meta: dict, n: int,
                  dtype: Any) -> np.ndarray:
        out = (np.asarray(bufs["ints"]).astype(np.float32)
               / np.float32(10.0 ** meta["decimals"]))
        out[np.asarray(bufs["exc_idx"]).astype(np.int64)] = np.asarray(bufs["exc_val"])
        return out.astype(dtype)

    def stages(self, enc, buf_names: dict[str, str], out_name: str,
               meta_names: dict[str, str] | None = None) -> list:
        n_exc = int(enc.meta["n_exc"])
        mid = f"{out_name}.scaled" if n_exc else out_name

        def fn(ctx: Ctx, ints: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
            v = primary(ctx, ints)
            return v.astype(jnp.float32) / scale[0]

        stages: list = [FullyParallel(
            fn=fn, inputs=(buf_names["ints"], buf_names["scale"]),
            specs=(BufSpec("tile"), BufSpec("full")),
            out=mid, n_out=enc.n, out_dtype=jnp.float32,
            elementwise=True, name="f2i-scale")]
        if n_exc:
            def patch(x: jnp.ndarray, idx: jnp.ndarray, val: jnp.ndarray):
                return x.at[idx].set(val)

            stages.append(Aux(
                fn=patch, inputs=(mid, buf_names["exc_idx"], buf_names["exc_val"]),
                out=out_name, n_out=enc.n, out_dtype=jnp.float32, name="f2i-patch"))
        return stages


register(Float2IntCodec())
