"""Bit-packing + Frame-of-Reference (paper §2.1, Fully-Parallel family).

Encode: subtract the column minimum (FOR), pack each value into ``bit_width`` bits,
little-endian within a stream of uint32 words.  ``bit_width`` <= 32.

Decode (Fully-Parallel): out[i] spans at most two words:
    bitpos = i*bw;  w = bitpos >> 5;  off = bitpos & 31
    v = (word[w] >> off | word[w+1] << (32-off)) & mask;  out = v + base
The closure is gather-capable (evaluable at arbitrary i) so fusion can absorb it into
Group-Parallel value gathers -- the paper's Fig. 7(c).
"""
from __future__ import annotations

from typing import Any

import jax.numpy as jnp
import numpy as np

from repro.core.patterns import BufSpec, Ctx, FullyParallel
from repro.core.registry import register


def required_bits(span: int) -> int:
    return max(1, int(span).bit_length()) if span > 0 else 0


def pack_np(values: np.ndarray, bit_width: int) -> np.ndarray:
    """Pack non-negative ints < 2^bit_width into uint32 words (+1 guard word)."""
    n = values.size
    v = values.astype(np.uint64)
    n_words = (n * bit_width + 31) // 32 + 1  # +1 guard for the cross-word read
    packed = np.zeros(n_words, dtype=np.uint64)
    bitpos = np.arange(n, dtype=np.uint64) * np.uint64(bit_width)
    w = (bitpos >> np.uint64(5)).astype(np.int64)
    off = bitpos & np.uint64(31)
    np.bitwise_or.at(packed, w, (v << off) & np.uint64(0xFFFFFFFF))
    np.bitwise_or.at(packed, w + 1, v >> (np.uint64(32) - off))
    return packed.astype(np.uint32)


def unpack_np(packed: np.ndarray, n: int, bit_width: int) -> np.ndarray:
    p = packed.astype(np.uint64)
    bitpos = np.arange(n, dtype=np.uint64) * np.uint64(bit_width)
    w = (bitpos >> np.uint64(5)).astype(np.int64)
    off = bitpos & np.uint64(31)
    both = p[w] | (p[w + 1] << np.uint64(32))
    mask = np.uint64((1 << bit_width) - 1)
    return ((both >> off) & mask).astype(np.int64)


class BitpackCodec:
    name = "bitpack"
    pattern = "fp"
    # bit_width/base are data-dependent scalars: lifted to runtime operands so every
    # same-shaped column shares one compiled program regardless of value range
    lifted_meta = {"bit_width": np.int32, "base": np.int32}

    def encode(self, arr: np.ndarray, bit_width: int | None = None,
               **_: Any) -> tuple[dict[str, np.ndarray], dict]:
        flat = np.asarray(arr).reshape(-1)
        if np.issubdtype(flat.dtype, np.floating):
            raise TypeError("bitpack expects integers (use float2int first)")
        base = int(flat.min()) if flat.size else 0
        shifted = (flat.astype(np.int64) - base)
        bw = bit_width if bit_width is not None else required_bits(int(shifted.max())
                                                                   if flat.size else 0)
        bw = max(1, min(32, bw))
        if shifted.size and int(shifted.max()) >= (1 << bw):
            raise ValueError(f"bit_width {bw} too small for span {int(shifted.max())}")
        return ({"packed": pack_np(shifted, bw)},
                {"bit_width": bw, "base": base})

    def decode_np(self, bufs: dict[str, np.ndarray], meta: dict, n: int,
                  dtype: Any) -> np.ndarray:
        vals = unpack_np(bufs["packed"], n, meta["bit_width"]) + meta["base"]
        return vals.astype(dtype)

    def stages(self, enc, buf_names: dict[str, str], out_name: str,
               meta_names: dict[str, str] | None = None) -> list:
        bw_name = meta_names["bit_width"]
        base_name = meta_names["base"]
        out_dt = jnp.dtype(enc.dtype) if np.dtype(enc.dtype).itemsize <= 4 else jnp.int32

        def fn(ctx: Ctx, packed: jnp.ndarray, bw_op: jnp.ndarray,
               base_op: jnp.ndarray) -> jnp.ndarray:
            bw = bw_op[0]        # traced (1,) operands: value is NOT program identity
            base = base_op[0]    # (already wrapped to int32 by meta_operands)
            i = ctx.out_idx
            start = ctx.starts[0] if ctx.starts and ctx.starts[0] is not None else 0
            # overflow-safe split of bitpos = i*bw (i*bw would wrap int32 for large n):
            # w = (i>>5)*bw + ((i&31)*bw)>>5,  off = ((i&31)*bw) & 31
            frac = (i & 31) * bw
            w = (i >> 5) * bw + (frac >> 5) - start
            off = (frac & 31).astype(jnp.uint32)
            last = packed.shape[0] - 1
            lo = packed[w] >> off
            hi_shift = (jnp.uint32(32) - off) & jnp.uint32(31)
            hi = jnp.where(off == 0, jnp.uint32(0),
                           packed[jnp.minimum(w + 1, last)] << hi_shift)
            # (1 << (bw & 31)) - 1 is 0 at bw=32, where the select takes the full mask
            mask = jnp.where(bw >= 32, jnp.uint32(0xFFFFFFFF),
                             (jnp.uint32(1) << (bw.astype(jnp.uint32)
                                                & jnp.uint32(31))) - jnp.uint32(1))
            v = (lo | hi) & mask
            return (v.astype(jnp.int32) + base).astype(out_dt)

        return [FullyParallel(
            fn=fn, inputs=(buf_names["packed"], bw_name, base_name),
            specs=(BufSpec("tile", den=32, num_op=bw_name),
                   BufSpec("full"), BufSpec("full")),
            out=out_name, n_out=enc.n, out_dtype=out_dt,
            elementwise=False, name="bitpack")]


def compare_stage(enc, packed_name: str, bw_name: str, base_name: str,
                  out_name: str, lo: int | None, hi: int | None) -> FullyParallel:
    """Compressed-domain range predicate: ``lo <= value < hi`` evaluated on the
    packed words *pre-widening* -- the unpacked field ``v`` is compared against
    the rebased bounds ``lo - base`` / ``hi - base`` without ever materializing
    the decoded ``v + base`` column.  ``None`` bounds are open.  The bounds are
    baked into the closure (they are part of the query's identity, which the
    fused graph's signature digests), while ``base`` stays a lifted operand so
    blobs sharing the structure share the program."""

    def fn(ctx: Ctx, packed: jnp.ndarray, bw_op: jnp.ndarray,
           base_op: jnp.ndarray) -> jnp.ndarray:
        bw = bw_op[0]
        i = ctx.out_idx
        start = ctx.starts[0] if ctx.starts and ctx.starts[0] is not None else 0
        frac = (i & 31) * bw
        w = (i >> 5) * bw + (frac >> 5) - start
        off = (frac & 31).astype(jnp.uint32)
        last = packed.shape[0] - 1
        lo_w = packed[w] >> off
        hi_shift = (jnp.uint32(32) - off) & jnp.uint32(31)
        hi_w = jnp.where(off == 0, jnp.uint32(0),
                         packed[jnp.minimum(w + 1, last)] << hi_shift)
        mask = jnp.where(bw >= 32, jnp.uint32(0xFFFFFFFF),
                         (jnp.uint32(1) << (bw.astype(jnp.uint32)
                                            & jnp.uint32(31))) - jnp.uint32(1))
        v = ((lo_w | hi_w) & mask).astype(jnp.int64)
        base = base_op[0].astype(jnp.int64)
        sel = jnp.ones(i.shape, jnp.bool_)
        if lo is not None:
            sel = sel & (v >= jnp.int64(int(lo)) - base)
        if hi is not None:
            sel = sel & (v < jnp.int64(int(hi)) - base)
        return sel

    return FullyParallel(
        fn=fn, inputs=(packed_name, bw_name, base_name),
        specs=(BufSpec("tile", den=32, num_op=bw_name),
               BufSpec("full"), BufSpec("full")),
        out=out_name, n_out=enc.n, out_dtype=jnp.bool_,
        elementwise=False, name=f"bitpack-cmp[{lo},{hi})")


register(BitpackCodec())
