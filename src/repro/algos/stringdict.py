"""String-dictionary (paper §2.1/§5.3.1, Group-Parallel family).

Tokenize the column's byte stream on spaces and periods (the paper's O_COMMENT recipe:
1,878 unique words, indices bit-packable to 12 bits), build a word dictionary, and
store one index per token.  Decoding expands each token to its word's bytes: each
token is a group whose count is the word length; out[i] = dict_chars[dict_offsets[idx]
+ pos].  This avoids LZ77's serial decode entirely -- the paper's stated motivation.

Exactness: every byte of the input is covered by the token grammar
``[^ .]*[ .] | [^ .]+$`` so decode is byte-identical (property-tested).
"""
from __future__ import annotations

import re
from typing import Any

import jax.numpy as jnp
import numpy as np

from repro.core.patterns import Aux, BufSpec, Ctx, FullyParallel, GroupParallel, primary
from repro.core.registry import register

_TOKEN_RE = re.compile(rb"[^ .]*[ .]|[^ .]+$")


class StringDictCodec:
    name = "stringdict"
    pattern = "gp"
    # per-token output byte offsets, host planning data (see RleCodec.host_meta)
    host_meta = ("group_presum",)

    def encode(self, arr: np.ndarray, **_: Any) -> tuple[dict[str, np.ndarray], dict]:
        raw = np.ascontiguousarray(np.asarray(arr)).view(np.uint8).reshape(-1)
        data = raw.tobytes()
        tokens = _TOKEN_RE.findall(data) if data else []
        vocab: dict[bytes, int] = {}
        index = np.empty(len(tokens), dtype=np.int32)
        for t, tok in enumerate(tokens):
            index[t] = vocab.setdefault(tok, len(vocab))
        words = list(vocab.keys())
        dict_chars = np.frombuffer(b"".join(words), dtype=np.uint8).copy()
        lengths = np.fromiter((len(w) for w in words), dtype=np.int32,
                              count=len(words))
        dict_offsets = np.concatenate([[0], np.cumsum(lengths)]).astype(np.int32)
        presum = np.concatenate(
            [[0], np.cumsum(lengths[index], dtype=np.int64)]).astype(np.int64)
        return ({"index": index, "dict_chars": dict_chars,
                 "dict_offsets": dict_offsets},
                {"n_tokens": len(tokens), "n_words": len(words),
                 "n_bytes": raw.size, "itemsize": int(np.dtype(arr.dtype).itemsize),
                 "group_presum": presum})

    def decode_np(self, bufs: dict[str, np.ndarray], meta: dict, n: int,
                  dtype: Any) -> np.ndarray:
        index = np.asarray(bufs["index"]).astype(np.int64)
        chars = np.asarray(bufs["dict_chars"])
        offs = np.asarray(bufs["dict_offsets"]).astype(np.int64)
        lengths = np.diff(offs)
        counts = lengths[index]
        g = np.repeat(np.arange(index.size), counts)
        presum = np.concatenate([[0], np.cumsum(counts)])
        pos = np.arange(g.size) - presum[g]
        raw = chars[offs[index[g]] + pos].astype(np.uint8)
        return raw[: meta["n_bytes"]].view(np.dtype(dtype))[:n].copy()

    def stages(self, enc, buf_names: dict[str, str], out_name: str,
               meta_names: dict[str, str] | None = None) -> list:
        meta = enc.meta
        n_tokens = int(meta["n_tokens"])
        n_bytes = int(meta["n_bytes"])
        counts_name = f"{out_name}.counts"
        presum_name = f"{out_name}.presum"

        def counts_fn(ctx: Ctx, index: jnp.ndarray, offs: jnp.ndarray) -> jnp.ndarray:
            idx = primary(ctx, index).astype(jnp.int32)
            return offs[idx + 1] - offs[idx]

        def presum(counts: jnp.ndarray) -> jnp.ndarray:
            z = jnp.zeros((1,), jnp.int32)
            return jnp.concatenate([z, jnp.cumsum(counts.astype(jnp.int32))])

        def value_fn(ctx: Ctx, g: jnp.ndarray, index: jnp.ndarray) -> jnp.ndarray:
            return primary(Ctx(out_idx=g, starts=ctx.starts), index)

        def map_fn(ctx: Ctx, gval, pos, g, chars, offs):
            return chars[offs[gval.astype(jnp.int32)] + pos]

        gp = GroupParallel(
            presum=presum_name, value_inputs=(buf_names["index"],),
            value_specs=(BufSpec("tile"),), value_fn=value_fn, map_fn=map_fn,
            out=out_name, n_out=n_bytes, out_dtype=jnp.uint8, n_groups=n_tokens,
            extra_inputs=(buf_names["dict_chars"], buf_names["dict_offsets"]),
            host_group_presum=enc.meta.get("group_presum"),
            name="stringdict-expand")
        gp._identity_values = True  # type: ignore[attr-defined]
        return [
            FullyParallel(fn=counts_fn,
                          inputs=(buf_names["index"], buf_names["dict_offsets"]),
                          specs=(BufSpec("tile"), BufSpec("full")),
                          out=counts_name, n_out=n_tokens, out_dtype=jnp.int32,
                          elementwise=True, name="word-lengths"),
            Aux(fn=presum, inputs=(counts_name,), out=presum_name,
                n_out=n_tokens + 1, out_dtype=jnp.int32, name="sd-presum"),
            gp,
        ]


register(StringDictCodec())
