"""DeltaStride (paper §5.3: an RLE variant for monotone sequences; Group-Parallel).

Encode: maximal runs of constant stride -> (start, stride, count) triples.  A sorted
primary-key column becomes a handful of triples.  Decode: out[i] = start[g] +
stride[g] * pos, a Group-Parallel expansion with an affine map function.
"""
from __future__ import annotations

from typing import Any

import jax.numpy as jnp
import numpy as np

from repro.core.patterns import Aux, BufSpec, Ctx, GroupParallel, primary
from repro.core.registry import register


def deltastride_encode_np(flat: np.ndarray):
    """Greedy maximal constant-stride runs, vectorized.

    Let d = diff(flat) and rb = [0, c_1, ..., c_k, len(d)] the boundaries of maximal
    equal-value runs of d.  The first diff-run claims elements [0, rb[1]]; every later
    diff-run [rb[k], rb[k+1]) claims elements [rb[k]+1, rb[k+1]] (its boundary element
    already belongs to the previous run).  Counts therefore telescope to n exactly.
    """
    n = flat.size
    flat64 = flat.astype(np.int64)
    if n == 0:
        z = np.zeros(0, np.int64)
        return z, z, z
    if n == 1:
        return flat64[:1], np.zeros(1, np.int64), np.ones(1, np.int64)
    d = np.diff(flat64)
    change = np.flatnonzero(np.diff(d) != 0) + 1
    rb = np.concatenate([[0], change, [d.size]])  # diff-run boundaries, len k+2
    k = rb.size - 1                               # number of diff-runs
    counts = np.empty(k, np.int64)
    counts[0] = rb[1] + 1
    counts[1:] = np.diff(rb)[1:]
    first_elem = np.empty(k, np.int64)
    first_elem[0] = 0
    first_elem[1:] = rb[1:-1] + 1
    starts = flat64[first_elem]
    strides = d[rb[:-1]]
    return starts, strides, counts


class DeltaStrideCodec:
    name = "deltastride"
    pattern = "gp"
    # per-group output offsets, host planning data (see RleCodec.host_meta)
    host_meta = ("group_presum",)

    def encode(self, arr: np.ndarray, **_: Any) -> tuple[dict[str, np.ndarray], dict]:
        flat = np.asarray(arr).reshape(-1)
        starts, strides, counts = deltastride_encode_np(flat)
        presum = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
        return ({"starts": starts.astype(np.int32),
                 "strides": strides.astype(np.int32),
                 "counts": counts.astype(np.int32)},
                {"n_groups": int(counts.size), "group_presum": presum})

    def decode_np(self, bufs: dict[str, np.ndarray], meta: dict, n: int,
                  dtype: Any) -> np.ndarray:
        starts = np.asarray(bufs["starts"]).astype(np.int64)
        strides = np.asarray(bufs["strides"]).astype(np.int64)
        counts = np.asarray(bufs["counts"]).astype(np.int64)
        g = np.repeat(np.arange(counts.size), counts)
        presum = np.concatenate([[0], np.cumsum(counts)])
        pos = np.arange(g.size) - presum[g]
        return (starts[g] + strides[g] * pos)[:n].astype(dtype)

    def stages(self, enc, buf_names: dict[str, str], out_name: str,
               meta_names: dict[str, str] | None = None) -> list:
        out_dt = jnp.dtype(enc.dtype) if np.dtype(enc.dtype).itemsize <= 4 else jnp.int32
        presum_name = f"{out_name}.presum"

        def presum(counts: jnp.ndarray) -> jnp.ndarray:
            z = jnp.zeros((1,), jnp.int32)
            return jnp.concatenate([z, jnp.cumsum(counts.astype(jnp.int32))])

        def value_fn(ctx: Ctx, g, starts, strides):
            c = Ctx(out_idx=g, starts=ctx.starts[:1])
            c2 = Ctx(out_idx=g, starts=ctx.starts[1:2])
            return primary(c, starts), primary(c2, strides)

        def map_fn(ctx: Ctx, gval, pos, g):
            start, stride = gval
            return start.astype(jnp.int32) + stride.astype(jnp.int32) * pos

        gp = GroupParallel(
            presum=presum_name,
            value_inputs=(buf_names["starts"], buf_names["strides"]),
            value_specs=(BufSpec("tile"), BufSpec("tile")),
            value_fn=value_fn, map_fn=map_fn,
            out=out_name, n_out=enc.n, out_dtype=out_dt,
            n_groups=int(enc.meta["n_groups"]),
            host_group_presum=enc.meta.get("group_presum"),
            name="deltastride-expand")
        gp._identity_values = False  # type: ignore[attr-defined]
        return [
            Aux(fn=presum, inputs=(buf_names["counts"],), out=presum_name,
                n_out=int(enc.meta["n_groups"]) + 1, out_dtype=jnp.int32,
                name="ds-presum"),
            gp,
        ]


register(DeltaStrideCodec())
