"""Run-Length Encoding (paper §2.1/§3.1, the Group-Parallel exemplar).

Encode: maximal runs -> (values, counts).  Decode: presum = exclusive-prefix-sum of
counts (the one-time data scan), then the balanced Group-Parallel expansion replicates
values[g] across out[presum[g] : presum[g+1]].
"""
from __future__ import annotations

from typing import Any

import jax.numpy as jnp
import numpy as np

from repro.core.patterns import Aux, BufSpec, Ctx, GroupParallel, primary
from repro.core.registry import register


def rle_encode_np(flat: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    if flat.size == 0:
        return flat[:0], np.zeros(0, np.int64)
    change = np.flatnonzero(np.diff(flat) != 0) + 1
    starts = np.concatenate([[0], change])
    counts = np.diff(np.concatenate([starts, [flat.size]]))
    return flat[starts], counts.astype(np.int64)


class RleCodec:
    name = "rle"
    pattern = "gp"
    # host-side planning metadata: per-group output offsets (and thus, through the
    # 1-row-per-group leaf layout, per-group compressed-byte offsets).  Identified
    # like a lifted operand -- by dtype/shape, never by value -- so blobs differing
    # only in run structure still share one compiled program (see ir._meta_tokens).
    host_meta = ("group_presum",)

    def encode(self, arr: np.ndarray, **_: Any) -> tuple[dict[str, np.ndarray], dict]:
        flat = np.asarray(arr).reshape(-1)
        values, counts = rle_encode_np(flat)
        presum = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
        return ({"values": values, "counts": counts.astype(np.int32)},
                {"n_groups": int(values.size), "group_presum": presum})

    def decode_np(self, bufs: dict[str, np.ndarray], meta: dict, n: int,
                  dtype: Any) -> np.ndarray:
        return np.repeat(np.asarray(bufs["values"]),
                         np.asarray(bufs["counts"]).astype(np.int64))[:n].astype(dtype)

    def stages(self, enc, buf_names: dict[str, str], out_name: str,
               meta_names: dict[str, str] | None = None) -> list:
        out_dt = jnp.dtype(enc.dtype) if np.dtype(enc.dtype).itemsize <= 4 else jnp.int32
        presum_name = f"{out_name}.presum"

        def presum(counts: jnp.ndarray) -> jnp.ndarray:
            z = jnp.zeros((1,), jnp.int32)
            return jnp.concatenate([z, jnp.cumsum(counts.astype(jnp.int32))])

        def value_fn(ctx: Ctx, g: jnp.ndarray, values: jnp.ndarray) -> jnp.ndarray:
            return primary(Ctx(out_idx=g, starts=ctx.starts), values)

        def map_fn(ctx: Ctx, gval, pos, g):
            return gval

        gp = GroupParallel(
            presum=presum_name, value_inputs=(buf_names["values"],),
            value_specs=(BufSpec("tile"),), value_fn=value_fn, map_fn=map_fn,
            out=out_name, n_out=enc.n, out_dtype=out_dt,
            n_groups=int(enc.meta["n_groups"]),
            host_group_presum=enc.meta.get("group_presum"), name="rle-expand")
        gp._identity_values = True  # type: ignore[attr-defined]
        return [
            Aux(fn=presum, inputs=(buf_names["counts"],), out=presum_name,
                n_out=int(enc.meta["n_groups"]) + 1, out_dtype=jnp.int32,
                name="rle-presum"),
            gp,
        ]


def run_reduce_graph(enc, pred_fn, proj_fns, digest: str, prefix: str = "root"):
    """Per-run fused aggregation for an RLE column (never per-row).

    A predicate over an RLE column is constant within a run, so a predicated
    sum collapses to run-length-weighted arithmetic over the RUN axis:

        partial[l] = sum_g counts_g * pred(values_g) * proj_l(values_g)

    The runs' values/counts children decode at run granularity (n_groups
    elements) and feed a terminal ``Reduce`` with ``n_in = n_groups`` -- the
    expansion to ``enc.n`` rows never happens, and chunked execution streams
    RUN spans.  Returns a fused, Reduce-terminated ``DecodeGraph`` whose final
    lane is the run-length-weighted selected-row count (selectivity feedback).
    ``digest`` distinguishes queries on structurally identical blobs."""
    import dataclasses

    from repro.core import fusion, ir as ir_mod, plan as plan_mod
    from repro.core.patterns import Reduce, arg_at

    n_groups = int(enc.meta["n_groups"])
    stages: list = []
    names: dict[str, str] = {}
    for slot in ("values", "counts"):
        if slot in enc.children:
            out = f"{prefix}/{slot}.runs"
            stages += plan_mod.lower(enc.children[slot],
                                     prefix=f"{prefix}/{slot}", out_name=out)
            names[slot] = out
        elif slot in enc.buffers:
            names[slot] = f"{prefix}.{slot}"
        else:
            raise ValueError(f"rle blob has no {slot!r} child or buffer")
    # children lowered on their own Encoded have n == n_groups, so every stage
    # works the RUN axis; guard against anything expanding to the row axis
    for st in stages:
        if enc.n != n_groups and getattr(st, "n_out", 0) == enc.n:
            raise ValueError(f"per-run path leaked a per-row stage: {st.name}")

    def fn(ctx: Ctx, vals: jnp.ndarray, cnts: jnp.ndarray) -> jnp.ndarray:
        v = arg_at(ctx, 0, vals)
        w = pred_fn(v).astype(jnp.float32) * arg_at(ctx, 1, cnts).astype(jnp.float32)
        lanes = [jnp.sum(p(v).astype(jnp.float32) * w) for p in proj_fns]
        return jnp.stack(lanes + [jnp.sum(w)])

    red = Reduce(fn=fn, inputs=(names["values"], names["counts"]),
                 specs=(BufSpec("tile"), BufSpec("tile")),
                 n_in=n_groups, out=f"{prefix}.agg", n_out=len(proj_fns) + 1,
                 out_dtype=jnp.float32, name="rle-run-reduce")
    graph = ir_mod.graph_from_encoded(enc, stages + [red])
    graph = dataclasses.replace(
        graph, signature=f"{graph.signature}+runq:{digest}")
    return fusion.fuse_graph(graph)


register(RleCodec())
