"""rANS entropy coding (paper §2.1/§3.1, the Non-Parallel exemplar).

The paper recovers parallelism from inherently-serial entropy decoding by chunking the
stream and decoding chunks in SIMT lockstep (Fig. 5(c)/6(c)/11).  The TPU analogue:
every VPU *lane* owns a chunk; all lanes execute the identical decode step under a
single program counter (lax.scan), which is the paper's lockstep ideal enforced by
hardware.  Compressed words are stored *chunk-transposed* ("striped"): word t of every
chunk is one contiguous row, so each lockstep step reads one (n_chunks,)-row -- the
paper's "consistency of I/O and cache accesses across chunks".

Construction (rans_word, 32-bit state, 16-bit renorm, 12-bit probability scale):
  L = 2^16, M = 2^12.  Encode (symbols in reverse order so decode is forward):
     if x >= freq[s] << 20: emit low 16 bits, x >>= 16        (at most once -- proof in
     x  = (x // freq[s]) << 12 | (x % freq[s]) + cum[s]        tests/test_ans.py)
  Decode:
     slot = x & 4095; s = sym[slot]
     x = freq[s] * (x >> 12) + slot - cum[s]
     if x < L: x = x << 16 | next_word                          (exactly <= 1 word)
The <=1-word renorm bound is what makes the lockstep decode branch-free (a select),
mirroring the paper's divergence-free N.P. schedule.

Chunk padding: chunks are padded to the per-blob maximum word count so the stripe is
rectangular; the resulting ratio/throughput trade-off against chunk size is exactly the
paper's Fig. 15 experiment.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.patterns import BufSpec, Ctx, FullyParallel, NonParallel, primary
from repro.core.registry import register

L = 1 << 16          # renormalization lower bound
SCALE_BITS = 12
M = 1 << SCALE_BITS  # probability denominator


def normalize_freqs(counts: np.ndarray) -> np.ndarray:
    """Scale 256-bin counts to sum to M with every present symbol >= 1."""
    counts = counts.astype(np.float64)
    total = counts.sum()
    if total == 0:
        freqs = np.zeros(256, np.int64)
        freqs[0] = M
        return freqs
    freqs = np.floor(counts / total * M).astype(np.int64)
    freqs[(counts > 0) & (freqs == 0)] = 1
    # repair the sum by adjusting the largest bin (always large enough)
    diff = M - freqs.sum()
    freqs[np.argmax(freqs)] += diff
    if freqs.max() <= 0:  # degenerate guard
        freqs[:] = 0
        freqs[np.argmax(counts)] = M
    assert freqs.sum() == M and freqs.min() >= 0
    return freqs


def encode_chunks_np(syms: np.ndarray, freq: np.ndarray, cum: np.ndarray,
                     return_wcount: bool = False):
    """Vectorized (across chunks) rANS encode.

    syms: (n_chunks, chunk_size) uint8.  Returns (streams, states):
    streams (max_words, n_chunks) uint16 in *decoder consumption order*, states
    (n_chunks,) uint32 final encoder states (= decoder initial states).  With
    ``return_wcount`` also returns the actual per-chunk word counts (the stripe
    pads every chunk to the maximum; wcount is the pre-padding truth).
    """
    n_chunks, cs = syms.shape
    x = np.full(n_chunks, L, dtype=np.uint64)
    emitted = np.zeros((cs + 1, n_chunks), dtype=np.uint16)  # emission order
    wcount = np.zeros(n_chunks, dtype=np.int64)
    freq64 = freq.astype(np.uint64)
    cum64 = cum.astype(np.uint64)
    lanes = np.arange(n_chunks)
    for t in range(cs - 1, -1, -1):
        s = syms[:, t]
        f = freq64[s]
        need = x >= (f << np.uint64(20))
        idx = lanes[need]
        emitted[wcount[idx], idx] = (x[idx] & np.uint64(0xFFFF)).astype(np.uint16)
        wcount[idx] += 1
        x[idx] >>= np.uint64(16)
        x = ((x // f) << np.uint64(SCALE_BITS)) | (x % f)
        x += cum64[s]
    max_words = int(wcount.max()) if n_chunks else 0
    max_words = max(max_words, 1)
    # decoder consumes in reverse emission order -> flip each chunk's prefix
    take = wcount[None, :] - 1 - np.arange(max_words)[:, None]
    streams = np.where(take >= 0,
                       emitted[np.clip(take, 0, cs), lanes[None, :]],
                       np.uint16(0)).astype(np.uint16)
    if return_wcount:
        return streams, x.astype(np.uint32), wcount
    return streams, x.astype(np.uint32)


def decode_chunks_np(streams: np.ndarray, states: np.ndarray, sym: np.ndarray,
                     freq: np.ndarray, cum: np.ndarray, cs: int) -> np.ndarray:
    """Numpy oracle mirroring the lockstep decode."""
    n_chunks = states.shape[0]
    x = states.astype(np.uint64)
    cur = np.zeros(n_chunks, dtype=np.int64)
    lanes = np.arange(n_chunks)
    out = np.empty((n_chunks, cs), dtype=np.uint8)
    cap = streams.shape[0] - 1
    for t in range(cs):
        slot = (x & np.uint64(M - 1)).astype(np.int64)
        s = sym[slot]
        out[:, t] = s
        x = freq[s].astype(np.uint64) * (x >> np.uint64(SCALE_BITS)) \
            + slot.astype(np.uint64) - cum[s].astype(np.uint64)
        need = x < L
        w = streams[np.clip(cur, 0, cap), lanes].astype(np.uint64)
        x = np.where(need, (x << np.uint64(16)) | w, x)
        cur += need
    return out


def decode_chunks_jnp(streams: jnp.ndarray, states: jnp.ndarray, sym: jnp.ndarray,
                      freq: jnp.ndarray, cum: jnp.ndarray, cs: int) -> jnp.ndarray:
    """Reference jnp lockstep decode: lax.scan over the serial dim, vector over
    chunks.  Returns (n_chunks, cs) uint8."""
    n_chunks = states.shape[0]
    lanes = jnp.arange(n_chunks)
    cap = streams.shape[0] - 1
    sym32 = sym.astype(jnp.int32)
    freq32 = freq.astype(jnp.uint32)
    cum32 = cum.astype(jnp.uint32)

    def step(carry, _):
        x, cur = carry
        slot = (x & jnp.uint32(M - 1)).astype(jnp.int32)
        s = sym32[slot]
        x = freq32[s] * (x >> SCALE_BITS) + slot.astype(jnp.uint32) - cum32[s]
        need = x < jnp.uint32(L)
        w = streams[jnp.clip(cur, 0, cap), lanes].astype(jnp.uint32)
        x = jnp.where(need, (x << 16) | w, x)
        cur = cur + need.astype(jnp.int32)
        return (x, cur), s.astype(jnp.uint8)

    init = (states.astype(jnp.uint32), jnp.zeros(n_chunks, jnp.int32))
    _, syms = jax.lax.scan(step, init, None, length=cs)
    return syms.T  # (n_chunks, cs)


class AnsCodec:
    name = "ans"
    pattern = "np"
    # host-side planning metadata: actual per-chunk compressed word counts (the
    # per-group compressed-byte offsets are cumsum(group_words) * 2).  Identified
    # by dtype/shape only, never by value, and never transferred.  Not yet read
    # by the planner -- it prices the max_words-padded stripe, which is what
    # actually transfers today; the counts exist for the unpadded-stripe layout
    # (ROADMAP), where real per-group offsets replace the padding.
    host_meta = ("group_words",)

    def encode(self, arr: np.ndarray, chunk_size: int = 4096,
               **_: Any) -> tuple[dict[str, np.ndarray], dict]:
        raw = np.ascontiguousarray(np.asarray(arr)).view(np.uint8).reshape(-1)
        n_bytes = raw.size
        cs = int(chunk_size)
        n_chunks = max(1, -(-n_bytes // cs))
        padded = np.zeros(n_chunks * cs, dtype=np.uint8)
        padded[:n_bytes] = raw
        counts = np.bincount(padded, minlength=256)
        freq = normalize_freqs(counts)
        cum = np.concatenate([[0], np.cumsum(freq)[:-1]])
        sym_tab = np.repeat(np.arange(256, dtype=np.uint8), freq)
        streams, states, wcount = encode_chunks_np(
            padded.reshape(n_chunks, cs), freq, cum, return_wcount=True)
        return ({"streams": streams, "states": states,
                 "sym_tab": sym_tab.astype(np.uint8),
                 "freq_tab": freq.astype(np.uint16),
                 "cum_tab": cum.astype(np.uint16)},
                {"chunk_size": cs, "n_chunks": n_chunks, "n_bytes": n_bytes,
                 "itemsize": int(np.dtype(arr.dtype).itemsize),
                 "group_words": wcount.astype(np.int64)})

    def decode_np(self, bufs: dict[str, np.ndarray], meta: dict, n: int,
                  dtype: Any) -> np.ndarray:
        syms = decode_chunks_np(
            np.asarray(bufs["streams"]), np.asarray(bufs["states"]),
            np.asarray(bufs["sym_tab"]).astype(np.int64),
            np.asarray(bufs["freq_tab"]).astype(np.int64),
            np.asarray(bufs["cum_tab"]).astype(np.int64), meta["chunk_size"])
        raw = syms.reshape(-1)[: meta["n_bytes"]]
        return raw.view(np.dtype(dtype))[:n].copy()

    def stages(self, enc, buf_names: dict[str, str], out_name: str,
               meta_names: dict[str, str] | None = None) -> list:
        meta = enc.meta
        itemsize = int(meta["itemsize"])
        n_bytes = int(meta["n_bytes"])
        bytes_name = f"{out_name}.bytes" if itemsize > 1 else out_name
        stages: list = [NonParallel(
            streams=buf_names["streams"], states=buf_names["states"],
            sym_tab=buf_names["sym_tab"], freq_tab=buf_names["freq_tab"],
            cum_tab=buf_names["cum_tab"], chunk_size=int(meta["chunk_size"]),
            n_chunks=int(meta["n_chunks"]), out=bytes_name, n_out=n_bytes,
            out_dtype=jnp.uint8, host_group_words=meta.get("group_words"),
            name="ans-decode")]
        if itemsize > 1:
            out_dt = (jnp.dtype(enc.dtype)
                      if np.dtype(enc.dtype).itemsize <= 4 else jnp.int32)

            def reassemble(ctx: Ctx, b: jnp.ndarray) -> jnp.ndarray:
                i = ctx.out_idx
                start = (ctx.starts[0]
                         if ctx.starts and ctx.starts[0] is not None else 0)
                base = i * itemsize - start
                v = jnp.zeros_like(i, dtype=jnp.uint32)
                for k in range(itemsize):
                    v = v | (b[base + k].astype(jnp.uint32) << (8 * k))
                if jnp.dtype(out_dt) == jnp.float32:
                    return jax.lax.bitcast_convert_type(v, jnp.float32)
                return v.astype(out_dt)

            stages.append(FullyParallel(
                fn=reassemble, inputs=(bytes_name,),
                specs=(BufSpec("tile", num=itemsize, den=1),),
                out=out_name, n_out=enc.n, out_dtype=out_dt,
                elementwise=False, name="byte-reassemble"))
        return stages


register(AnsCodec())
