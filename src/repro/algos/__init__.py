"""Algorithm Layer (paper §3.2): primitive (de)compression codecs built on the three
patterns.  Importing this package registers every codec."""
from repro.algos import (ans, bitpack, delta, deltastride, dictionary,  # noqa: F401
                         float2int, rle, stringdict)
