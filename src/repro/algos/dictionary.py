"""Dictionary encoding (paper §2.1/Fig. 6(a), Fully-Parallel family).

Encode: unique values -> dictionary; data -> indices.  Decode is a parallel table
lookup with the dictionary resident in VMEM ("the Dictionary is provided as
metadata").  The index buffer is the natural child-plan slot (dictionary|bit-packing,
paper Table 2's date columns).
"""
from __future__ import annotations

from typing import Any

import jax.numpy as jnp
import numpy as np

from repro.core.patterns import BufSpec, Ctx, FullyParallel, primary
from repro.core.registry import register


class DictionaryCodec:
    name = "dictionary"
    pattern = "fp"

    def encode(self, arr: np.ndarray, **_: Any) -> tuple[dict[str, np.ndarray], dict]:
        flat = np.asarray(arr).reshape(-1)
        dictionary, index = np.unique(flat, return_inverse=True)
        return ({"index": index.astype(np.int32), "dictionary": dictionary},
                {"n_dict": int(dictionary.size)})

    def decode_np(self, bufs: dict[str, np.ndarray], meta: dict, n: int,
                  dtype: Any) -> np.ndarray:
        return np.asarray(bufs["dictionary"])[
            np.asarray(bufs["index"]).astype(np.int64)].astype(dtype)

    def stages(self, enc, buf_names: dict[str, str], out_name: str,
               meta_names: dict[str, str] | None = None) -> list:
        out_dt = jnp.dtype(enc.dtype) if np.dtype(enc.dtype).itemsize <= 4 else jnp.int32

        def fn(ctx: Ctx, index: jnp.ndarray, dictionary: jnp.ndarray) -> jnp.ndarray:
            idx = primary(ctx, index)
            return dictionary[idx]

        return [FullyParallel(
            fn=fn, inputs=(buf_names["index"], buf_names["dictionary"]),
            specs=(BufSpec("tile"), BufSpec("full")),
            out=out_name, n_out=enc.n, out_dtype=out_dt,
            elementwise=True, name="dict-lookup")]


def code_bounds(dictionary: np.ndarray, lo, hi) -> tuple[int | None, int | None]:
    """Map a value range ``[lo, hi)`` to a dictionary-code range ``[clo, chi)``.

    ``np.unique`` emits the dictionary SORTED, so order-preserving predicates
    translate exactly: ``value >= lo``  <=>  ``code >= searchsorted(d, lo)``
    and ``value < hi``  <=>  ``code < searchsorted(d, hi)`` (both 'left').
    This lets a range predicate on a dictionary column run on the (bit-packed)
    codes without the dictionary gather.  ``None`` bounds stay open."""
    d = np.asarray(dictionary)
    clo = None if lo is None else int(np.searchsorted(d, lo, side="left"))
    chi = None if hi is None else int(np.searchsorted(d, hi, side="left"))
    return clo, chi


register(DictionaryCodec())
