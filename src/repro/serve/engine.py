"""Batched serving engine: prefill + decode with continuous-batching slots.

Minimal but real: fixed-slot batch, greedy sampling, per-slot lengths, slot recycling
when a sequence emits EOS or hits max length.  The decode step is one jitted program
(shape-stable), which is what the dry-run lowers for the decode_* shapes.

Prompts may arrive as ZipFlow-compressed blobs (``submit_compressed``): they are
decoded through the shared ``StreamingExecutor``/``ProgramCache``, so every request
with the same compression structure reuses one jitted decode program -- the serving
analogue of the column pipeline's one-jit-per-structure rule.  Data-dependent meta
(bitpack base / bit width) is a runtime operand, not program identity, so two
prompts of equal length with different token ranges hit the same cached program
instead of compiling twice.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import plan as plan_mod
from repro.core.executor import StreamingExecutor
from repro.models import get_model


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (S,) int32
    max_new: int = 32
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, batch_slots: int = 4,
                 max_len: int = 512, eos: int = 0,
                 decode_policy: str = "johnson",
                 executor: StreamingExecutor | None = None):
        self.cfg = cfg
        self.model = get_model(cfg)
        self.params = params
        self.slots: list[Request | None] = [None] * batch_slots
        self.max_len = max_len
        self.eos = eos
        self.state = self.model.make_state(batch_slots, max_len)
        self._decode = jax.jit(
            lambda p, t, st: self.model.decode_step(p, t, st))
        self._queue: list[Request] = []
        # decompression engine for compressed prompt ingestion: whole-blob transfer
        # (prompts are small) with a bounded private ProgramCache -- every distinct
        # prompt LENGTH is still a distinct structural signature (shapes jit), so an
        # unbounded cache would grow one program per length for the life of the
        # engine; within a length, operand-lifted meta makes all prompts share one.
        # Decode flows through the same planner layer as the column pipeline
        # (``decode_policy``), so batched prompt ingestion inherits cost-model
        # ordering for free -- a single prompt plans trivially to one whole decode
        from repro.core.compiler import ProgramCache

        self.executor = executor or StreamingExecutor(
            chunk_bytes=None, cache=ProgramCache(max_programs=64),
            policy=decode_policy)

    @property
    def decode_cache_stats(self) -> dict[str, int]:
        """Prompt-decode ProgramCache counters (hits show cross-request reuse)."""
        return self.executor.cache.stats

    def submit(self, req: Request):
        self._queue.append(req)

    def submit_compressed(self, rid: int, enc: plan_mod.Encoded,
                          max_new: int = 32) -> Request:
        """Admit a request whose prompt arrives as a compressed blob."""
        arr = self.executor.run_one(enc, name=f"prompt/{rid}")
        req = Request(rid, np.asarray(arr).astype(np.int32).reshape(-1),
                      max_new=max_new)
        self.submit(req)
        return req

    def _admit(self):
        for i, slot in enumerate(self.slots):
            if slot is None and self._queue:
                req = self._queue.pop(0)
                self.slots[i] = req
                # per-slot prefill (batch=1 against the shared cache is kept simple:
                # tokens fed through decode steps; real TPU serving path would use
                # the prefill program)
                for tok in req.prompt:
                    t = np.zeros((len(self.slots), 1), np.int32)
                    t[i, 0] = tok
                    logits, self.state = self._decode(
                        self.params, jnp.asarray(t), self.state)
                req._last_logits = np.asarray(logits)[i, -1]

    def step(self) -> list[tuple[int, int]]:
        """One decode step for all active slots; returns [(rid, token)]."""
        self._admit()
        if not any(self.slots):
            return []
        toks = np.zeros((len(self.slots), 1), np.int32)
        for i, req in enumerate(self.slots):
            if req is not None and req.out:
                toks[i, 0] = req.out[-1]
            elif req is not None:
                toks[i, 0] = int(np.argmax(req._last_logits))
        logits, self.state = self._decode(self.params, jnp.asarray(toks),
                                          self.state)
        emitted = []
        arr = np.asarray(logits)[:, -1]
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            tok = int(np.argmax(arr[i]))
            req.out.append(tok)
            emitted.append((req.rid, tok))
            if tok == self.eos or len(req.out) >= req.max_new:
                req.done = True
                self.slots[i] = None
        return emitted

    def run_to_completion(self, max_steps: int = 1000) -> dict[int, list[int]]:
        done: dict[int, list[int]] = {}
        all_reqs = list(self._queue)
        for _ in range(max_steps):
            self.step()
            for r in all_reqs:
                if r.done and r.rid not in done:
                    done[r.rid] = r.out
            if not self._queue and not any(self.slots):
                break
        return done
