"""Batched serving engine: prefill + decode with continuous-batching slots.

Minimal but real: fixed-slot batch, greedy sampling, per-slot lengths, slot recycling
when a sequence emits EOS or hits max length.  The decode step is one jitted program
(shape-stable), which is what the dry-run lowers for the decode_* shapes.

Prompts may arrive as ZipFlow-compressed blobs (``submit_compressed``): they
enqueue into a shared ``ServePlanner`` transfer queue instead of decoding
synchronously -- all prompts pending at the next admission drain as ONE planned
wave through the shared ``StreamingExecutor``/``ProgramCache``, so same-structure
prompts from different requests decode in one batched vmap launch (cross-query
batching) and the issue order is chosen under the shared-link contention model.
Data-dependent meta (bitpack base / bit width) is a runtime operand, not program
identity, so two prompts of equal length with different token ranges hit the
same cached program instead of compiling twice.
"""
from __future__ import annotations

import dataclasses
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import plan as plan_mod
from repro.core.executor import StreamingExecutor
from repro.core.serve_planner import ServePlanner
from repro.models import get_model


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (S,) int32
    max_new: int = 32
    out: list = dataclasses.field(default_factory=list)
    done: bool = False
    # decode-wave failure for THIS request's compressed prompt: surfaced to
    # the submitting caller instead of dying in whatever thread drained
    error: BaseException | None = None


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, batch_slots: int = 4,
                 max_len: int = 512, eos: int = 0,
                 decode_policy: str = "johnson",
                 serve_policy: str = "shared",
                 executor: StreamingExecutor | None = None):
        self.cfg = cfg
        self.model = get_model(cfg)
        self.params = params
        self.slots: list[Request | None] = [None] * batch_slots
        self.max_len = max_len
        self.eos = eos
        self.state = self.model.make_state(batch_slots, max_len)
        self._decode = jax.jit(
            lambda p, t, st: self.model.decode_step(p, t, st))
        # prefill feeds the whole prompt in ONE jitted call: a lax.scan of
        # decode_step over all but the last token (state updates only), then
        # one decode_step for the last token's logits -- O(1) dispatches per
        # admission instead of one full-batch launch per prompt token.  One
        # compile per prompt LENGTH (shapes jit), same granularity as the
        # compressed-prompt decode programs below.
        self._prefill = jax.jit(self._prefill_fn)
        self._queue: deque[Request] = deque()
        self._requests: list[Request] = []       # everything ever submitted
        self._awaiting_prompt: dict[int, Request] = {}
        # decompression engine for compressed prompt ingestion: whole-blob transfer
        # (prompts are small) with a bounded private ProgramCache -- every distinct
        # prompt LENGTH is still a distinct structural signature (shapes jit), so an
        # unbounded cache would grow one program per length for the life of the
        # engine; within a length, operand-lifted meta makes all prompts share one.
        # Decode flows through the serving planner's shared transfer queue
        # (``serve_policy``): prompts pending at one admission decode as one
        # planned wave, batching same-signature blobs across requests.
        from repro.core.compiler import ProgramCache

        self.executor = executor or StreamingExecutor(
            chunk_bytes=None, cache=ProgramCache(max_programs=64),
            policy=decode_policy)
        self.planner = ServePlanner(self.executor, policy=serve_policy)

    def _prefill_fn(self, params, toks, state):
        """toks: (S, n_slots, 1) -- scan state through toks[:-1], return the
        last step's logits.  S >= 1 (empty prompts are guarded out)."""
        def step(st, t):
            _, st = self.model.decode_step(params, t, st)
            return st, None

        state, _ = jax.lax.scan(step, state, toks[:-1])
        return self.model.decode_step(params, toks[-1], state)

    @property
    def decode_cache_stats(self) -> dict[str, int]:
        """Prompt-decode ProgramCache counters (hits show cross-request reuse)."""
        return self.executor.cache.stats

    def submit(self, req: Request):
        self._queue.append(req)
        self._requests.append(req)

    def submit_compressed(self, rid: int, enc: plan_mod.Encoded,
                          max_new: int = 32, klass: str = "point") -> Request:
        """Admit a request whose prompt arrives as a compressed blob.

        The blob enqueues into the shared serving planner; it decodes at the
        next admission as part of one planned multi-request wave (the
        returned ``Request``'s ``prompt`` is filled then)."""
        req = Request(rid, np.zeros((0,), np.int32), max_new=max_new)
        self.planner.submit(rid, {"prompt": enc}, klass=klass)
        self._awaiting_prompt[rid] = req
        self._requests.append(req)
        return req

    def _drain_prompts(self):
        """Decode all queued compressed prompts as one shared planned wave.
        A failed wave marks each of its requests done-with-error (the per-
        request exception ``ServePlanner`` attaches) rather than raising out
        of the admission path."""
        if not self.planner.pending:
            return
        for rid, sreq in self.planner.drain().items():
            req = self._awaiting_prompt.pop(int(rid), None)
            if req is None:
                continue
            if sreq.error is not None or "prompt" not in sreq.results:
                req.error = sreq.error or RuntimeError(
                    f"request {rid}: prompt decode produced no result")
                req.done = True
                continue
            req.prompt = np.asarray(
                sreq.results["prompt"].array).astype(np.int32).reshape(-1)
            self._queue.append(req)

    def _admit(self):
        self._drain_prompts()
        for i, slot in enumerate(self.slots):
            if slot is None and self._queue:
                req = self._queue.popleft()
                self.slots[i] = req
                if len(req.prompt) == 0:
                    # zero-length prompt: nothing to prefill; greedy start
                    # from uniform logits (argmax -> token 0)
                    req._last_logits = np.zeros((self.cfg.vocab,), np.float32)
                    continue
                toks = np.zeros((len(req.prompt), len(self.slots), 1),
                                np.int32)
                toks[:, i, 0] = req.prompt
                logits, self.state = self._prefill(
                    self.params, jnp.asarray(toks), self.state)
                req._last_logits = np.asarray(logits)[i, -1]

    def step(self) -> list[tuple[int, int]]:
        """One decode step for all active slots; returns [(rid, token)]."""
        self._admit()
        if not any(self.slots):
            return []
        toks = np.zeros((len(self.slots), 1), np.int32)
        for i, req in enumerate(self.slots):
            if req is not None and req.out:
                toks[i, 0] = req.out[-1]
            elif req is not None:
                toks[i, 0] = int(np.argmax(req._last_logits))
        logits, self.state = self._decode(self.params, jnp.asarray(toks),
                                          self.state)
        emitted = []
        arr = np.asarray(logits)[:, -1]
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            tok = int(np.argmax(arr[i]))
            req.out.append(tok)
            emitted.append((req.rid, tok))
            if tok == self.eos or len(req.out) >= req.max_new:
                req.done = True
                self.slots[i] = None
        return emitted

    def run_to_completion(self, max_steps: int = 1000) -> dict[int, list[int]]:
        done: dict[int, list[int]] = {}
        all_reqs = list(self._requests)
        for _ in range(max_steps):
            self.step()
            for r in all_reqs:
                if r.done and r.rid not in done:
                    done[r.rid] = r.out
            if (not self._queue and not self._awaiting_prompt
                    and not any(self.slots)):
                break
        return done
