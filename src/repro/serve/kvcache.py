"""KV-cache compression for serving (ZipFlow applied to the serving data path).

Two mechanisms:
  * int8 per-head-scale quantization of K/V blocks (in-HBM footprint, 2x vs bf16);
  * bit-packed host<->HBM paging of cold cache blocks (long-context serving swaps
    least-recent blocks to host RAM; the wire format is the ZipFlow bitpack codec so
    the paging link moves ~9-13 bits/value instead of 16).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


def quantize_kv(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x: (..., S, H, hd) -> (int8 values, f32 scales per (..., S, H))."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0 + 1e-9
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_kv(q: jnp.ndarray, scale: jnp.ndarray,
                  dtype=jnp.bfloat16) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


@dataclasses.dataclass
class PagedBlock:
    """A cache block paged out to host in ZipFlow wire format."""
    packed: np.ndarray
    bit_width: int
    base: int
    shape: tuple


def page_out(block: jnp.ndarray) -> PagedBlock:
    """Quantize + bitpack a KV block for host paging."""
    from repro.algos.bitpack import pack_np, required_bits

    q, scale = quantize_kv(block)
    host = np.asarray(q).astype(np.int64).reshape(-1) + 127  # non-negative
    bw = required_bits(254)
    packed = pack_np(host, bw)
    pb = PagedBlock(packed=packed, bit_width=bw, base=-127, shape=block.shape)
    pb.scale = np.asarray(scale)  # type: ignore[attr-defined]
    return pb


def page_in(pb: PagedBlock, dtype=jnp.bfloat16) -> jnp.ndarray:
    from repro.kernels.ref import unpack_bits_ref

    n = int(np.prod(pb.shape))
    vals = unpack_bits_ref(jnp.asarray(pb.packed), n, pb.bit_width, pb.base)
    q = vals.reshape(pb.shape).astype(jnp.int8)
    return dequantize_kv(q, jnp.asarray(pb.scale), dtype)
