"""Assigned-architecture registry: ``--arch <id>`` resolves here."""
from repro.configs import (dbrx_132b, nemotron_4_15b, phi3_5_moe_42b, phi3_mini_3_8b,
                           qwen1_5_0_5b, qwen2_vl_2b, rwkv6_7b, seamless_m4t_medium,
                           smollm_360m, zamba2_7b)
from repro.configs.base import SHAPES, ModelConfig, ShapeConfig

_MODULES = {
    "nemotron-4-15b": nemotron_4_15b,
    "qwen1.5-0.5b": qwen1_5_0_5b,
    "phi3-mini-3.8b": phi3_mini_3_8b,
    "smollm-360m": smollm_360m,
    "seamless-m4t-medium": seamless_m4t_medium,
    "rwkv6-7b": rwkv6_7b,
    "zamba2-7b": zamba2_7b,
    "qwen2-vl-2b": qwen2_vl_2b,
    "phi3.5-moe-42b-a6.6b": phi3_5_moe_42b,
    "dbrx-132b": dbrx_132b,
}

ARCHS: dict[str, ModelConfig] = {k: m.CONFIG for k, m in _MODULES.items()}
SMOKES: dict[str, ModelConfig] = {k: m.SMOKE for k, m in _MODULES.items()}


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    table = SMOKES if smoke else ARCHS
    if arch not in table:
        raise KeyError(f"unknown arch '{arch}'; known: {sorted(table)}")
    return table[arch]


def cells() -> list[tuple[str, str]]:
    """All 40 (arch x shape) dry-run cells, including recorded skips."""
    return [(a, s) for a in ARCHS for s in SHAPES]


__all__ = ["ARCHS", "SHAPES", "SMOKES", "ModelConfig", "ShapeConfig", "cells",
           "get_config"]
