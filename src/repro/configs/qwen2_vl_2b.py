"""qwen2-vl-2b [vlm]: M-RoPE, dynamic resolution [arXiv:2409.12191; hf].

Backbone only: the vision tower is a stub; ``input_specs`` provides precomputed patch
embeddings for ``image_frac`` of the sequence plus 3D (t,h,w) M-RoPE position ids.
head_dim=128; mrope_sections=(16,24,24) halves-of-head-dim split as in the release.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b", family="vlm", n_layers=28, d_model=1536, n_heads=12,
    n_kv_heads=2, d_ff=8960, vocab=151936, head_dim=128, mrope=True,
    mrope_sections=(16, 24, 24), image_frac=0.25)

SMOKE = ModelConfig(
    name="qwen2-vl-2b-smoke", family="vlm", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab=256, head_dim=16, mrope=True,
    mrope_sections=(4, 2, 2), image_frac=0.25)
