"""zamba2-7b [hybrid]: Mamba2 backbone + shared attention block [arXiv:2411.15242;
unverified].

81 Mamba2 layers; one *shared* (weight-tied) attention+MLP block is interposed every
``attn_every`` inner layers (the Zamba2 design re-uses a single transformer block).
ssm_state=64 per the assignment.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid", n_layers=81, d_model=3584, n_heads=32,
    n_kv_heads=32, d_ff=14336, vocab=32000, ssm_state=64, ssm_heads=112,
    ssm_chunk=128, attn_every=6)

SMOKE = ModelConfig(
    name="zamba2-7b-smoke", family="hybrid", n_layers=4, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=128, vocab=256, ssm_state=16, ssm_heads=2, ssm_chunk=16,
    attn_every=2)
