"""Model/config dataclasses shared by every assigned architecture."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None
    mlp: str = "swiglu"         # swiglu | relu2 | gelu
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    dtype: Any = jnp.bfloat16
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_group_size: int = 512   # GShard dispatch group (perf-tunable)
    # --- SSM / RWKV ---
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_chunk: int = 256        # chunked-scan block for train/prefill
    # --- hybrid (zamba2-style shared attention) ---
    attn_every: int = 0         # apply the shared attn block every k inner layers
    # --- enc-dec ---
    enc_layers: int = 0
    dec_layers: int = 0
    # --- VLM ---
    mrope: bool = False
    mrope_sections: tuple[int, int, int] = (16, 24, 24)  # t/h/w split of head_dim/2
    image_frac: float = 0.25    # fraction of train/prefill tokens that are patches

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def sub_quadratic(self) -> bool:
        """True when serving 500k-token contexts is deployable (SSM/hybrid)."""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks), for 6*N*D roofline."""
        D, F, V, L = self.d_model, self.d_ff, self.vocab, self.n_layers
        hd = self.hd
        emb = V * D * (1 if self.tie_embeddings else 2)
        if self.family == "ssm":  # rwkv6
            per = D * D * 4 + D * F * 2 + D * 64 * 8  # timemix + channelmix + lora
            return emb + L * per
        attn = D * hd * self.n_heads + 2 * D * hd * self.n_kv_heads \
            + self.n_heads * hd * D
        if self.family == "moe":
            ffn = self.n_experts * 3 * D * F + D * self.n_experts
        elif self.mlp == "swiglu":
            ffn = 3 * D * F
        else:
            ffn = 2 * D * F
        per = attn + ffn + 2 * D
        if self.family == "hybrid":
            # mamba2 inner layers + one shared attention/mlp block
            n_shared = max(1, L // max(1, self.attn_every))
            mamba = L * (2 * D * 2 * D + 2 * D * (self.ssm_state * 2 + self.ssm_heads)
                         + 2 * D * D)
            shared = attn + 3 * D * F + 2 * D
            return emb + mamba + shared + n_shared * 2 * D * D // 8
        if self.family == "encdec":
            enc = self.enc_layers * (attn + ffn + 2 * D)
            dec = self.dec_layers * (attn + attn + ffn + 3 * D)  # + cross-attn
            return emb + enc + dec
        return emb + L * per

    def active_param_count(self) -> int:
        """Activated params per token (MoE: top_k experts only)."""
        if self.family != "moe":
            return self.param_count()
        D, F, L = self.d_model, self.d_ff, self.n_layers
        dense = self.param_count() - L * self.n_experts * 3 * D * F
        return dense + L * self.top_k * 3 * D * F


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}
