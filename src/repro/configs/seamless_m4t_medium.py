"""seamless-m4t-medium [audio]: enc-dec multimodal backbone [arXiv:2308.11596; hf].

The assigned listing says 12L; m4t-medium pairs a 12-layer speech/text encoder with a
12-layer text decoder, so enc_layers=dec_layers=12.  The audio frontend is a stub:
``input_specs`` yields precomputed frame embeddings (B, S_src, d_model).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="encdec", n_layers=24, d_model=1024,
    n_heads=16, n_kv_heads=16, d_ff=4096, vocab=256206,
    enc_layers=12, dec_layers=12)

SMOKE = ModelConfig(
    name="seamless-m4t-medium-smoke", family="encdec", n_layers=4, d_model=64,
    n_heads=4, n_kv_heads=4, d_ff=128, vocab=256, enc_layers=2, dec_layers=2)
