"""rwkv6-7b [ssm]: Finch, attention-free, data-dependent decay [arXiv:2404.05892; hf].

head_size=64 => 64 heads at d_model=4096.  ssm_state is the per-head (64,64) wkv
state; ssm_chunk is the chunked-scan block length for train/prefill.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b", family="ssm", n_layers=32, d_model=4096, n_heads=64,
    n_kv_heads=64, d_ff=14336, vocab=65536, ssm_state=64, ssm_heads=64,
    ssm_chunk=128)

SMOKE = ModelConfig(
    name="rwkv6-7b-smoke", family="ssm", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=128, vocab=256, ssm_state=16, ssm_heads=4, ssm_chunk=16)
