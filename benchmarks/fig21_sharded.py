"""Sharded multi-device streaming decode scaling (topology-aware planning).

Modeled rows: TPC-H column profiles planned over N = 1/2/4/8 virtual devices
through ``planner.plan_mesh_execution`` -- each row reports the chosen
assignment's ``simulate_stream_multi`` makespan next to the naive round-robin
and single-device baselines it must dominate BY CONSTRUCTION (both are scored
candidates).  These rows need no devices: they exercise the N-link flow-shop
model itself.

Measured rows: when the process actually has >= 2 jax devices (run under
``XLA_FLAGS=--xla_force_host_platform_device_count=N``, as scripts/
bench_smoke.sh and the CI mesh job do), the same columns execute through
``StreamingExecutor.run_sharded`` -- per-device committed transfers,
shard-local group-span decode -- and every output is asserted bitwise equal
to the single-device decode.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import row
from repro.core import plan as P
from repro.core import planner
from repro.core.compiler import ProgramCache
from repro.core.costmodel import LinkTopology
from repro.core.executor import StreamingExecutor
from repro.data.columns import TABLE2_PLANS
from repro.data.tpch import generate

FIG21_COLS = ["L_PARTKEY", "L_SHIPDATE", "L_EXTENDEDPRICE", "L_ORDERKEY",
              "L_RETURNFLAG", "L_QUANTITY", "O_COMMENT", "L_SUPPKEY"]


def main(quick: bool = False) -> list[str]:
    rows: list[str] = []
    cols = generate(scale=0.002 if quick else 0.005, seed=0)
    names = [n for n in FIG21_COLS if n in TABLE2_PLANS][:6 if quick else None]
    ex = StreamingExecutor(chunk_bytes="auto", chunk_decode=True,
                           cache=ProgramCache())
    encs = {}
    for name in names:
        encs[name] = P.encode(TABLE2_PLANS[name], cols[name])
        ex.compile(name, encs[name])
    # one large skewed ANS chunk-grid column: enough groups to group-span
    # shard (the TPC-H columns at benchmark scale are too small / not
    # group-chunkable), with ragged per-chunk word counts
    rng = np.random.default_rng(0)
    big = np.concatenate([
        np.zeros(60_000 if quick else 240_000, np.int32),
        rng.integers(0, 60, 40_000 if quick else 160_000).astype(np.int32)])
    names = names + ["BIG_ANS"]
    cols["BIG_ANS"] = big
    encs["BIG_ANS"] = P.encode(P.Plan("ans", params={"chunk_size": 512}), big)
    ex.compile("BIG_ANS", encs["BIG_ANS"])
    profiles = {n: ex.column_profile(n) for n in names}
    total_b = sum(p.compressed_nbytes for p in profiles.values())

    # --- modeled scaling: N independent links, shared host staging ---
    for N in (1, 2, 4, 8):
        mp = planner.plan_mesh_execution(profiles, ex.cost_model, n_devices=N)
        mk = mp.modeled_makespan_s
        rr = mp.baselines["round-robin"]
        single = mp.baselines["single-device"]
        assert mk <= rr + 1e-12 and mk <= single + 1e-12, (
            f"dominance violated at N={N}: {mk} vs rr={rr} single={single}")
        rows.append(row(
            f"fig21/sharded_model_n{N}", mk,
            f"sharded_mk={mk * 1e6:.1f};rr_mk={rr * 1e6:.1f};"
            f"single_mk={single * 1e6:.1f};chosen={mp.policy};"
            f"n_sharded_cols={len(mp.shards)};"
            f"speedup_vs_single={single / max(mk, 1e-12):.2f}"))

    # --- modeled D2D rebalance: one 6x-slowed host link + a fast fabric.
    # placement="sharded" pins shard i's FINAL home to logical device i;
    # decode-where-landed streams those bytes over a fast link instead and
    # pays one fabric copy per displaced shard.  Decode-in-place is ALWAYS a
    # scored candidate, so the chosen makespan can only tie or beat it --
    # with this skew it must strictly beat it, carrying real D2D legs; the
    # same topology without a fabric must never propose redistribution. ---
    topo_fab = LinkTopology(n_links=4, link_scale=(6.0, 1.0, 1.0, 1.0),
                            d2d_scale=0.05)
    topo_nofab = LinkTopology(n_links=4, link_scale=(6.0, 1.0, 1.0, 1.0))
    mp_fab = planner.plan_mesh_execution(
        profiles, ex.cost_model, n_devices=4, shard_threshold_bytes=0,
        topology=topo_fab, placement="sharded")
    mp_nofab = planner.plan_mesh_execution(
        profiles, ex.cost_model, n_devices=4, shard_threshold_bytes=0,
        topology=topo_nofab, placement="sharded")
    redist_mk = mp_fab.modeled_makespan_s
    direct_mk = mp_fab.baselines["no-redistribution"]
    assert mp_fab.redistribution, "fast fabric must beat the 6x link"
    assert redist_mk < direct_mk, (redist_mk, direct_mk)
    assert not mp_nofab.redistribution, "no fabric -> no D2D legs"
    rows.append(row(
        "fig21/d2d_rebalance_model", redist_mk,
        f"redist_mk={redist_mk * 1e6:.1f};direct_mk={direct_mk * 1e6:.1f};"
        f"nofabric_mk={mp_nofab.modeled_makespan_s * 1e6:.1f};"
        f"n_legs={len(mp_fab.redistribution)};chosen={mp_fab.policy};"
        f"win_vs_direct={direct_mk / max(redist_mk, 1e-12):.2f}"))

    # --- measured: real run_sharded when the process has multiple devices ---
    n_dev = jax.device_count()
    if n_dev >= 2:
        refs = {n: P.decode_np(enc) for n, enc in encs.items()}
        for N in [x for x in (1, 2, 4) if x <= n_dev]:
            # force at least one group-span-sharded column so the shard path
            # is measured, not just whole-column placement
            mp = planner.plan_mesh_execution(
                profiles, ex.cost_model, n_devices=N,
                shard_threshold_bytes=total_b // (2 * N) if N > 1 else None)
            t0 = time.perf_counter()
            res = ex.run_sharded(mp, encs)
            wall = time.perf_counter() - t0
            for n in names:
                np.testing.assert_array_equal(np.asarray(res[n].array),
                                              refs[n], err_msg=n)
            launches = sum(res.device_launches.values())
            rows.append(row(
                f"fig21/sharded_measured_n{N}", wall,
                f"devices={len(res.per_device)};launches={launches};"
                f"n_sharded_cols={len(mp.shards)};bit_exact=1"))
        # --- async overlap: the SAME mesh plan executed with all device legs
        # issued concurrently through the DispatchEngine (one transfer worker
        # per link) vs the legacy one-device-at-a-time host loop.  Interleaved
        # best-of-3; both modes asserted bitwise against the single-device
        # oracle.  On a single-core host concurrent issuance cannot win, so
        # the bench_smoke guard is "no regression within tolerance". ---
        N = min(4, n_dev)
        mp = planner.plan_mesh_execution(
            profiles, ex.cost_model, n_devices=N,
            shard_threshold_bytes=total_b // (2 * N))
        for conc in (False, True):          # warm both paths + bitwise check
            res = ex.run_sharded(mp, encs, concurrent=conc)
            for n in names:
                np.testing.assert_array_equal(
                    np.asarray(res[n].array), refs[n],
                    err_msg=f"async_overlap conc={conc}/{n}")
        t_seq, t_conc = [], []
        for _ in range(5):
            t0 = time.perf_counter()
            ex.run_sharded(mp, encs, concurrent=False)
            t_seq.append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            ex.run_sharded(mp, encs, concurrent=True)
            t_conc.append(time.perf_counter() - t0)
        rows.append(row(
            f"fig21/async_overlap_n{N}", min(t_conc),
            f"concurrent={min(t_conc):.4f}s;sequential={min(t_seq):.4f}s;"
            f"devices={N};bit_exact=1"))
        # --- measured D2D rebalance: the skewed-link + fabric plan executed
        # for real -- fabric legs are timed jax.device_put copies issued
        # through the dispatch engine, outputs bitwise identical and shards
        # landing on the REQUESTED placement devices ---
        N = min(4, n_dev)
        mp_d2d = planner.plan_mesh_execution(
            profiles, ex.cost_model, n_devices=N, shard_threshold_bytes=0,
            topology=LinkTopology(
                n_links=N, link_scale=(6.0,) + (1.0,) * (N - 1),
                d2d_scale=0.05),
            placement="sharded")
        t0 = time.perf_counter()
        res = ex.run_sharded(mp_d2d, encs)
        wall = time.perf_counter() - t0
        for n in names:
            np.testing.assert_array_equal(np.asarray(res[n].array),
                                          refs[n], err_msg=f"d2d/{n}")
        placement_ok = all(
            res[col].shard_devices == tuple(
                int(mp_d2d.device_ids[mp_d2d.final_device(s.name)])
                for s in specs)
            for col, specs in mp_d2d.shards.items())
        rows.append(row(
            "fig21/d2d_rebalance_measured", wall,
            f"devices={N};legs={len(res.d2d_copies)};"
            f"planned_legs={len(mp_d2d.redistribution)};bit_exact=1;"
            f"placement_ok={int(placement_ok)}"))
    else:
        rows.append(row(
            "fig21/sharded_measured_skipped", 0.0,
            f"devices={n_dev};hint=XLA_FLAGS=--xla_force_host_platform_"
            "device_count=4"))
    return rows


if __name__ == "__main__":
    main()
