"""Benchmark harness: one module per paper table/figure.

``python -m benchmarks.run [--quick] [--only fig12,fig19]``
prints ``name,us_per_call,derived`` CSV rows (the scaffold contract).
"""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks import (ckpt_grad, fig12_bitpack, fig13_rle, fig14_ans,
                        fig15_ans_chunks, fig16_tpch_ratio,
                        fig17_tpch_throughput, fig18_fusion, fig19_e2e,
                        fig22_geometry, roofline_table)

MODULES = {
    "fig12": fig12_bitpack, "fig13": fig13_rle, "fig14": fig14_ans,
    "fig15": fig15_ans_chunks, "fig16": fig16_tpch_ratio,
    "fig17": fig17_tpch_throughput, "fig18": fig18_fusion,
    "fig19": fig19_e2e, "fig22": fig22_geometry,
    "roofline": roofline_table, "ckpt_grad": ckpt_grad,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced sweeps (CI-sized)")
    ap.add_argument("--only", default=None,
                    help="comma-separated module keys")
    args = ap.parse_args()
    keys = args.only.split(",") if args.only else list(MODULES)
    print("name,us_per_call,derived")
    t0 = time.time()
    for key in keys:
        mod = MODULES[key]
        print(f"# --- {key} ({mod.__doc__.splitlines()[0].strip()}) ---",
              flush=True)
        try:
            mod.main(quick=args.quick)
        except Exception as e:  # noqa: BLE001 -- keep the harness running
            print(f"{key}/ERROR,0,{type(e).__name__}: {str(e)[:120]}",
                  file=sys.stderr)
            raise
    print(f"# total {time.time() - t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
