"""Paper Fig. 15: ANS chunk-size sweep x input size -- the ratio/parallelism
trade-off.  Larger chunks amortize per-chunk state+padding (better ratio); smaller
chunks give more lockstep lanes (throughput on wide machines)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import gbps, row, time_fn
from repro.core import plan as P
from repro.core.compiler import compile_decoder, device_buffers


def main(quick: bool = False) -> list[str]:
    rng = np.random.default_rng(3)
    rows = []
    sizes = [1 << 18] if quick else [1 << 18, 1 << 21, 1 << 23]
    chunks = [1024, 8192] if quick else [512, 1024, 4096, 16384, 65536]
    for n in sizes:
        arr = rng.choice(np.arange(4, dtype=np.uint8) + 60, n,
                         p=[.55, .25, .15, .05]).astype(np.uint8)
        for cs in chunks:
            if cs > n:
                continue
            enc = P.encode(P.Plan("ans", params={"chunk_size": cs}), arr)
            dec = compile_decoder(enc)
            t = time_fn(dec, device_buffers(enc), iters=3)
            rows.append(row(
                f"fig15/ans_n{n >> 10}k_cs{cs}", t,
                f"cpu_gbps={gbps(n, t):.3f};ratio={enc.ratio:.2f};"
                f"lanes={enc.meta['n_chunks']}"))
    return rows


if __name__ == "__main__":
    main()
