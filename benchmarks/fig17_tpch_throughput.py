"""Paper Fig. 17: per-column decompression throughput on TPC-H (ZipFlow vs the
unfused fixed-geometry baseline), with the compression-ratio advantage as the
derived column.

Columns compile through a ProgramCache (one jit per structure -- the cache stats
row reports hit/miss/eviction counters, so cross-blob program reuse is observable,
not inferred) and the timed decode is the cached Program on pre-transferred
buffers; transfer overlap is fig19's subject.

The ``operand_reuse`` row re-encodes every integer column as a value-shifted twin:
identical structure, different data-dependent meta (bitpack base, delta base).
With meta lifted to runtime operands those twins are pure cache hits -- zero new
compiles -- where the meta-as-constant scheme recompiled each one.

The ``costmodel`` row streams each column's measured decode into the planner's
``CostModel`` and reports the per-column prediction error before vs after the
EWMA calibration warms up -- the feedback loop fig19's planner schedules by.

The ``cost_persistence`` row saves the warmed model and loads it into a FRESH
``CostModel`` (a new process's planning state): predictions for the same column
structures must come back from the persisted per-signature history, not the raw
chip model.  The ``group_chunk`` row decodes each group-chunkable column
(CHUNK_GROUP: ANS chunk grids here) whole vs group-boundary-streamed and
asserts bit-equality -- the measured counterpart of what used to be model-only."""
from __future__ import annotations

import os
import tempfile
import time

import jax
import numpy as np

from benchmarks.common import gbps, row, time_fn
from repro.core import plan as P
from repro.core.compiler import (ProgramCache, compile_blob, compile_decoder,
                                 device_buffers)
from repro.core.costmodel import CostModel, profile_from
from repro.core.executor import StreamingExecutor
from repro.core.ir import CHUNK_GROUP
from repro.data.columns import TABLE2_PLANS
from repro.data.tpch import generate

QUICK_COLS = ["L_PARTKEY", "L_SHIPDATE", "L_EXTENDEDPRICE", "L_ORDERKEY",
              "L_RETURNFLAG", "O_COMMENT"]


def main(quick: bool = False) -> list[str]:
    cols = generate(scale=0.002 if quick else 0.005, seed=0)
    rows = []
    names = QUICK_COLS if quick else list(TABLE2_PLANS)
    cache = ProgramCache()
    cm = CostModel()
    pred_errs = []
    for name in names:
        enc = P.encode(TABLE2_PLANS[name], cols[name])
        prog = compile_blob(enc, backend="jnp", fuse=True, cache=cache)
        cm.register(profile_from(name, enc, prog.graph))
        pred_d = cm.predict(name)[1]     # calibrated decode prediction, pre-run
        t0 = time.perf_counter()
        bufs = device_buffers(enc)
        jax.block_until_ready(list(bufs.values()))
        t_transfer = time.perf_counter() - t0
        t_zip = time_fn(prog, bufs, iters=3)
        pred_errs.append(abs(pred_d / t_zip - 1.0))
        cm.observe(name, t_transfer, t_zip)   # EWMA feedback for later columns
        t_base = time_fn(compile_decoder(enc, backend="baseline"), bufs, iters=3)
        rows.append(row(
            f"fig17/{name}", t_zip,
            f"cpu_gbps={gbps(enc.plain_nbytes, t_zip):.2f};"
            f"baseline_gbps={gbps(enc.plain_nbytes, t_base):.2f};"
            f"speedup={t_base / t_zip:.2f};ratio={enc.ratio:.2f};"
            f"sig={prog.signature[:8]}"))
    half = max(1, len(pred_errs) // 2)
    rows.append(row(
        "fig17/costmodel", 0.0,
        f"decode_scale={cm.decode_scale:.1f};"
        f"mean_err_first_half={float(np.mean(pred_errs[:half])):.2f};"
        f"mean_err_second_half={float(np.mean(pred_errs[half:])):.2f}"))
    stats = cache.stats
    rows.append(row(
        "fig17/program_cache", 0.0,
        f"columns={len(names)};programs={stats['programs']};"
        f"hits={stats['hits']};misses={stats['misses']};"
        f"evictions={stats['evictions']}"))
    # --- operand-lifted cross-blob reuse: shifted twins must be pure hits ---
    misses_before = stats["misses"]
    twins = 0
    for name in names:
        arr = cols[name]
        if arr.dtype.kind not in "iu" or arr.dtype == np.uint8:
            continue    # ans/stringdict twins change stream shapes; ints suffice
        twin = (arr + 7).astype(arr.dtype)   # same span/runs, different base meta
        compile_blob(P.encode(TABLE2_PLANS[name], twin), backend="jnp",
                     fuse=True, cache=cache)
        twins += 1
    stats = cache.stats
    rows.append(row(
        "fig17/operand_reuse", 0.0,
        f"twin_columns={twins};new_compiles={stats['misses'] - misses_before};"
        f"hits={stats['hits']}"))
    # --- cost-model persistence: a fresh model plans from saved history ---
    fd, cache_path = tempfile.mkstemp(suffix=".json")
    os.close(fd)
    try:
        cm.save(cache_path)
        fresh = CostModel.load(cache_path)
        hist_errs = []
        for name in names:
            enc = P.encode(TABLE2_PLANS[name], cols[name])
            prog = compile_blob(enc, backend="jnp", fuse=True, cache=cache)
            fresh.register(profile_from(name, enc, prog.graph))
            t_meas, d_meas = cm.measured[name]
            _, d_hist = fresh.predict(name)   # from persisted signature stats
            hist_errs.append(abs(d_hist / max(d_meas, 1e-12) - 1.0))
        rows.append(row(
            "fig17/cost_persistence", 0.0,
            f"signatures={len(fresh.sig_stats)};"
            f"mean_err_from_history={float(np.mean(hist_errs)):.2f};"
            f"n_observed={fresh.n_observed}"))
    finally:
        os.unlink(cache_path)
    # --- group-boundary chunked decode, measured (CHUNK_GROUP columns) ---
    from repro.core import costmodel as costmodel_mod
    from repro.core.ir import group_chunk_layout

    padded_b = ragged_b = 0       # ANS stripe transfer bytes: padded vs capped
    for name in names:
        enc = P.encode(TABLE2_PLANS[name], cols[name])
        lay = group_chunk_layout(compile_blob(enc, cache=cache).graph)
        if lay is None:
            continue
        # span size from the column's own group geometry (~4 spans), so the
        # row engages at every benchmark scale
        bpg = costmodel_mod.group_bytes_per_group(lay, P.host_operands(enc))
        cb = max(256, int(np.ceil(bpg * max(1, lay.n_groups // 4))))
        ex = StreamingExecutor(chunk_bytes=cb, chunk_decode=True,
                               cache=ProgramCache())
        ex.compile(name, enc)
        if ex.graph(name).chunkability != CHUNK_GROUP:
            continue
        if ex.chunk_schedule(name) is None:
            continue
        res = ex.run({name: enc})[name]        # cold: traces span programs
        np.testing.assert_array_equal(np.asarray(res.array),
                                      P.decode_np(enc), err_msg=name)
        t0 = time.perf_counter()
        res = ex.run({name: enc})[name]        # warm group-streamed wall-clock
        t_group = time.perf_counter() - t0
        rows.append(row(
            f"fig17/group_chunk/{name}", t_group,
            f"launches={res.decode_launches};spans={res.n_chunks};"
            f"gbps={gbps(enc.plain_nbytes, max(t_group, 1e-9)):.2f};"
            f"bit_exact=1"))
        # unpadded ANS stripes: per-span row caps (encoder group_words) vs the
        # max_words-padded layout the spans used to transfer
        sched = ex.chunk_schedule(name)
        ops = P.host_operands(enc)
        for nm, caps in sched.row_caps.items():
            arr = np.asarray(ops[nm])
            isz = arr.dtype.itemsize
            for k, (lo, hi) in enumerate(sched.slices[nm]):
                padded_b += arr.shape[0] * (hi - lo) * isz
                ragged_b += caps[k] * (hi - lo) * isz
    if padded_b:
        rows.append(row(
            "fig17/ragged_stripes", 0.0,
            f"padded_bytes={padded_b};ragged_bytes={ragged_b};"
            f"saved_pct={100.0 * (1.0 - ragged_b / padded_b):.1f}"))
    return rows


if __name__ == "__main__":
    main()
