"""Paper Fig. 17: per-column decompression throughput on TPC-H (ZipFlow vs the
unfused fixed-geometry baseline), with the compression-ratio advantage as the
derived column.

Columns compile through a ProgramCache (one jit per structure -- the cache stats
row reports how many programs served how many columns) and the timed decode is the
cached Program on pre-transferred buffers; transfer overlap is fig19's subject."""
from __future__ import annotations

from benchmarks.common import gbps, row, time_fn
from repro.core import plan as P
from repro.core.compiler import (ProgramCache, compile_blob, compile_decoder,
                                 device_buffers)
from repro.data.columns import TABLE2_PLANS
from repro.data.tpch import generate

QUICK_COLS = ["L_PARTKEY", "L_SHIPDATE", "L_EXTENDEDPRICE", "L_ORDERKEY",
              "L_RETURNFLAG", "O_COMMENT"]


def main(quick: bool = False) -> list[str]:
    cols = generate(scale=0.002 if quick else 0.005, seed=0)
    rows = []
    names = QUICK_COLS if quick else list(TABLE2_PLANS)
    cache = ProgramCache()
    for name in names:
        enc = P.encode(TABLE2_PLANS[name], cols[name])
        prog = compile_blob(enc, backend="jnp", fuse=True, cache=cache)
        bufs = device_buffers(enc)
        t_zip = time_fn(prog, bufs, iters=3)
        t_base = time_fn(compile_decoder(enc, backend="baseline"), bufs, iters=3)
        rows.append(row(
            f"fig17/{name}", t_zip,
            f"cpu_gbps={gbps(enc.plain_nbytes, t_zip):.2f};"
            f"baseline_gbps={gbps(enc.plain_nbytes, t_base):.2f};"
            f"speedup={t_base / t_zip:.2f};ratio={enc.ratio:.2f};"
            f"sig={prog.signature[:8]}"))
    rows.append(row(
        "fig17/program_cache", 0.0,
        f"columns={len(names)};programs={cache.stats['programs']};"
        f"hits={cache.stats['hits']}"))
    return rows


if __name__ == "__main__":
    main()
