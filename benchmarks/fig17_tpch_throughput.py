"""Paper Fig. 17: per-column decompression throughput on TPC-H (ZipFlow vs the
unfused fixed-geometry baseline), with the compression-ratio advantage as the
derived column.

Columns compile through a ProgramCache (one jit per structure -- the cache stats
row reports hit/miss/eviction counters, so cross-blob program reuse is observable,
not inferred) and the timed decode is the cached Program on pre-transferred
buffers; transfer overlap is fig19's subject.

The ``operand_reuse`` row re-encodes every integer column as a value-shifted twin:
identical structure, different data-dependent meta (bitpack base, delta base).
With meta lifted to runtime operands those twins are pure cache hits -- zero new
compiles -- where the meta-as-constant scheme recompiled each one.

The ``costmodel`` row streams each column's measured decode into the planner's
``CostModel`` and reports the per-column prediction error before vs after the
EWMA calibration warms up -- the feedback loop fig19's planner schedules by."""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import gbps, row, time_fn
from repro.core import plan as P
from repro.core.compiler import (ProgramCache, compile_blob, compile_decoder,
                                 device_buffers)
from repro.core.costmodel import CostModel, profile_from
from repro.data.columns import TABLE2_PLANS
from repro.data.tpch import generate

QUICK_COLS = ["L_PARTKEY", "L_SHIPDATE", "L_EXTENDEDPRICE", "L_ORDERKEY",
              "L_RETURNFLAG", "O_COMMENT"]


def main(quick: bool = False) -> list[str]:
    cols = generate(scale=0.002 if quick else 0.005, seed=0)
    rows = []
    names = QUICK_COLS if quick else list(TABLE2_PLANS)
    cache = ProgramCache()
    cm = CostModel()
    pred_errs = []
    for name in names:
        enc = P.encode(TABLE2_PLANS[name], cols[name])
        prog = compile_blob(enc, backend="jnp", fuse=True, cache=cache)
        cm.register(profile_from(name, enc, prog.graph))
        pred_d = cm.predict(name)[1]     # calibrated decode prediction, pre-run
        t0 = time.perf_counter()
        bufs = device_buffers(enc)
        jax.block_until_ready(list(bufs.values()))
        t_transfer = time.perf_counter() - t0
        t_zip = time_fn(prog, bufs, iters=3)
        pred_errs.append(abs(pred_d / t_zip - 1.0))
        cm.observe(name, t_transfer, t_zip)   # EWMA feedback for later columns
        t_base = time_fn(compile_decoder(enc, backend="baseline"), bufs, iters=3)
        rows.append(row(
            f"fig17/{name}", t_zip,
            f"cpu_gbps={gbps(enc.plain_nbytes, t_zip):.2f};"
            f"baseline_gbps={gbps(enc.plain_nbytes, t_base):.2f};"
            f"speedup={t_base / t_zip:.2f};ratio={enc.ratio:.2f};"
            f"sig={prog.signature[:8]}"))
    half = max(1, len(pred_errs) // 2)
    rows.append(row(
        "fig17/costmodel", 0.0,
        f"decode_scale={cm.decode_scale:.1f};"
        f"mean_err_first_half={float(np.mean(pred_errs[:half])):.2f};"
        f"mean_err_second_half={float(np.mean(pred_errs[half:])):.2f}"))
    stats = cache.stats
    rows.append(row(
        "fig17/program_cache", 0.0,
        f"columns={len(names)};programs={stats['programs']};"
        f"hits={stats['hits']};misses={stats['misses']};"
        f"evictions={stats['evictions']}"))
    # --- operand-lifted cross-blob reuse: shifted twins must be pure hits ---
    misses_before = stats["misses"]
    twins = 0
    for name in names:
        arr = cols[name]
        if arr.dtype.kind not in "iu" or arr.dtype == np.uint8:
            continue    # ans/stringdict twins change stream shapes; ints suffice
        twin = (arr + 7).astype(arr.dtype)   # same span/runs, different base meta
        compile_blob(P.encode(TABLE2_PLANS[name], twin), backend="jnp",
                     fuse=True, cache=cache)
        twins += 1
    stats = cache.stats
    rows.append(row(
        "fig17/operand_reuse", 0.0,
        f"twin_columns={twins};new_compiles={stats['misses'] - misses_before};"
        f"hits={stats['hits']}"))
    return rows


if __name__ == "__main__":
    main()
