"""Paper Fig. 13: RLE (Group-Parallel) decompression under group-size distributions
(even / random / outlier / mixed).  The balanced output-centric kernel's throughput
should be insensitive to skew; the baseline materializes more and has fixed geometry.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import gbps, modeled_tpu_throughput_gbps, row, time_fn
from benchmarks.fig12_bitpack import tpu_model_ms
from repro.core import plan as P
from repro.core.compiler import compile_decoder, device_buffers

N = 1 << 21


def _counts(dist: str, rng) -> np.ndarray:
    if dist.startswith("even"):
        k = int(dist[4:])
        return np.full(N // k, k)
    if dist == "random":
        c = rng.integers(1, 256, N // 96)
        return c
    if dist == "outlier":
        c = np.where(rng.random(N // 8) < 0.004, 1024, 1)
        return c
    if dist == "mixed":
        return np.concatenate([np.full(N // 8, 4),
                               np.where(rng.random(N // 16) < 0.01, 2048, 1)])
    raise ValueError(dist)


def main(quick: bool = False) -> list[str]:
    rng = np.random.default_rng(1)
    rows = []
    dists = ["even4", "outlier"] if quick else \
        ["even2", "even16", "even256", "random", "outlier", "mixed"]
    for dist in dists:
        counts = _counts(dist, rng)
        csum = np.cumsum(counts)
        counts = counts[: int(np.searchsorted(csum, N)) + 1]
        values = rng.integers(0, 4096, counts.size).astype(np.int32)
        arr = np.repeat(values, counts).astype(np.int32)
        enc = P.encode(P.Plan("rle", children={"counts": P.make_plan("bitpack"),
                                               "values": P.make_plan("bitpack")}),
                       arr)
        bufs = device_buffers(enc)
        for label, backend in (("zipflow", "jnp"), ("baseline", "baseline")):
            dec = compile_decoder(enc, backend=backend)
            t = time_fn(dec, bufs)
            theo = modeled_tpu_throughput_gbps(enc.plain_nbytes,
                                               enc.compressed_nbytes)
            rows.append(row(
                f"fig13/rle_{dist}_{label}", t,
                f"cpu_gbps={gbps(enc.plain_nbytes, t):.2f};"
                f"ratio={enc.ratio:.2f};tpu_eq1_gbps={theo:.0f};"
                f"tpu_model_ms={tpu_model_ms('gp', N, label == 'zipflow'):.3f}"))
    return rows


if __name__ == "__main__":
    main()
