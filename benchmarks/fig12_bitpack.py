"""Paper Fig. 12: bit-packing (Fully-Parallel) decompression throughput vs bit width.

ZipFlow (fused, native geometry) vs the baseline backend (fixed library geometry, the
nvCOMP role).  The dashed-line theoretical max of the paper (Eq. 1) is reported as the
derived column.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import gbps, modeled_tpu_throughput_gbps, row, time_fn
from repro.core import plan as P
from repro.core.compiler import compile_decoder, device_buffers
from repro.core.geometry import CHIPS, Geometry, analytic_cost_ns, native_config

N = 1 << 21  # 8 MiB of int32 per point (CPU-sized; paper used 4 GB on A100)


def tpu_model_ms(pattern: str, n: int, native: bool) -> float:
    """Modeled v5e kernel time: native geometry vs the fixed library config --
    the hardware-aware-scheduling differentiator the CPU wall clock cannot show."""
    spec = CHIPS["v5e"]
    g = native_config(pattern, spec) if native else Geometry(1, 8, 128)
    return analytic_cost_ns(pattern, g, n, 4, spec) * 1e-6


def main(quick: bool = False) -> list[str]:
    rng = np.random.default_rng(0)
    rows = []
    widths = [4, 13, 25] if quick else [1, 4, 8, 13, 17, 21, 25, 29, 32]
    for bw in widths:
        hi = 2**bw - 1 if bw < 32 else 2**31 - 1
        arr = rng.integers(0, hi, N, dtype=np.int64).astype(np.int32)
        enc = P.encode(P.Plan("bitpack", params={"bit_width": bw}), arr)
        bufs = device_buffers(enc)
        for label, backend in (("zipflow", "jnp"), ("baseline", "baseline")):
            dec = compile_decoder(enc, backend=backend)
            t = time_fn(dec, bufs)
            theo = modeled_tpu_throughput_gbps(enc.plain_nbytes,
                                               enc.compressed_nbytes)
            rows.append(row(
                f"fig12/bitpack_bw{bw}_{label}", t,
                f"cpu_gbps={gbps(enc.plain_nbytes, t):.2f};"
                f"ratio={enc.ratio:.2f};tpu_eq1_gbps={theo:.0f};"
                f"tpu_model_ms={tpu_model_ms('fp', N, label == 'zipflow'):.3f}"))
    return rows


if __name__ == "__main__":
    main()
