"""Fig. 20 (serving counterpart): multi-query decode under link contention.

The paper's figures optimize one query's compress->transfer->decode flow in
isolation; this benchmark measures the serving regime -- N concurrent requests
contending for ONE host->device link -- where the shared-resource planner
(``core/serve_planner.py``) composes per-query plans into one transfer queue
with cross-query signature batching and SLO-aware issue ordering.

Mixes (each a row):

  closed_mix -- closed loop: all requests submitted at t=0, one shared wave
      vs. the naive per-query FIFO server (one wave per request, submission
      order -- ``policy="fifo-per-query"``, ``max_wave=1``).  Reports measured
      wall/p50/p99/throughput for both, the DETERMINISTIC modeled makespans
      (``shared_mk`` <= ``naive_mk`` by construction: the naive composition is
      one of the shared planner's candidates), decode-launch counts and the
      launches removed by cross-request batching.
  open_loop  -- requests arrive in batches (open loop); each drain services
      the backlog as one wave.  Latency includes queueing delay.
  slo_mix    -- one bulk scan + point queries under ``policy="slo"`` vs the
      shared-throughput policy: point-class p99 (modeled, deterministic)
      must not degrade past the naive composition.

``--cost-cache PATH`` persists the run's calibrated ``CostModel`` (PR 5
``save``/``load``), so repeated bench runs plan from warm calibration.
"""
from __future__ import annotations

import os
import time

import numpy as np

from benchmarks.common import row
from repro.core import plan as P
from repro.core.costmodel import CostModel
from repro.core.executor import StreamingExecutor
from repro.core.serve_planner import ServePlanner
from repro.data.columns import TABLE2_PLANS
from repro.data.tpch import QUERY_COLUMNS, generate, scale_columns

SCALE_FACTOR_QUICK = 4
SCALE_FACTOR_FULL = 8


def _pct(vals, q):
    return float(np.percentile(np.asarray(vals, dtype=np.float64), q))


def _encode_request(cols, names):
    """Fresh Encoded blobs per request: distinct clients ship distinct buffers
    (same structure -> same signature -> cross-request batching candidates)."""
    return {n: P.encode(TABLE2_PLANS[n], cols[n]) for n in names}


def _executor(cost_model):
    return StreamingExecutor(chunk_bytes="auto", chunk_decode=True,
                             policy="adaptive", cost_model=cost_model)


def _bitwise_check(done):
    for req in done.values():
        for c, rec in req.results.items():
            np.testing.assert_array_equal(
                np.asarray(rec.array), P.decode_np(req.encs[c]),
                err_msg=f"{req.rid}/{c} serving decode")


def _drain_stats(planner, done):
    reqs = list(done.values())
    lat = [r.latency_s for r in reqs]
    reports = planner.reports
    return {
        "wall_s": sum(r.wall_s for r in reports),
        "p50": _pct(lat, 50), "p99": _pct(lat, 99),
        "launches": sum(r.decode_launches for r in reports),
        "cross_saved": sum(r.cross_batched_saved for r in reports),
        "shared_mk": sum(r.shared_makespan_s for r in reports),
        "naive_mk": sum(r.naive_makespan_s for r in reports),
        "plain_bytes": sum(rec.plain_bytes for r in reqs
                           for rec in r.results.values()),
    }


def main(quick: bool = False, cost_cache: str | None = None) -> list[str]:
    cols = generate(scale=0.002 if quick else 0.01, seed=0)
    cols = scale_columns(cols,
                         SCALE_FACTOR_QUICK if quick else SCALE_FACTOR_FULL,
                         [n for n in cols if n.startswith("L_")])
    cm = (CostModel.load(cost_cache)
          if cost_cache and os.path.exists(cost_cache) else CostModel())
    rows: list[str] = []

    # ---- closed loop: 6 requests at t=0, shared wave vs per-query FIFO ----
    mix = [QUERY_COLUMNS[1], QUERY_COLUMNS[6], QUERY_COLUMNS[13]] * 2
    reqs = [(f"r{i}", _encode_request(cols, names))
            for i, names in enumerate(mix)]

    shared = ServePlanner(_executor(cm), policy="shared")
    for rid, encs in reqs:
        shared.submit(rid, encs)
    shared.drain()                       # cold: traces + calibrates
    sh2 = ServePlanner(_executor(cm), policy="shared")
    for rid, encs in reqs:
        sh2.submit(rid, encs)
    t0 = time.perf_counter()
    done_s = sh2.drain()                 # warm shared wave
    _ = time.perf_counter() - t0
    _bitwise_check(done_s)
    s = _drain_stats(sh2, done_s)

    naive = ServePlanner(_executor(cm), policy="fifo-per-query", max_wave=1)
    for rid, encs in reqs:
        naive.submit(rid, encs)
    naive.drain()                        # cold
    nv2 = ServePlanner(_executor(cm), policy="fifo-per-query", max_wave=1)
    for rid, encs in reqs:
        nv2.submit(rid, encs)
    done_n = nv2.drain()                 # warm per-query FIFO
    _bitwise_check(done_n)
    n = _drain_stats(nv2, done_n)

    thr = s["plain_bytes"] / max(s["wall_s"], 1e-12) / 1e9
    thr_n = n["plain_bytes"] / max(n["wall_s"], 1e-12) / 1e9
    # modeled throughput from the deterministic makespans (CPU wall-clock is
    # noisy; shared_mk <= naive_mk is the regression-relevant invariant)
    thr_mk = s["plain_bytes"] / max(s["shared_mk"], 1e-12) / 1e9
    thr_mk_n = n["plain_bytes"] / max(s["naive_mk"], 1e-12) / 1e9
    hits = sh2.executor.cache.stats["hits"]
    rows.append(row(
        "fig20/closed_mix", s["wall_s"],
        f"shared={s['wall_s']:.4f}s;naive={n['wall_s']:.4f}s;"
        f"shared_mk={s['shared_mk']:.6f}s;naive_mk={s['naive_mk']:.6f}s;"
        f"modeled_throughput_gbps={thr_mk:.2f};"
        f"naive_modeled_throughput_gbps={thr_mk_n:.2f};"
        f"throughput_gbps={thr:.2f};naive_throughput_gbps={thr_n:.2f};"
        f"p50={s['p50']:.4f}s;p99={s['p99']:.4f}s;"
        f"naive_p50={n['p50']:.4f}s;naive_p99={n['p99']:.4f}s;"
        f"launches={s['launches']};naive_launches={n['launches']};"
        f"cross_batched_saved={s['cross_saved']};cache_hits={hits};"
        f"requests={len(reqs)}"))

    # ---- open loop: arrivals in batches, drain services the backlog ----
    ol = ServePlanner(_executor(cm), policy="shared")
    batches = [mix[:2], mix[2:4], mix[4:]]
    done_o: dict = {}
    t0 = time.perf_counter()
    for b, batch in enumerate(batches):
        for i, names in enumerate(batch):
            ol.submit(f"b{b}x{i}", _encode_request(cols, names))
        done_o.update(ol.drain())
    wall_o = time.perf_counter() - t0
    _bitwise_check(done_o)
    o = _drain_stats(ol, done_o)
    rows.append(row(
        "fig20/open_loop", wall_o,
        f"wall={wall_o:.4f}s;waves={len(ol.reports)};"
        f"shared_mk={o['shared_mk']:.6f}s;naive_mk={o['naive_mk']:.6f}s;"
        f"p50={o['p50']:.4f}s;p99={o['p99']:.4f}s;"
        f"launches={o['launches']};cross_batched_saved={o['cross_saved']};"
        f"requests={len(done_o)}"))

    # ---- open loop, background drain: the always-on drain loop services
    # arrivals with NO explicit drain() call on the submitting thread --
    # ``start()`` + ``submit()`` + ``req.wait()`` + ``stop()`` is the whole
    # client API.  Same batched arrivals as open_loop above, so the two rows
    # compare caller-driven vs engine-driven wave formation. ----
    od = ServePlanner(_executor(cm), policy="shared").start()
    try:
        t0 = time.perf_counter()
        od_reqs = []
        for b, batch in enumerate(batches):
            for i, names in enumerate(batch):
                od_reqs.append(od.submit(f"d{b}x{i}",
                                         _encode_request(cols, names)))
        for req in od_reqs:
            assert req.wait(timeout=600.0), f"{req.rid} never completed"
            if req.error is not None:
                raise req.error
        wall_d = time.perf_counter() - t0
    finally:
        od.stop()
    done_d = {r.rid: r for r in od_reqs}
    _bitwise_check(done_d)
    d = _drain_stats(od, done_d)
    rows.append(row(
        "fig20/open_loop_drain", wall_d,
        f"wall={wall_d:.4f}s;waves={len(od.reports)};"
        f"shared_mk={d['shared_mk']:.6f}s;"
        f"p50={d['p50']:.4f}s;p99={d['p99']:.4f}s;"
        f"launches={d['launches']};cross_batched_saved={d['cross_saved']};"
        f"requests={len(done_d)};background_drain=1"))

    # ---- SLO mix: bulk scan + point queries; point tail must not degrade ----
    bulk_names = QUERY_COLUMNS[1]
    point_names = ["O_ORDERKEY"]
    sl = ServePlanner(_executor(cm), policy="slo")
    sl.submit("bulk0", _encode_request(cols, bulk_names), klass="bulk")
    for i in range(3):
        sl.submit(f"pt{i}", _encode_request(cols, point_names), klass="point")
    done_slo = sl.drain()
    _bitwise_check(done_slo)
    rep = sl.reports[-1]
    pt_fin = [rep.modeled_finish_s[r] for r in rep.rids if r.startswith("pt")]
    pt_naive = [rep.naive_finish_s[r] for r in rep.rids if r.startswith("pt")]
    pt_meas = [done_slo[r].latency_s for r in done_slo if r.startswith("pt")]
    rows.append(row(
        "fig20/slo_mix", max(pt_meas),
        f"point_p99_mk={max(pt_fin):.6f}s;"
        f"point_p99_naive_mk={max(pt_naive):.6f}s;"
        f"point_p99={_pct(pt_meas, 99):.4f}s;"
        f"bulk_mk={rep.modeled_finish_s['bulk0']:.6f}s;"
        f"shared_mk={rep.shared_makespan_s:.6f}s;"
        f"naive_mk={rep.naive_makespan_s:.6f}s;"
        f"chosen={rep.chosen};preempted={rep.preempted}"))

    if cost_cache:
        cm.save(cost_cache)
        rows.append(row("fig20/cost_cache", 0.0,
                        f"path={cost_cache};n_observed={cm.n_observed};"
                        f"signatures={len(cm.sig_stats)}"))
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--cost-cache", default=None,
                    help="CostModel JSON path: load before, save after "
                         "(warm-starts calibration across runs)")
    args = ap.parse_args()
    main(quick=args.quick, cost_cache=args.cost_cache)
