"""Beyond-figure benchmarks for the framework integrations:
  * checkpoint shard compression (ZipFlow byte-plane ANS on bf16/f32 params);
  * cross-pod gradient wire-format reduction (int8 error-feedback psum);
  * compressed training-data loader ratio.
"""
from __future__ import annotations

import tempfile
import time

import jax
import numpy as np

from benchmarks.common import row
from repro.configs import SMOKES
from repro.data.loader import CompressedTokenLoader
from repro.models import get_model
from repro.train import checkpoint as ckpt
from repro.train.grad_compress import wire_bytes


def main(quick: bool = False) -> list[str]:
    rows = []
    cfg = SMOKES["qwen1.5-0.5b"]
    model = get_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    with tempfile.TemporaryDirectory() as d:
        t0 = time.perf_counter()
        ckpt.save(d, 1, params)
        t_save = time.perf_counter() - t0
        rep = ckpt.compression_report(d)
        t0 = time.perf_counter()
        ckpt.restore(d, params)
        t_restore = time.perf_counter() - t0
    rows.append(row("ckpt/compress", t_save,
                    f"ratio={rep['ratio']:.3f};restore_s={t_restore:.3f}"))
    rows.append(row("grad/wire_bytes", 0.0,
                    f"f32={wire_bytes(params, False)};"
                    f"int8={wire_bytes(params, True)};reduction=4.0x"))
    loader = CompressedTokenLoader(vocab=151_936, batch=8, seq_len=1024)
    loader.encode_host(0)
    rows.append(row("loader/token_ratio", 0.0,
                    f"ratio={loader.ratio:.2f};bits={loader.bits}"))
    return rows


if __name__ == "__main__":
    main()
