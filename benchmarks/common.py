"""Shared benchmark helpers.

All wall-clock numbers are CPU (this container has no TPU); each benchmark also
derives modeled-TPU quantities (bytes moved, roofline throughput) so the table
structure matches the paper's figures.  Output format: ``name,us_per_call,derived``.
"""
from __future__ import annotations

import time
from typing import Callable

import jax
import numpy as np


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall-clock seconds per call of a jitted function."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def row(name: str, seconds: float, derived: str = "") -> str:
    line = f"{name},{seconds * 1e6:.1f},{derived}"
    print(line, flush=True)
    return line


def gbps(nbytes: int, seconds: float) -> float:
    return nbytes / max(seconds, 1e-12) / 1e9


def modeled_tpu_throughput_gbps(plain_bytes: int, compressed_bytes: int,
                                hbm_gbps: float = 819.0) -> float:
    """Paper Eq. 1: GpuMemBandwidth * plain / (compressed + plain)."""
    return hbm_gbps * plain_bytes / (compressed_bytes + plain_bytes)
