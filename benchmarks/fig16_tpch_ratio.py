"""Paper Fig. 16 + Table 2: per-column compression ratios on TPC-H.

ZipFlow custom nestings (Table 2) vs:
  * "cascaded" -- nvCOMP-Cascaded role: best of {RLE, delta, bitpack} nestings only
    (no dictionary / Float2Int / String-dictionary support, per paper Table 1);
  * zstd -- general-purpose CPU baseline (the Parquet+zstd role).
"""
from __future__ import annotations

import numpy as np

try:
    import zstandard
except ImportError:           # zstd baseline column reports 1.0x when absent
    zstandard = None

from benchmarks.common import row
from repro.core import plan as P
from repro.data.columns import TABLE2_PLANS
from repro.data.tpch import generate

CASCADED = [
    P.make_plan("bitpack"),
    P.Plan("delta", children={"deltas": P.make_plan("bitpack")}),
    P.Plan("rle", children={"counts": P.make_plan("bitpack"),
                            "values": P.make_plan("bitpack")}),
    P.Plan("rle", children={
        "counts": P.make_plan("bitpack"),
        "values": P.Plan("delta", children={"deltas": P.make_plan("bitpack")})}),
]


def best_cascaded(arr: np.ndarray) -> float:
    best = 1.0
    for pl in CASCADED:
        try:
            best = max(best, P.encode(pl, arr).ratio)
        except (TypeError, ValueError):
            continue
    return best


def main(quick: bool = False) -> list[str]:
    cols = generate(scale=0.002 if quick else 0.01, seed=0)
    rows = []
    agg = {"zipflow": [0, 0], "cascaded": [0, 0], "zstd": [0, 0]}
    for name, pl in TABLE2_PLANS.items():
        arr = cols[name]
        enc = P.encode(pl, arr)
        if zstandard is not None:
            z = zstandard.ZstdCompressor(level=6).compress(
                np.ascontiguousarray(arr).tobytes())
        else:
            z = np.ascontiguousarray(arr).tobytes()
        r_zstd = arr.nbytes / max(len(z), 1)
        # the cascaded framework has no string/float support (paper Table 1):
        # such columns move uncompressed under that baseline
        r_casc = best_cascaded(arr) if arr.dtype.kind in "iu" \
            and arr.dtype != np.uint8 else 1.0
        agg["zipflow"][0] += enc.plain_nbytes
        agg["zipflow"][1] += enc.compressed_nbytes
        agg["cascaded"][0] += arr.nbytes
        agg["cascaded"][1] += arr.nbytes / max(r_casc, 1.0)
        agg["zstd"][0] += arr.nbytes
        agg["zstd"][1] += len(z)
        rows.append(row(
            f"fig16/{name}", 0.0,
            f"plan={pl.describe()};zipflow={enc.ratio:.2f};"
            f"cascaded={r_casc:.2f};zstd={r_zstd:.2f}"))
    for k, (p, c) in agg.items():
        rows.append(row(f"fig16/TOTAL_{k}", 0.0, f"ratio={p / max(c, 1):.2f}"))
    return rows


if __name__ == "__main__":
    main()
