"""Paper Fig. 18 + Eq. 2: kernel-fusion ablation on the three nested functions
(Float2Int+BP on L_EXTENDEDPRICE, Dictionary+BP on L_SHIPDATE, RLE+BP on
L_ORDERKEY).  Reports measured CPU speedup, stage counts, and the Eq.-2 modeled
HBM-traffic ratio.

The ``q6_operator_fusion`` row extends the ablation across the codec/operator
boundary: TPC-H Q6's scan-filter-aggregate grafted onto its four columns'
decode graphs (``core.query.lower_query``), comparing HBM traffic before
operator fusion (every decoded column and predicate mask round-trips HBM)
against the fused graph (leaf reads + partial-aggregate lanes only)."""
from __future__ import annotations

from benchmarks.common import row, time_fn
from repro.core import plan as P
from repro.core.compiler import compile_decoder, device_buffers
from repro.core.fusion import fuse, hbm_traffic_bytes
from repro.core.plan import lower
from repro.core.query import lower_query
from repro.data.columns import TABLE2_PLANS
from repro.data.queries import Q6_PLAN
from repro.data.tpch import QUERY_COLUMNS, generate

CASES = {"f2i+bp": "L_EXTENDEDPRICE", "dict+bp": "L_SHIPDATE",
         "rle+bp": "L_ORDERKEY"}


def main(quick: bool = False) -> list[str]:
    cols = generate(scale=0.002 if quick else 0.01, seed=0)
    rows = []
    for label, col in CASES.items():
        enc = P.encode(TABLE2_PLANS[col], cols[col])
        bufs = device_buffers(enc)
        dec_f = compile_decoder(enc, fuse=True)
        dec_u = compile_decoder(enc, fuse=False)
        t_f = time_fn(dec_f, bufs, iters=3)
        t_u = time_fn(dec_u, bufs, iters=3)
        unfused = lower(enc)
        traffic_ratio = hbm_traffic_bytes(unfused, bufs) / \
            max(hbm_traffic_bytes(fuse(list(unfused)), bufs), 1)
        rows.append(row(
            f"fig18/{label}", t_f,
            f"speedup={t_u / t_f:.2f};kernels={dec_u.n_kernels}->"
            f"{dec_f.n_kernels};eq2_traffic_ratio={traffic_ratio:.2f}"))
    # codec x operator fusion (Q6 grafted onto its columns' decode graphs):
    # before/after HBM-traffic delta of the whole fused-query stage list
    encs = {n: P.encode(TABLE2_PLANS[n], cols[n]) for n in QUERY_COLUMNS[6]}
    fq = lower_query(Q6_PLAN, encs)
    pre = hbm_traffic_bytes(fq.prefuse_stages, fq.operands)
    post = hbm_traffic_bytes(fq.graph.stages, fq.operands)
    plain = sum(e.plain_nbytes for e in encs.values())
    rows.append(row(
        "fig18/q6_operator_fusion", 0.0,
        f"traffic_before={pre};traffic_after={post};"
        f"ratio={pre / max(post, 1):.2f};"
        f"stages={len(fq.prefuse_stages)}->{len(fq.graph.stages)};"
        f"decoded_bytes_never_written={plain}"))
    return rows


if __name__ == "__main__":
    main()
