"""Paper Fig. 19/20/21: end-to-end TPC-H query latency.

Per query: move the query's columns host->device and decompress, then run query
processing (a JAX mini-engine executes Q1 and Q6 fully; other queries report the
data-movement phase, the paper's dominant term -- 91.3% of noCOMP latency).

Configurations (paper Fig. 20 labels):
  noCOMP -- raw column transfer;
  N      -- cascaded-only compression, no fusion, fixed geometry (nvCOMP role);
  C      -- ZipFlow compression, no transfer/decode pipelining;
  Z      -- full ZipFlow incl. Johnson-ordered pipelining;
  Zc     -- Z modeled with chunk-level jobs: the chunk-granular decoder's
            makespan when transfer/decode overlap *within* a column;
  Zc_run -- MEASURED wall-clock of the PLANNED per-chunk executor: the holistic
            planner (``policy="adaptive"``, ``chunk_bytes="auto"``) chooses each
            column's chunk size, decode mode and the issue order by minimizing
            modeled makespan over the cost model's calibrated timings; every
            transferred chunk of a chunk-decoded column runs in its own launch
            while later chunks are in flight.  Group-chunkable columns (RLE
            expansions, ANS chunk grids -- CHUNK_GROUP) now take a MEASURED
            group-boundary streaming path too (previously model-only): the row
            reports ``gp_cols`` (group-chunkable columns present) and
            ``gp_chunk_cols`` (those the plan streamed per group span).  The
            chunked output is asserted bitwise-equal to ``plan.decode_np``
            before it is timed, alongside Z_run (measured whole-column
            wall-clock) for an apples-to-apples pair.  The row also reports the
            planner's PLANNED makespan next to the measured one, and the
            planner's simulated baselines (FIFO / whole-column Johnson) so
            planned <= min(baselines) is visible.

The pipeline runs on the streaming executor; C/Z/Zc makespans reuse the one set of
timings measured by ``run`` (no per-config re-measurement); Zc_run/Z_run are warm
second runs of each executor.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row
from repro.core import plan as P, scheduler
from repro.core.compiler import compile_decoder, device_buffers
from repro.core.ir import CHUNK_GROUP
from repro.data.columns import TABLE2_PLANS
from repro.data.loader import ColumnPipeline
from repro.data.tpch import QUERY_COLUMNS, generate, scale_columns
from benchmarks.fig16_tpch_ratio import CASCADED


from repro.data.queries import ENGINES, QUERY_PLANS, q1_engine, q6_engine  # noqa: E402

# lineitem scale-up factors toward SF>=1 row counts (``tpch.scale_columns``
# tiles the generated distributions; only the L_* columns scale, so the
# ANS-heavy O_COMMENT text column does not blow up the unrelated queries)
SCALE_FACTOR_QUICK = 24      # 0.002 base -> ~290k lineitem rows
SCALE_FACTOR_FULL = 4        # 0.01 base  -> ~240k lineitem rows, 22 queries

# queries executed decode-fused (operators grafted onto the decode graphs)
FUSED_QUERIES = (1, 6)


def best_cascaded_plan(arr):
    best, br = None, 0.0
    for pl in CASCADED:
        if arr.dtype.kind not in "iu" or arr.dtype == np.uint8:
            continue
        try:
            r = P.encode(pl, arr).ratio
        except (TypeError, ValueError):
            continue
        if r > br:
            best, br = pl, r
    return best


def _move_raw(cols):
    t0 = time.perf_counter()
    out = {k: jax.device_put(v) for k, v in cols.items()}
    jax.block_until_ready(list(out.values()))
    return out, time.perf_counter() - t0


def main(quick: bool = False) -> list[str]:
    cols = generate(scale=0.002 if quick else 0.01, seed=0)
    cols = scale_columns(cols,
                         SCALE_FACTOR_QUICK if quick else SCALE_FACTOR_FULL,
                         [n for n in cols if n.startswith("L_")])
    rows = []
    queries = [1, 6, 13] if quick else sorted(QUERY_COLUMNS)
    speedups = []
    gp_total = gp_chunked_total = 0
    gp_time_s = 0.0           # measured (transfer+decode) over GP/NP columns
    for q in queries:
        names = QUERY_COLUMNS[q]
        qcols = {n: cols[n] for n in names}
        # --- noCOMP ---
        moved, t_raw = _move_raw(qcols)
        # --- N: cascaded-only, unfused ---
        t_casc = 0.0
        for n, arr in qcols.items():
            pl = best_cascaded_plan(arr)
            if pl is None:
                _, dt = _move_raw({n: arr})
                t_casc += dt
                continue
            enc = P.encode(pl, arr)
            dec = compile_decoder(enc, backend="baseline")
            t0 = time.perf_counter()
            bufs = device_buffers(enc)
            jax.block_until_ready(list(bufs.values()))
            jax.block_until_ready(dec(bufs))
            t_casc += time.perf_counter() - t0
        # --- C / Z / Zc: ZipFlow without / with pipelining, whole-column / chunked ---
        chunk_bytes = 1 << 14 if quick else 1 << 18
        pipe = ColumnPipeline({n: TABLE2_PLANS[n] for n in names},
                              chunk_bytes=chunk_bytes)
        pipe.compress(qcols)
        pipe.run()      # one real streaming run populates the timing cache
        t_c = pipe.modeled_makespan(pipeline=False)
        t_z = pipe.modeled_makespan(pipeline=True, johnson=True)
        t_zc = pipe.modeled_makespan(pipeline=True, johnson=True, chunked=True)
        t0 = time.perf_counter()
        pipe.run()      # warm whole-column wall-clock (Z_run)
        t_z_run = time.perf_counter() - t0
        # --- Zc measured: planner-chosen per-column chunks + decode modes ---
        pipe_zc = ColumnPipeline({n: TABLE2_PLANS[n] for n in names},
                                 chunk_bytes="auto", chunk_decode=True,
                                 policy="adaptive")
        pipe_zc.compress(qcols)
        res_zc = pipe_zc.run()          # cold run traces + calibrates cost model
        for n in names:                 # bitwise guard: chunked == oracle
            np.testing.assert_array_equal(
                np.asarray(res_zc[n].array), P.decode_np(pipe_zc._encoded[n]),
                err_msg=f"q{q}/{n} chunk-decode")
        ep = pipe_zc.plan()             # re-plan from measured timings
        pipe_zc.run(plan=ep)            # trace any newly-chosen chunk programs
        t0 = time.perf_counter()
        res_zc = pipe_zc.run(plan=ep)   # warm planned wall-clock (Zc_run)
        t_zc_run = time.perf_counter() - t0
        t_planned = ep.modeled_makespan_s
        chunked_cols = sum(r.chunk_decoded for r in res_zc.values())
        launches = sum(r.decode_launches for r in res_zc.values())
        auto_sizes = sorted({(d.chunk_bytes or 0) >> 10
                             for d in ep.decisions.values()})
        # group-chunkable (GP/NP) columns: previously model-only, now measured
        gp_cols = [n for n in names
                   if pipe_zc.executor.graph(n).chunkability == CHUNK_GROUP]
        gp_chunk_cols = [n for n in gp_cols if res_zc[n].chunk_decoded]
        gp_total += len(gp_cols)
        gp_chunked_total += len(gp_chunk_cols)
        gp_time_s += sum(res_zc[n].transfer_s + res_zc[n].decode_s
                         for n in gp_cols)
        # --- query execution phase (engine, identical across configs) ---
        t_engine = 0.0
        if q in ENGINES:
            eng = jax.jit(ENGINES[q])
            jax.block_until_ready(eng(
                {k: jnp.asarray(v) for k, v in qcols.items()}))
            t0 = time.perf_counter()
            jax.block_until_ready(eng(
                {k: jnp.asarray(v) for k, v in qcols.items()}))
            t_engine = time.perf_counter() - t0
        # --- decode-fused query execution (late materialization): the query's
        # operators ride the per-chunk decode launches; only partial-aggregate
        # lanes reach HBM.  Compared against materialize-then-query on the SAME
        # warm planned pipeline (transfer+decode+engine), both best-of-3. ---
        fused_fields = ""
        if q in FUSED_QUERIES:
            qp = QUERY_PLANS[q]
            ep_q = pipe_zc.query_plan(qp)   # fused-vs-materialize per column
            qe = pipe_zc.run_query(qp)      # cold: traces the chunk programs
            ref = eng({k: jnp.asarray(v) for k, v in qcols.items()})
            np.testing.assert_allclose(np.asarray(qe.result), np.asarray(ref),
                                       rtol=1e-4, err_msg=f"q{q} fused")
            # interleave the two timed paths (best-of-5 each) so slow drift on
            # a noisy host hits both equally
            tf, tm = [], []
            for _ in range(5):
                t0 = time.perf_counter()
                qe = pipe_zc.run_query(qp)
                tf.append(time.perf_counter() - t0)
                t0 = time.perf_counter()
                res_m = pipe_zc.run(plan=ep)
                jax.block_until_ready(eng({n: res_m[n].array for n in names}))
                tm.append(time.perf_counter() - t0)
            t_fused, t_mat = min(tf), min(tm)
            n_fused = sum(d.fused for d in ep_q.decisions.values())
            fused_fields = (
                f";fused={t_fused:.4f}s;materialized={t_mat:.4f}s;"
                f"fused_sel={qe.selectivity:.4f};"
                f"fused_cols={n_fused}/{len(names)}")
            rows.append(row(
                f"fig19/fused_q{q}", t_fused,
                f"fused={t_fused:.4f}s;materialized={t_mat:.4f}s;"
                f"sel={qe.selectivity:.4f};chunks={qe.n_chunks};"
                f"launches={qe.decode_launches};"
                f"traffic={qe.traffic_bytes};"
                f"prefuse_traffic={qe.prefuse_traffic_bytes};"
                f"never_materialized={qe.plain_bytes}"))
        total_z = t_z + t_engine
        total_n = t_casc + t_engine
        speedups.append(total_n / max(total_z, 1e-9))
        rows.append(row(
            f"fig19/q{q}", total_z,
            f"noCOMP={t_raw + t_engine:.4f}s;N={total_n:.4f}s;"
            f"C={t_c + t_engine:.4f}s;Z={total_z:.4f}s;"
            f"Zc={t_zc + t_engine:.4f}s;"
            f"Z_run={t_z_run + t_engine:.4f}s;"
            f"Zc_run={t_zc_run + t_engine:.4f}s;"
            f"planned={t_planned:.4f}s;measured={t_zc_run:.4f}s;"
            f"plan_fifo={ep.baselines['fifo']:.4f}s;"
            f"plan_johnson={ep.baselines['johnson']:.4f}s;"
            f"auto_chunk_kib={'/'.join(str(s) for s in auto_sizes)};"
            f"chunk_cols={chunked_cols}/{len(names)};launches={launches};"
            f"gp_cols={len(gp_cols)};gp_chunk_cols={len(gp_chunk_cols)};"
            f"engine={t_engine:.4f}s;zipflow_vs_cascaded={speedups[-1]:.2f}x"
            + fused_fields))
    rows.append(row("fig19/MEAN_speedup_vs_cascaded", 0.0,
                    f"x{float(np.mean(speedups)):.2f}"))
    # --- async dispatch engine: worker-thread issuance vs inline puts on the
    # SAME warm plan (Q1's whole/chunked column mix).  The two timed modes
    # interleave (best-of-5 each) so host noise hits both equally; on a
    # single-core host the worker cannot beat inline puts, so the guard in
    # bench_smoke.sh is "no regression", not "speedup".  Output asserted
    # bitwise against the oracle decode before timing. ---
    names_a = QUERY_COLUMNS[1]
    qcols_a = {n: cols[n] for n in names_a}
    pipe_a = ColumnPipeline({n: TABLE2_PLANS[n] for n in names_a},
                            chunk_bytes="auto", chunk_decode=True,
                            policy="adaptive")
    pipe_a.compress(qcols_a)
    pipe_a.run()                      # cold: trace + calibrate
    ep_a = pipe_a.plan()
    pipe_a.executor.run(pipe_a._encoded, plan=ep_a)   # warm sequential
    res_a = pipe_a.executor.run(pipe_a._encoded, plan=ep_a,
                                async_dispatch=True)  # warm async
    for n in names_a:
        np.testing.assert_array_equal(
            np.asarray(res_a[n].array), P.decode_np(pipe_a._encoded[n]),
            err_msg=f"async_overlap/{n}")
    t_seq, t_async = [], []
    for _ in range(5):
        t0 = time.perf_counter()
        pipe_a.executor.run(pipe_a._encoded, plan=ep_a, async_dispatch=False)
        t_seq.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        pipe_a.executor.run(pipe_a._encoded, plan=ep_a, async_dispatch=True)
        t_async.append(time.perf_counter() - t0)
    rows.append(row(
        "fig19/async_overlap", min(t_async),
        f"async={min(t_async):.4f}s;sequential={min(t_seq):.4f}s;"
        f"bit_exact=1;cols={len(names_a)}"))
    # GP-column Zc_run: the measured planned path over Group-Parallel /
    # Non-Parallel columns, summed across queries (model-only before the
    # group-boundary chunked decoder existed)
    rows.append(row("fig19/gp_columns", gp_time_s,
                    f"Zc_run={gp_time_s:.4f}s;gp_cols={gp_total};"
                    f"gp_chunk_cols={gp_chunked_total}"))
    return rows


if __name__ == "__main__":
    main()
