"""§Roofline table generator: reads the dry-run JSON records and emits the per-cell
three-term roofline rows (also used to refresh EXPERIMENTS.md)."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import row


def load_records(out_dir: str = "experiments/dryrun") -> list[dict]:
    recs = []
    for f in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        recs.append(json.load(open(f)))
    return recs


def main(quick: bool = False) -> list[str]:
    rows = []
    recs = load_records()
    if not recs:
        rows.append(row("roofline/NO_DRYRUN_DATA", 0.0,
                        "run: python -m repro.launch.dryrun --all --mesh both"))
        return rows
    for r in recs:
        tag = f"{r['arch']}/{r['shape']}/{'mp' if 'multi' in r.get('mesh', '') else 'sp'}"
        if r.get("status") != "ok":
            rows.append(row(f"roofline/{tag}", 0.0,
                            f"status={str(r.get('status'))[:60]}"))
            continue
        rf = r["roofline"]
        rows.append(row(
            f"roofline/{tag}", rf["step_time"],
            f"bottleneck={rf['bottleneck']};t_c={rf['t_compute'] * 1e3:.1f}ms;"
            f"t_m={rf['t_memory'] * 1e3:.1f}ms;"
            f"t_coll={rf['t_collective'] * 1e3:.1f}ms;"
            f"useful_flops={rf['useful_flops_frac'] * 100:.0f}%;"
            f"bw_frac={rf.get('bw_frac', 0) * 100:.0f}%;"
            f"roofline_frac={rf['roofline_frac'] * 100:.2f}%;"
            f"mem_gib={r['memory']['per_device_live'] / 2**30:.1f};"
            f"fits={r['memory']['fits_16g_hbm']}"))
    return rows


if __name__ == "__main__":
    main()
