"""Paper Fig. 22 + Table 3: native-vs-shared geometry configs across 4 chips, and
the cost of finding them (brute force vs monotonicity-pruned search)."""
from __future__ import annotations

from benchmarks.common import row
from repro.core.autotune import analytic_measure, brute_force, pruned_search
from repro.core.geometry import CHIPS, analytic_cost_ns, native_config


def main(quick: bool = False) -> list[str]:
    rows = []
    chips = ["v5e", "v6e"] if quick else ["v4", "v5e", "v5p", "v6e"]
    patterns = ["fp", "gp"] if quick else ["fp", "gp", "np"]
    # Fig 22: shared-config degradation matrix
    for pattern in patterns:
        for target in chips:
            native = native_config(pattern, CHIPS[target])
            c_nat = analytic_cost_ns(pattern, native, 1 << 24, 4, CHIPS[target])
            worst = 1.0
            for src in chips:
                if src == target:
                    continue
                shared = native_config(pattern, CHIPS[src])
                c_sh = analytic_cost_ns(pattern, shared, 1 << 24, 4,
                                        CHIPS[target])
                worst = max(worst, c_sh / c_nat)
            rows.append(row(f"fig22/{pattern}_{target}", c_nat * 1e-9,
                            f"native={native};worst_shared_degradation="
                            f"{(worst - 1) * 100:.1f}%"))
    # Table 3: search cost
    for pattern in patterns:
        spec = CHIPS["v5e"]
        measure = analytic_measure(pattern, spec)
        bf = brute_force(pattern, spec, measure)
        pr = pruned_search(pattern, spec, measure)
        rows.append(row(
            f"table3/{pattern}_search", 0.0,
            f"bruteforce_probes={bf.probes};pruned_probes={pr.probes};"
            f"same_optimum={pr.cost <= bf.cost * 1.001}"))
    return rows


if __name__ == "__main__":
    main()
