"""Paper Fig. 14: ANS (Non-Parallel) throughput vs compression ratio (left) and vs
frequency skew (right).  ZipFlow's lockstep decode does constant work per symbol, so
throughput tracks the ratio and ignores skew."""
from __future__ import annotations

import numpy as np

from benchmarks.common import gbps, row, time_fn
from repro.core import plan as P
from repro.core.compiler import compile_decoder, device_buffers

N = 1 << 21


def main(quick: bool = False) -> list[str]:
    rng = np.random.default_rng(2)
    rows = []
    # left: sweep alphabet size -> compression ratio
    alphabet = [2, 16] if quick else [2, 4, 16, 64, 192]
    for a in alphabet:
        arr = rng.integers(0, a, N).astype(np.uint8)
        enc = P.encode(P.Plan("ans", params={"chunk_size": 4096}), arr)
        dec = compile_decoder(enc)
        t = time_fn(dec, device_buffers(enc))
        rows.append(row(f"fig14/ans_alpha{a}", t,
                        f"cpu_gbps={gbps(N, t):.3f};ratio={enc.ratio:.2f}"))
    # right: fixed alphabet, sweep skew
    skews = [0.34, 0.95] if quick else [0.34, 0.6, 0.8, 0.95]
    for s in skews:
        arr = rng.choice(np.arange(3, dtype=np.uint8) + 65, N,
                         p=[s, (1 - s) / 2, (1 - s) / 2]).astype(np.uint8)
        enc = P.encode(P.Plan("ans", params={"chunk_size": 4096}), arr)
        dec = compile_decoder(enc)
        t = time_fn(dec, device_buffers(enc))
        rows.append(row(f"fig14/ans_skew{int(s * 100)}", t,
                        f"cpu_gbps={gbps(N, t):.3f};ratio={enc.ratio:.2f}"))
    return rows


if __name__ == "__main__":
    main()
