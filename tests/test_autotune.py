"""Geometry autotuning (paper §5.5 / Table 3): the pruned ('R.L.') search matches
brute force on the analytic landscape at a fraction of the probes, and Native
Configs beat Shared Configs across chips (Fig. 22)."""
import pytest

from repro.core.autotune import analytic_measure, brute_force, pruned_search
from repro.core.geometry import CHIPS, analytic_cost_ns, native_config


@pytest.mark.parametrize("pattern", ["fp", "gp", "np"])
@pytest.mark.parametrize("chip", ["v5e", "v4", "v6e"])
def test_pruned_matches_brute_force(pattern, chip):
    spec = CHIPS[chip]
    measure = analytic_measure(pattern, spec)
    bf = brute_force(pattern, spec, measure)
    pr = pruned_search(pattern, spec, measure)
    assert pr.cost <= bf.cost * 1.001, (pr.best, bf.best)


@pytest.mark.parametrize("pattern", ["fp", "gp", "np"])
def test_pruned_probe_budget(pattern):
    """Paper Table 3: pruned search lands in the ~10-probe regime while brute
    force explores the whole space."""
    spec = CHIPS["v5e"]
    measure = analytic_measure(pattern, spec)
    bf = brute_force(pattern, spec, measure)
    pr = pruned_search(pattern, spec, measure)
    assert pr.probes < bf.probes
    assert pr.probes <= 25, pr.probes


def test_native_vs_shared_config():
    """A config tuned for one chip underperforms on another (paper Fig. 22)."""
    degradations = []
    for pattern in ("fp", "gp"):
        for a in ("v5e", "v4", "v6e"):
            native = native_config(pattern, CHIPS[a])
            cost_native = analytic_cost_ns(pattern, native, 1 << 24, 4, CHIPS[a])
            for b in ("v5e", "v4", "v6e"):
                if a == b:
                    continue
                shared = native_config(pattern, CHIPS[b])
                cost_shared = analytic_cost_ns(pattern, shared, 1 << 24, 4,
                                               CHIPS[a])
                degradations.append(cost_shared / cost_native)
    assert all(d >= 1.0 - 1e-9 for d in degradations)
    assert max(degradations) > 1.005, "chips too similar to matter"
