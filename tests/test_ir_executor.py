"""Decode-graph IR + streaming executor: structural signatures and ProgramCache
sharing, chunked/batched decode bitwise-equality against the numpy oracle, and
chunk-level Johnson scheduling."""
import numpy as np
import pytest

from repro.core import plan as P, scheduler
from repro.core.compiler import ProgramCache, compile_blob
from repro.core.executor import StreamingExecutor, split_chunks
from repro.core.fusion import fuse_graph
from repro.data.columns import TABLE2_PLANS
from repro.data.tpch import QUERY_COLUMNS, generate


def _dict_bp():
    return P.Plan("dictionary", children={"index": P.make_plan("bitpack")})


# ------------------------------------------------------------------- signatures

def test_structural_signature_equality():
    rng = np.random.default_rng(0)
    a = rng.integers(100, 612, 50_000).astype(np.int32)
    b = rng.permutation(a)            # same structure, different values
    ga = P.lower_graph(P.encode(_dict_bp(), a))
    gb = P.lower_graph(P.encode(_dict_bp(), b))
    assert ga.signature == gb.signature
    # a different plan over the same data must not collide
    gc = P.lower_graph(P.encode(P.make_plan("bitpack"), a))
    assert gc.signature != ga.signature
    # a different length is a different structure (different jit shapes)
    gd = P.lower_graph(P.encode(_dict_bp(), a[:-1]))
    assert gd.signature != ga.signature


def test_signature_lifts_data_dependent_meta():
    # bit width / base are runtime OPERANDS, not program identity: blobs of the
    # same shape with different value ranges share a signature (and a program)
    a = np.arange(0, 4096, dtype=np.int32)
    b = a + 100_000          # same shape+dtype, different base (same bit width)
    ga = P.lower_graph(P.encode(P.make_plan("bitpack"), a))
    gb = P.lower_graph(P.encode(P.make_plan("bitpack"), b))
    assert ga.signature == gb.signature
    assert {m.name for m in ga.meta_specs} == {"root.@bit_width", "root.@base"}
    # structural meta still separates: a different length is a different program
    gc = P.lower_graph(P.encode(P.make_plan("bitpack"), a[:-33]))
    assert gc.signature != ga.signature


def test_fuse_graph_rewrites_and_retags():
    enc = P.encode(_dict_bp(), np.arange(10_000, dtype=np.int32))
    g = P.lower_graph(enc)
    fg = fuse_graph(g)
    assert fg.fused and not g.fused
    assert len(fg.stages) <= len(g.stages)
    assert fg.signature != g.signature            # fused/unfused never share a slot
    assert fg.out == g.out and fg.buffers == g.buffers


def test_graph_buffer_defs_match_flat_buffers():
    enc = P.encode(TABLE2_PLANS["L_ORDERKEY"],
                   np.repeat(np.arange(500, dtype=np.int64), 4).astype(np.int64))
    g = P.lower_graph(enc)
    flat = P.flat_buffers(enc)
    assert set(g.buffer_names()) == set(flat)
    for bd in g.buffers:
        assert bd.shape == tuple(flat[bd.name].shape)
        assert bd.nbytes == flat[bd.name].nbytes
    assert g.compressed_nbytes == enc.compressed_nbytes


# ----------------------------------------------------------------- ProgramCache

def test_n_identical_columns_compile_once():
    rng = np.random.default_rng(1)
    base = rng.integers(0, 999, 20_000).astype(np.int32)
    cols = {f"c{i}": rng.permutation(base) for i in range(5)}
    cache = ProgramCache()
    progs = {n: compile_blob(P.encode(_dict_bp(), arr), cache=cache)
             for n, arr in cols.items()}
    assert len(cache) == 1, "5 structurally identical columns -> 1 cached program"
    assert cache.stats == {"programs": 1, "hits": 4, "misses": 1, "evictions": 0}
    assert len({id(p) for p in progs.values()}) == 1


def test_cache_keys_compile_options():
    enc = P.encode(P.make_plan("bitpack"), np.arange(4096, dtype=np.int32))
    cache = ProgramCache()
    p1 = compile_blob(enc, backend="jnp", fuse=True, cache=cache)
    p2 = compile_blob(enc, backend="jnp", fuse=False, cache=cache)
    p3 = compile_blob(enc, backend="baseline", cache=cache)
    assert len({id(p1), id(p2), id(p3)}) == 3


# ------------------------------------------------------- chunked streaming decode

def test_split_chunks_roundtrip():
    rng = np.random.default_rng(2)
    for shape in [(1,), (100,), (10_000,), (65, 33)]:
        arr = rng.integers(0, 255, shape).astype(np.uint8)
        pieces = split_chunks(arr, 256)
        assert all(p.nbytes <= max(256, arr.nbytes // max(1, arr.shape[0]))
                   for p in pieces)
        np.testing.assert_array_equal(np.concatenate(pieces, axis=0)
                                      if len(pieces) > 1 else pieces[0], arr)


@pytest.mark.parametrize("chunk_bytes", [None, 4096])
def test_chunked_decode_bitwise_equals_oracle(chunk_bytes):
    """Every Q1 codec nesting: chunked streaming decode == plan.decode_np."""
    cols = generate(scale=0.002, seed=7)
    names = QUERY_COLUMNS[1]
    encs = {n: P.encode(TABLE2_PLANS[n], cols[n]) for n in names}
    ex = StreamingExecutor(chunk_bytes=chunk_bytes, cache=ProgramCache())
    results = ex.run(encs)
    for n in names:
        got = np.asarray(results[n].array)
        np.testing.assert_array_equal(got, P.decode_np(encs[n]), err_msg=n)
        np.testing.assert_array_equal(got, cols[n], err_msg=n)
        if chunk_bytes is not None:
            # reported chunk count == pieces the transfer actually issues
            expected = sum(len(split_chunks(np.asarray(v), chunk_bytes))
                           for v in P.flat_buffers(encs[n]).values())
            assert results[n].n_chunks == expected >= 1


def test_batched_decode_matches_single():
    rng = np.random.default_rng(3)
    base = rng.integers(0, 500, 30_000).astype(np.int32)
    cols = {f"c{i}": rng.permutation(base) for i in range(3)}
    encs = {n: P.encode(_dict_bp(), arr) for n, arr in cols.items()}
    cache = ProgramCache()
    ex = StreamingExecutor(chunk_bytes=8192, batch_columns=True, cache=cache)
    results = ex.run(encs)
    assert len(cache) == 1
    for n, arr in cols.items():
        np.testing.assert_array_equal(np.asarray(results[n].array), arr)
        assert len(results[n].batched_with) == 2     # one launch for all three
    # executor timings populated for makespan reuse
    assert set(ex.timings) == set(cols)


# --------------------------------------------------------- chunk-level scheduling

def test_chunk_jobs_split_and_naming():
    jobs = [scheduler.Job("a", 4.0, 1.0), scheduler.Job("b", 1.0, 4.0)]
    cjobs = scheduler.chunk_jobs(jobs, [4, 2])
    assert len(cjobs) == 6
    assert cjobs[0].name == "a#0" and scheduler.column_of(cjobs[0].name) == "a"
    assert abs(sum(j.transfer_s for j in cjobs) - 5.0) < 1e-12
    assert abs(sum(j.decompress_s for j in cjobs) - 5.0) < 1e-12
    assert scheduler.column_order([j.name for j in cjobs]) == ["a", "b"]


def test_chunk_level_johnson_beats_fifo():
    # transfer-heavy column submitted first: FIFO stalls the device behind the link
    jobs = [scheduler.Job("big_xfer", 4.0, 1.0), scheduler.Job("big_dec", 1.0, 4.0)]
    cjobs = scheduler.chunk_jobs(jobs, [8, 8])
    mk_fifo = scheduler.makespan(cjobs, scheduler.fifo_order(cjobs))
    mk_johnson = scheduler.makespan(cjobs, scheduler.johnson_order(cjobs))
    assert mk_johnson < mk_fifo
    # finer-grained jobs can only improve the Johnson makespan (more overlap)
    mk_whole = scheduler.makespan(jobs, scheduler.johnson_order(jobs))
    assert mk_johnson <= mk_whole + 1e-12
    # and the Johnson chunk order keeps each column's chunks contiguous
    order = scheduler.johnson_order(cjobs)
    cols_seen = scheduler.column_order([cjobs[i].name for i in order])
    assert cols_seen == ["big_dec", "big_xfer"]


def test_executor_issue_order_prefers_decode_heavy_first():
    # synthetic timings: make one column clearly transfer-bound, one decode-bound
    rng = np.random.default_rng(4)
    a = rng.integers(0, 9, 40_000).astype(np.int32)       # small alphabet
    b = rng.integers(0, 1 << 20, 40_000).astype(np.int32)
    ex = StreamingExecutor(chunk_bytes=4096, cache=ProgramCache())
    ex.compile("a", P.encode(P.make_plan("bitpack"), a))
    ex.compile("b", P.encode(P.make_plan("bitpack"), b))
    ex.timings["a"] = (0.001, 0.010)    # decode-heavy -> should go first
    ex.timings["b"] = (0.010, 0.001)
    assert ex.issue_order(["b", "a"]) == ["a", "b"]


# ------------------------------------------------------------- pipeline client

def test_column_pipeline_measures_each_column_once():
    from repro.data.loader import ColumnPipeline

    cols = generate(scale=0.002, seed=9)
    names = QUERY_COLUMNS[6]
    pipe = ColumnPipeline({n: TABLE2_PLANS[n] for n in names}, chunk_bytes=16384)
    pipe.compress({n: cols[n] for n in names})
    pipe.run()                                   # populates the timing cache
    est_a = {n: pipe._measure(n) for n in names}
    est_b = {n: pipe._measure(n) for n in names}
    assert est_a == est_b, "measurements must be cached, not re-taken"
    # all three makespan configs come from the same cached measurement set
    mk_serial = pipe.modeled_makespan(pipeline=False)
    mk_j = pipe.modeled_makespan(pipeline=True, johnson=True)
    mk_jc = pipe.modeled_makespan(pipeline=True, johnson=True, chunked=True)
    assert mk_jc <= mk_j + 1e-9 <= mk_serial + 1e-9


def test_recompress_invalidates_cached_timings():
    from repro.data.loader import ColumnPipeline

    rng = np.random.default_rng(11)
    pipe = ColumnPipeline({"a": P.make_plan("bitpack")}, chunk_bytes=4096)
    pipe.compress({"a": rng.integers(0, 100, 1_000).astype(np.int32)})
    pipe.run()
    assert "a" in pipe._timings
    big = rng.integers(0, 100, 500_000).astype(np.int32)
    pipe.compress({"a": big})        # new data under the same name
    assert "a" not in pipe._timings, "stale measurement must not schedule new data"
    assert "a" not in pipe.executor.timings
    np.testing.assert_array_equal(np.asarray(pipe.run()["a"].array), big)
