"""Fusion pass (paper §3.2/Fig. 7(c), §5.3.3): semantic equivalence, kernel-count
reduction, and the Eq.-2 memory-traffic model."""
import numpy as np

from repro.core import plan as P
from repro.core.compiler import compile_decoder, device_buffers
from repro.core.fusion import fuse, hbm_traffic_bytes
from repro.core.plan import lower

mp = P.make_plan


def _mk(pl, arr):
    enc = P.encode(pl, arr)
    return enc, device_buffers(enc)


def test_fp_fp_chain_collapses(rng):
    arr = rng.choice([2, 5, 9], 2000).astype(np.int32)
    enc, bufs = _mk(P.Plan("dictionary", children={"index": mp("bitpack")}), arr)
    unfused = lower(enc)
    fused = fuse(list(unfused))
    assert len(unfused) == 2 and len(fused) == 1
    a = compile_decoder(enc, fuse=False)(bufs)
    b = compile_decoder(enc, fuse=True)(bufs)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_fp_absorbed_into_gp_values(rng):
    """bit-packed RLE values decode inside the Group-Parallel kernel."""
    counts = rng.integers(1, 50, 200)
    values = rng.integers(0, 500, 200).astype(np.int32)
    arr = np.repeat(values, counts).astype(np.int32)
    enc, bufs = _mk(P.Plan("rle", children={"counts": mp("bitpack"),
                                            "values": mp("bitpack")}), arr)
    unfused = lower(enc)
    fused = fuse(list(unfused))
    # bitpack(values) absorbed; bitpack(counts) inlined into the presum Aux
    assert len(fused) == len(unfused) - 2
    names = [s.name for s in fused]
    assert any(">" in n for n in names), names
    np.testing.assert_array_equal(
        np.asarray(compile_decoder(enc, fuse=True)(bufs)), arr)


def test_eq2_traffic_ratio(rng):
    """Paper Eq. 2: unfused dictionary|bitpack costs > 2x the fused traffic."""
    arr = rng.choice(np.arange(16, dtype=np.int32), 1 << 16)
    enc, bufs = _mk(P.Plan("dictionary", children={"index": mp("bitpack")}), arr)
    flat = {k: v for k, v in bufs.items()}
    unfused = lower(enc)
    fused = fuse(list(unfused))
    t_unfused = hbm_traffic_bytes(unfused, flat)
    t_fused = hbm_traffic_bytes(fused, flat)
    assert t_unfused / t_fused > 2.0, (t_unfused, t_fused)


def test_fusion_never_changes_results_all_table2(rng):
    from repro.data.columns import TABLE2_PLANS
    from repro.data.tpch import generate

    cols = generate(scale=0.001, seed=5)
    for name, pl in TABLE2_PLANS.items():
        enc = P.encode(pl, cols[name])
        bufs = device_buffers(enc)
        a = np.asarray(compile_decoder(enc, fuse=False)(bufs))
        b = np.asarray(compile_decoder(enc, fuse=True)(bufs))
        np.testing.assert_array_equal(a, b, err_msg=name)
        assert len(compile_decoder(enc, fuse=True).stages) <= \
            len(compile_decoder(enc, fuse=False).stages), name
