"""Training substrate: optimizer descent, fault-tolerant checkpointing (atomic,
hash-verified, compressed), restart-from-failure, gradient compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SMOKES
from repro.models import get_model
from repro.train import checkpoint as ckpt
from repro.train.loop import LoopConfig, SimulatedFailure, run
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import make_train_step


def _setup(arch="qwen1.5-0.5b"):
    cfg = SMOKES[arch]
    model = get_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=50,
                         weight_decay=0.0)
    from repro.train import optimizer
    opt_state = optimizer.init(params)
    step = jax.jit(make_train_step(cfg, opt_cfg, remat=None))

    def batch_fn(i):
        rng = np.random.default_rng(i)  # deterministic in step
        toks = rng.integers(0, cfg.vocab, (2, 33))
        return {"tokens": jnp.asarray(toks[:, :-1], dtype=jnp.int32),
                "labels": jnp.asarray(toks[:, 1:], dtype=jnp.int32)}

    return cfg, params, opt_state, step, batch_fn


def test_loss_decreases():
    _, params, opt, step, batch_fn = _setup()
    batch = batch_fn(0)  # overfit one batch
    losses = []
    for _ in range(12):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3, losses


def test_checkpoint_roundtrip(tmp_path):
    _, params, opt, step, batch_fn = _setup()
    params, opt, _ = step(params, opt, batch_fn(0))
    d = str(tmp_path / "ck")
    ckpt.save(d, 7, (params, opt), extra={"note": "x"})
    (p2, o2), step_no, extra = ckpt.restore(d, (params, opt))
    assert step_no == 7 and extra["note"] == "x"
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    rep = ckpt.compression_report(d)
    assert rep["ratio"] > 1.0, rep  # exponent-plane ANS actually compresses


def test_checkpoint_corruption_detected(tmp_path):
    _, params, opt, *_ = _setup()
    d = str(tmp_path / "ck")
    sdir = ckpt.save(d, 1, params)
    victim = [f for f in os.listdir(sdir) if f.endswith(".npz")][0]
    with open(os.path.join(sdir, victim), "r+b") as f:
        f.seek(100)
        f.write(b"\xde\xad")
    with pytest.raises(IOError, match="corruption"):
        ckpt.restore(d, params)


def test_loop_restart_after_failure(tmp_path):
    """Crash at step 5, restart, converge to the same final state as an
    uninterrupted run (deterministic batches)."""
    d1, d2 = str(tmp_path / "a"), str(tmp_path / "b")
    # uninterrupted reference
    _, params, opt, step, batch_fn = _setup()
    cfg_ref = LoopConfig(total_steps=8, ckpt_dir=d1, ckpt_every=2, log_every=100)
    p_ref, o_ref, hist = run(cfg_ref, step, params, opt, batch_fn,
                             log=lambda s: None)
    # crashing run
    _, params, opt, step, batch_fn = _setup()
    cfg_fail = LoopConfig(total_steps=8, ckpt_dir=d2, ckpt_every=2,
                          log_every=100, fail_at_step=5)
    with pytest.raises(SimulatedFailure):
        run(cfg_fail, step, params, opt, batch_fn, log=lambda s: None)
    # restart resumes from step 4 checkpoint and finishes
    cfg_resume = LoopConfig(total_steps=8, ckpt_dir=d2, ckpt_every=2,
                            log_every=100)
    _, params2, opt2, step, batch_fn = _setup()
    p_fin, o_fin, hist2 = run(cfg_resume, step, params2, opt2, batch_fn,
                              log=lambda s: None)
    assert hist2[0]["step"] == 4  # resumed, not restarted from scratch
    for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_fin)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-4, atol=2e-4)


def test_grad_compression_unbiased_convergence():
    """int8 error-feedback psum: a quadratic objective still converges, and the
    wire format is 4x smaller."""
    from repro.train import grad_compress as gc

    # jax < 0.5 has neither jax.sharding.AxisType nor jax.shard_map (and its
    # shard_map spells check_vma as check_rep) -- probe instead of pinning
    mesh_kwargs = {}
    if hasattr(jax.sharding, "AxisType"):
        mesh_kwargs["axis_types"] = (jax.sharding.AxisType.Auto,)
    mesh = jax.make_mesh((1,), ("pod",), **mesh_kwargs)
    if hasattr(jax, "shard_map"):
        shard_map, check_kwargs = jax.shard_map, {"check_vma": False}
    else:
        from jax.experimental.shard_map import shard_map
        check_kwargs = {"check_rep": False}
    target = jnp.asarray(np.random.default_rng(0).normal(size=(64,)),
                         jnp.float32)

    def one_step(w, err):
        g = 2 * (w - target)
        gsum, err = gc.compressed_psum(g, err, "pod")
        return w - 0.05 * gsum, err

    stepped = jax.jit(shard_map(
        one_step, mesh=mesh,
        in_specs=(jax.sharding.PartitionSpec(),) * 2,
        out_specs=(jax.sharding.PartitionSpec(),) * 2, **check_kwargs))
    w = jnp.zeros((64,))
    err = jnp.zeros((64,))
    for _ in range(200):
        w, err = stepped(w, err)
    np.testing.assert_allclose(np.asarray(w), np.asarray(target), atol=1e-2)
    assert gc.wire_bytes({"w": w}, compressed=True) * 4 == \
        gc.wire_bytes({"w": w}, compressed=False)


def test_quantize_int8_roundtrip_error():
    from repro.train.grad_compress import dequantize, quantize_int8

    x = jnp.asarray(np.random.default_rng(1).normal(size=(1000,)), jnp.float32)
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize(q, s) - x))
    assert err.max() <= float(s) * 0.5 + 1e-6
