"""Per-architecture smoke tests (reduced configs) + serving-path consistency:
prefill+decode must agree with the full forward pass; chunked recurrences must agree
with step-by-step recurrence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SMOKES
from repro.models import cell_status, get_model
from repro.configs.base import SHAPES


def make_batch(cfg, B=2, S=64, seed=0):
    rng = np.random.default_rng(seed)
    if cfg.family == "encdec":
        return {"frames": jnp.asarray(rng.normal(size=(B, S, cfg.d_model))
                                      .astype(np.float32) * 0.1, cfg.dtype),
                "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, 16)),
                                      dtype=jnp.int32),
                "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, 16)),
                                      dtype=jnp.int32)}
    if cfg.family == "vlm":
        s_img = 16
        return {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S - s_img)),
                                      dtype=jnp.int32),
                "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S - s_img)),
                                      dtype=jnp.int32),
                "patch_embeds": jnp.asarray(
                    rng.normal(size=(B, s_img, cfg.d_model)).astype(np.float32)
                    * 0.1, cfg.dtype),
                "pos3": jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32),
                                         (B, 3, S))}
    return {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)),
                                  dtype=jnp.int32),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)),
                                  dtype=jnp.int32)}


@pytest.mark.parametrize("arch", sorted(SMOKES))
def test_smoke_forward_and_train_step(arch):
    """One forward/train step on CPU: finite loss, finite grads, shapes."""
    cfg = SMOKES[arch]
    model = get_model(cfg)
    params, specs = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    loss, grads = jax.jit(jax.value_and_grad(
        lambda p: model.train_loss(p, batch)))(params)
    assert np.isfinite(float(loss)), arch
    gn = sum(float(jnp.sum(jnp.square(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0, arch


@pytest.mark.parametrize("arch", sorted(SMOKES))
def test_smoke_decode_step(arch):
    cfg = SMOKES[arch]
    model = get_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    B = 2
    st = model.make_state(B, 64)
    tok = jnp.zeros((B, 1), jnp.int32)
    logits, st2 = jax.jit(lambda p, t, s: model.decode_step(p, t, s))(
        params, tok, st)
    assert logits.shape == (B, 1, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits, np.float32))), arch


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "rwkv6-7b", "zamba2-7b",
                                  "phi3.5-moe-42b-a6.6b"])
def test_prefill_decode_matches_forward(arch):
    """Teacher-forced serving path == training forward: prefill a prompt, decode
    the next tokens step-by-step, compare logits against the full forward."""
    cfg = SMOKES[arch]
    if cfg.family == "moe":
        # capacity-based routing drops tokens differently per dispatch-group
        # size; consistency only holds drop-free (cf >= E/top_k)
        cfg = dataclasses.replace(cfg, capacity_factor=4.0)
    model = get_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(1))
    rng = np.random.default_rng(2)
    B, S = 2, 32
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), dtype=jnp.int32)
    # full forward logits
    from repro.models import transformer, rwkv, zamba
    from repro.models import layers as L
    if cfg.family in ("dense", "moe", "vlm"):
        x, _ = transformer.forward(params, cfg, toks)
        full = L.lm_logits(params["embed"], x, cfg)
    elif cfg.family == "ssm":
        x, _ = rwkv.forward(params, cfg, toks)
        full = L.lm_logits(params["embed"], x, cfg)
    else:
        x, _ = zamba._forward(params, cfg, toks, None, "train")
        full = L.lm_logits(params["embed"], x, cfg)
    # serve: prefill on the first half, decode the rest one token at a time
    half = S // 2
    state = model.make_state(B, S)
    batch = {"tokens": toks[:, :half]}
    logits, state = jax.jit(lambda p, b, s: model.prefill(p, b, s))(
        params, batch, state)
    outs = [logits]
    dec = jax.jit(lambda p, t, s: model.decode_step(p, t, s))
    for t in range(half, S - 1):
        logits, state = dec(params, toks[:, t:t + 1], state)
        outs.append(logits)
    serve = jnp.concatenate(outs, axis=1)       # logits for positions half-1..S-2
    want = full[:, half - 1: S - 1]
    # decode attention keeps p and the KV cache in bf16 (MXU-friendly serving
    # numerics) while the train-path flash computes in f32 -> ~0.4% relative noise
    np.testing.assert_allclose(np.asarray(serve, np.float32),
                               np.asarray(want, np.float32),
                               rtol=0.12, atol=0.12)


def test_rwkv_chunked_equals_stepwise():
    """The chunked wkv6 recurrence == token-by-token recurrence."""
    cfg = dataclasses.replace(SMOKES["rwkv6-7b"], ssm_chunk=8)
    cfg2 = dataclasses.replace(cfg, ssm_chunk=1)  # chunk=1 == pure recurrence
    model = get_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(3))
    toks = jnp.asarray(np.random.default_rng(4).integers(0, cfg.vocab, (2, 24)),
                       dtype=jnp.int32)
    from repro.models import rwkv
    xa, sta = rwkv.forward(params, cfg, toks)
    xb, stb = rwkv.forward(params, cfg2, toks)
    np.testing.assert_allclose(np.asarray(xa, np.float32),
                               np.asarray(xb, np.float32), rtol=2e-2, atol=2e-2)
    np.testing.assert_allclose(np.asarray(sta["wkv"]), np.asarray(stb["wkv"]),
                               rtol=2e-2, atol=2e-2)


def test_mamba_chunked_equals_stepwise():
    cfg = dataclasses.replace(SMOKES["zamba2-7b"], ssm_chunk=8)
    cfg2 = dataclasses.replace(cfg, ssm_chunk=1)
    model = get_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(5))
    toks = jnp.asarray(np.random.default_rng(6).integers(0, cfg.vocab, (2, 16)),
                       dtype=jnp.int32)
    from repro.models import zamba
    xa, _ = zamba._forward(params, cfg, toks, None, "train")
    xb, _ = zamba._forward(params, cfg2, toks, None, "train")
    np.testing.assert_allclose(np.asarray(xa, np.float32),
                               np.asarray(xb, np.float32), rtol=2e-2, atol=2e-2)


def test_cell_status_rules():
    from repro.configs import ARCHS
    assert cell_status(ARCHS["rwkv6-7b"], SHAPES["long_500k"]) == "run"
    assert cell_status(ARCHS["zamba2-7b"], SHAPES["long_500k"]) == "run"
    assert cell_status(ARCHS["phi3-mini-3.8b"],
                       SHAPES["long_500k"]).startswith("skip")
    assert cell_status(ARCHS["dbrx-132b"], SHAPES["train_4k"]) == "run"
