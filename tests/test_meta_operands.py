"""Operand-lifted metadata + per-chunk streamed decode.

Pins the tentpole invariants: (1) data-dependent meta (bitpack bit_width/base,
delta base) is a runtime operand, so blobs differing only in those values share ONE
compiled program; (2) the per-chunk decode path is bitwise-identical to one-shot
decode for every element-chunkable TPC-H Q1 nesting; (3) nestings with neither an
element nor a group chunk layout fall back cleanly to whole-column decode
(group-boundary streaming itself is pinned by tests/test_group_chunk.py).
"""
import numpy as np
import pytest

from repro.core import plan as P
from repro.core.compiler import ProgramCache, compile_blob
from repro.core.executor import StreamingExecutor
from repro.core.ir import CHUNK_GROUP, CHUNK_NONE  # re-exported pattern levels
from repro.data.columns import TABLE2_PLANS
from repro.data.tpch import QUERY_COLUMNS, generate

mp = P.make_plan


# --------------------------------------------------------- operand-lifted reuse

def test_bitpack_blobs_differing_in_meta_compile_once(rng):
    """N bitpack blobs with different bit_width AND base -> exactly one program.

    n=15 makes ceil(n*bw/32) collide for bw 16..17, so the packed shapes (the
    structural part) are equal while the lifted scalars differ."""
    cache = ProgramCache()
    blobs = []
    for bw, base in [(16, 0), (17, 5), (16, -123), (17, 100_000)]:
        arr = (rng.integers(0, 2 ** bw - 1, 15) + base).astype(np.int32)
        blobs.append((arr, P.encode(P.Plan("bitpack",
                                           params={"bit_width": bw}), arr)))
    progs = [compile_blob(enc, cache=cache) for _, enc in blobs]
    assert cache.stats["misses"] == 1, "one structure -> one XLA compile"
    assert len({id(p) for p in progs}) == 1
    from repro.core.compiler import device_buffers
    for (arr, enc), prog in zip(blobs, progs):
        np.testing.assert_array_equal(np.asarray(prog(device_buffers(enc))), arr)


def test_delta_base_is_an_operand(rng):
    """delta|bitpack columns with different start values share one program."""
    cache = ProgramCache()
    plan = P.Plan("delta", children={"deltas": mp("bitpack")})
    step = rng.integers(0, 3, 4096).astype(np.int64)
    outs = []
    for base in (0, 7_000_000):
        arr = (base + np.cumsum(step)).astype(np.int32)
        enc = P.encode(plan, arr)
        prog = compile_blob(enc, cache=cache)
        from repro.core.compiler import device_buffers
        outs.append((np.asarray(prog(device_buffers(enc))), arr))
    assert cache.stats["misses"] == 1
    for got, want in outs:
        np.testing.assert_array_equal(got, want)


def test_batched_decode_vmaps_over_meta_operands(rng):
    """Same-signature columns with DIFFERENT meta operands stack into one batched
    launch -- the operands vmap along with the buffers."""
    cols = {f"c{i}": (rng.integers(0, 1000, 20_000) + i * 37).astype(np.int32)
            for i in range(3)}
    encs = {n: P.encode(P.Plan("bitpack", params={"bit_width": 10}), arr)
            for n, arr in cols.items()}
    cache = ProgramCache()
    ex = StreamingExecutor(chunk_bytes=8192, batch_columns=True, cache=cache)
    results = ex.run(encs)
    assert cache.stats["misses"] == 1
    for n, arr in cols.items():
        np.testing.assert_array_equal(np.asarray(results[n].array), arr)
        assert len(results[n].batched_with) == 2


# ------------------------------------------------------- per-chunk decode path

@pytest.mark.parametrize("chunk_bytes,min_chunked", [(2048, 4), (16384, 1)])
def test_per_chunk_decode_bitwise_equals_oracle(chunk_bytes, min_chunked):
    """Every TPC-H Q1 nesting through chunk_decode=True == plan.decode_np,
    with chunkable graphs actually decoding in multiple launches."""
    cols = generate(scale=0.002, seed=7)
    names = QUERY_COLUMNS[1]
    encs = {n: P.encode(TABLE2_PLANS[n], cols[n]) for n in names}
    ex = StreamingExecutor(chunk_bytes=chunk_bytes, chunk_decode=True,
                           cache=ProgramCache())
    results = ex.run(encs)
    chunked_cols = 0
    for n in names:
        got = np.asarray(results[n].array)
        np.testing.assert_array_equal(got, P.decode_np(encs[n]), err_msg=n)
        np.testing.assert_array_equal(got, cols[n], err_msg=n)
        if results[n].chunk_decoded:
            chunked_cols += 1
            assert results[n].decode_launches == results[n].n_chunks > 1
    assert chunked_cols >= min_chunked, \
        "Q1's bitpack-family nestings must chunk-decode"


def test_per_chunk_decode_matches_whole_column(rng):
    """Chunked vs whole-column decode of the same blobs: bitwise identical."""
    arr = rng.integers(-500, 10_000, 100_000).astype(np.int32)
    enc = P.encode(P.Plan("dictionary", children={"index": mp("bitpack")}), arr)
    whole = StreamingExecutor(chunk_bytes=None, cache=ProgramCache())
    chunked = StreamingExecutor(chunk_bytes=4096, chunk_decode=True,
                                cache=ProgramCache())
    a = np.asarray(whole.run({"c": enc})["c"].array)
    b = np.asarray(chunked.run({"c": enc})["c"].array)
    np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(b, arr)


def test_non_chunkable_nestings_fall_back(rng):
    """Graphs with neither an element nor a group chunk layout fall back to one
    whole-column launch -- still bitwise-correct.

    delta's cumsum is whole-array: CHUNK_NONE.  rle with bit-packed leaves
    used to be stuck here too (the packed values ride an operand-ratio tile
    the layout rejected); operand-ratio slicing now streams it -- pinned as
    the contrast case.  Plain ANS is covered in tests/test_group_chunk.py."""
    from repro.core.ir import CHUNK_GROUP as CG
    from repro.core.patterns import GroupParallel

    ex = StreamingExecutor(chunk_bytes=1024, chunk_decode=True,
                           cache=ProgramCache())
    arr_d = np.cumsum(rng.integers(0, 4, 30_000)).astype(np.int32)
    enc_d = P.encode(P.Plan("delta", children={"deltas": mp("bitpack")}), arr_d)
    ex.compile("delta", enc_d)
    assert ex.graph("delta").chunkability == CHUNK_NONE
    assert ex.chunk_schedule("delta") is None
    res = ex.run({"delta": enc_d})["delta"]
    assert not res.chunk_decoded and res.decode_launches == 1
    np.testing.assert_array_equal(np.asarray(res.array), arr_d)

    arr_r = np.repeat(rng.integers(0, 5000, 2001),
                      rng.integers(1, 60, 2001)).astype(np.int32)
    enc_r = P.encode(P.Plan("rle", children={"counts": mp("bitpack"),
                                             "values": mp("bitpack")}), arr_r)
    ex.compile("rle", enc_r)
    assert ex.graph("rle").chunkability == CG
    res = ex.run({"rle": enc_r})["rle"]
    assert res.chunk_decoded and res.decode_launches > 1
    np.testing.assert_array_equal(np.asarray(res.array), arr_r)
    gp = [s for s in ex.graph("rle").stages if isinstance(s, GroupParallel)]
    assert gp and gp[0].chunkability == CG


def test_chunk_programs_shared_across_columns(rng):
    """Same-structure columns reuse the SAME per-chunk programs (body + tail)."""
    cache = ProgramCache()
    ex = StreamingExecutor(chunk_bytes=4096, chunk_decode=True, cache=cache)
    encs = {f"c{i}": P.encode(mp("bitpack"),
                              rng.integers(0, 4000, 50_000).astype(np.int32))
            for i in range(3)}
    results = ex.run(encs)
    for n, enc in encs.items():
        np.testing.assert_array_equal(np.asarray(results[n].array),
                                      P.decode_np(enc))
        assert results[n].chunk_decoded
    # one whole-column program (from compile) + body/tail chunk programs, shared:
    # 3 columns x K chunks hit the same <= 3 cache entries
    assert cache.stats["misses"] <= 3
    assert cache.stats["hits"] >= 2 * (results["c0"].decode_launches - 1)
