"""Trip-count-aware HLO cost walker vs closed forms (the roofline's foundation)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.roofline.hlo_cost import analyze
from repro.roofline.analysis import model_flops
from repro.configs import ARCHS, SHAPES


def _hlo(f, *shapes):
    return jax.jit(f).lower(*shapes).compile().as_text()


def test_matmul_exact():
    hlo = _hlo(lambda a, b: a @ b,
               jax.ShapeDtypeStruct((256, 512), jnp.float32),
               jax.ShapeDtypeStruct((512, 128), jnp.float32))
    assert analyze(hlo)["flops"] == 2 * 256 * 512 * 128


def test_scan_trip_multiplication():
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y.sum()

    hlo = _hlo(f, jax.ShapeDtypeStruct((128, 128), jnp.float32),
               jax.ShapeDtypeStruct((128, 128), jnp.float32))
    got = analyze(hlo)["flops"]
    want = 10 * 2 * 128**3
    assert abs(got - want) / want < 0.01, (got, want)


def test_nested_scan():
    def f(x, w):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ w, None
            c2, _ = jax.lax.scan(inner, c, None, length=4)
            return c2, None
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y.sum()

    hlo = _hlo(f, jax.ShapeDtypeStruct((64, 64), jnp.float32),
               jax.ShapeDtypeStruct((64, 64), jnp.float32))
    got = analyze(hlo)["flops"]
    want = 20 * 2 * 64**3
    assert abs(got - want) / want < 0.01, (got, want)


def test_flash_attention_flops_within_tolerance():
    """Chunked flash attention == 2 * 2 * B*H*Sq*Sk*hd (QK^T + PV), rectangular."""
    from repro.models.layers import flash_attention

    B, S, H, hd = 2, 1024, 4, 64
    q = jax.ShapeDtypeStruct((B, S, H, hd), jnp.float32)
    hlo = _hlo(lambda q, k, v: flash_attention(q, k, v, causal=True,
                                               q_chunk=256, kv_chunk=256),
               q, q, q)
    got = analyze(hlo)["flops"]
    want = 4 * B * H * S * S * hd
    assert abs(got - want) / want < 0.05, (got, want)


def test_training_flops_close_to_analytic():
    """Full smoke-model train grad: HLO flops ~ 6-8x N x D (fwd 2, bwd 4,
    (+recompute 2 under full remat))."""
    from repro.configs import SMOKES
    from repro.models import get_model

    cfg = SMOKES["qwen1.5-0.5b"]
    model = get_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    B, S = 4, 128
    batch = {"tokens": jnp.zeros((B, S), jnp.int32),
             "labels": jnp.zeros((B, S), jnp.int32)}
    hlo = jax.jit(jax.grad(lambda p: model.train_loss(p, batch))) \
        .lower(params).compile().as_text()
    got = analyze(hlo)["flops"]
    n = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
    lo, hi = 5 * n * B * S, 11 * n * B * S
    assert lo < got < hi, (got, lo, hi)


def test_collective_bytes_in_scan(monkeypatch):
    import os
    # (runs on 1 device: use replica_groups-free module from a saved dry-run if
    # present; else accept the unit scale check)
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y.sum()
    hlo = _hlo(f, jax.ShapeDtypeStruct((64, 64), jnp.float32),
               jax.ShapeDtypeStruct((64, 64), jnp.float32))
    assert analyze(hlo)["coll_bytes"] == 0.0


def test_model_flops_moe_uses_active_params():
    cfg = ARCHS["dbrx-132b"]
    dense_equiv = 6 * cfg.param_count() * 4096 * 256
    got = model_flops(cfg, SHAPES["train_4k"], "train")
    assert got < dense_equiv, "MoE must count active params only"
    assert got > 6 * cfg.active_param_count() * 4096 * 256 * 0.9
