"""End-to-end data pipelines: the analytics ColumnPipeline (compress -> transfer ->
decode, Johnson-ordered) and the fixed-shape compressed training loader."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.data.columns import TABLE2_PLANS
from repro.data.loader import ColumnPipeline, CompressedTokenLoader
from repro.data.tpch import QUERY_COLUMNS, generate


def test_column_pipeline_end_to_end():
    cols = generate(scale=0.002, seed=7)
    names = QUERY_COLUMNS[1]          # TPC-H Q1 columns
    plans = {n: TABLE2_PLANS[n] for n in names}
    pipe = ColumnPipeline(plans, backend="jnp", fuse=True)
    ratios = pipe.compress({n: cols[n] for n in names})
    assert min(ratios.values()) > 0.3
    results = pipe.run()
    for n in names:
        np.testing.assert_array_equal(np.asarray(results[n].array), cols[n])
    # Johnson order can't be worse than submission order or serial execution --
    # compare on ONE measurement set (repeated CPU measurements are noisy)
    from repro.core import scheduler
    est = {n: pipe._measure(n) for n in names}
    jobs = [scheduler.Job(n, est[n][0], est[n][1]) for n in names]
    mk_j = scheduler.makespan(jobs, scheduler.johnson_order(jobs))
    assert mk_j <= scheduler.makespan(jobs) + 1e-9
    assert mk_j <= scheduler.serial_time(jobs) + 1e-9


def test_compressed_token_loader_fixed_shapes():
    loader = CompressedTokenLoader(vocab=50_000, batch=4, seq_len=128)
    decode = jax.jit(loader.decode_fn())
    shapes = set()
    it = loader.batches()
    for _ in range(3):
        bufs = next(it)
        shapes.add(bufs["packed"].shape)
        batch = decode(bufs)
        assert batch["tokens"].shape == (4, 128)
        assert batch["labels"].shape == (4, 128)
        assert int(batch["tokens"].max()) < 50_000
    assert len(shapes) == 1, "compressed buffers must be shape-stable for jit"
    assert loader.ratio > 1.9   # 17 bits vs 32 for 50k vocab


def test_loader_decode_matches_source():
    loader = CompressedTokenLoader(vocab=1000, batch=2, seq_len=64)
    bufs = {k: jnp.asarray(v) for k, v in loader.encode_host(5).items()}
    batch = loader.decode_fn()(bufs)
    src = loader._synthetic(5)
    np.testing.assert_array_equal(np.asarray(batch["tokens"]), src[:, :-1])
    np.testing.assert_array_equal(np.asarray(batch["labels"]), src[:, 1:])


def test_serve_kv_paging_roundtrip():
    from repro.serve.kvcache import page_in, page_out, quantize_kv, dequantize_kv

    rng = np.random.default_rng(3)
    block = jnp.asarray(rng.normal(size=(2, 16, 4, 32)).astype(np.float32))
    q, s = quantize_kv(block)
    deq = dequantize_kv(q, s, jnp.float32)
    assert float(jnp.max(jnp.abs(deq - block))) < float(jnp.max(s)) * 0.51
    pb = page_out(block)
    back = page_in(pb, jnp.float32)
    np.testing.assert_allclose(np.asarray(back), np.asarray(deq),
                               rtol=1e-5, atol=1e-5)
    assert pb.packed.nbytes < block.nbytes / 3   # 8 bits vs 32 + scales


def test_serve_engine_generates():
    from repro.configs import SMOKES
    from repro.models import get_model
    from repro.serve.engine import Request, ServeEngine

    cfg = SMOKES["qwen1.5-0.5b"]
    model = get_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, batch_slots=2, max_len=64, eos=-1)
    rng = np.random.default_rng(0)
    for rid in range(3):
        eng.submit(Request(rid, rng.integers(0, cfg.vocab, 4).astype(np.int32),
                           max_new=5))
    done = eng.run_to_completion(max_steps=100)
    assert set(done) == {0, 1, 2}
    assert all(len(v) == 5 for v in done.values())
