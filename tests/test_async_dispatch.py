"""Async dispatch engine: per-link transfer workers + overlapped issuance.

In-process tests pin the engine's contracts on the single real CPU device:
worker-thread issuance must be BITWISE identical to the inline sequential
path on the same plan (whole-column, element-chunked and group-chunked decode
all covered), the shared host-staging budget must not deadlock at its tightest
setting, the ``ProgramCache`` must compile exactly once under a thread hammer,
``CostModel.observe``/``observe_link`` must stay exact under concurrency and
persist through save/load, a slowed link must shift planned bytes away, and
the ``ServePlanner`` background drain loop must complete submissions with no
explicit ``drain()`` (including a clean ``stop()`` with work in flight).

The multi-device path -- concurrent 4-link issuance through ``run_sharded``
bitwise against the sequential per-device loop -- needs >1 jax device, so it
runs in a subprocess with forced host devices (the tests/test_mesh_decode.py
pattern: XLA's device count locks at first init).
"""
import dataclasses
import os
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

from repro.core import plan as P
from repro.core.compiler import ProgramCache
from repro.core.costmodel import ColumnProfile, CostModel
from repro.core.executor import StreamingExecutor
from repro.core.planner import plan_mesh_execution
from repro.core.serve_planner import ServePlanner

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _columns():
    """Mixed-chunkability column set: whole, element-chunked, group-chunked."""
    rng = np.random.default_rng(11)
    cols = {
        "whole": rng.integers(0, 9, 3_000).astype(np.int32),
        "elem": (np.arange(200_000, dtype=np.int32) % 1000),
        "grp": np.concatenate([np.zeros(50_000, np.int32),
                               rng.integers(0, 60, 30_000).astype(np.int32)]),
        "rle": np.repeat(rng.integers(0, 50, 400),
                         rng.integers(1, 90, 400)).astype(np.int32),
    }
    plans = {"whole": P.Plan("ans", params={"chunk_size": 512}),
             "elem": P.make_plan("bitpack"),
             "grp": P.Plan("ans", params={"chunk_size": 512}),
             "rle": P.make_plan("rle")}
    return {n: P.encode(plans[n], a) for n, a in cols.items()}, cols


# ------------------------------------------------- worker vs inline issuance

def test_worker_issuance_bitwise_identical():
    """async_dispatch=True must produce byte-for-byte the sequential result
    on the SAME plan, across whole / element-chunked / group-chunked modes."""
    encs, cols = _columns()
    ex = StreamingExecutor(chunk_bytes=1 << 14, chunk_decode=True,
                           cache=ProgramCache())
    for n, e in encs.items():
        ex.compile(n, e)
    ep = ex.plan(list(encs))
    seq = ex.run(encs, plan=ep, async_dispatch=False)
    # the plan must actually cover both decode regimes, or this test would
    # silently degrade to whole-column-only coverage
    assert any(r.chunk_decoded for r in seq.values())
    assert any(not r.chunk_decoded for r in seq.values())
    asy = ex.run(encs, plan=ep, async_dispatch=True)
    for n in encs:
        np.testing.assert_array_equal(np.asarray(asy[n].array),
                                      np.asarray(seq[n].array), err_msg=n)
        np.testing.assert_array_equal(np.asarray(asy[n].array), cols[n],
                                      err_msg=n)


def test_worker_issuance_tightest_host_budget_completes():
    """host_window=1 -- ONE shared staging slot -- must still complete (the
    per-chunk acquire/release slots guarantee forward progress); output stays
    bitwise exact."""
    encs, cols = _columns()
    ex = StreamingExecutor(chunk_bytes=1 << 14, chunk_decode=True,
                           cache=ProgramCache())
    for n, e in encs.items():
        ex.compile(n, e)
    ex.cost_model.topology = dataclasses.replace(
        ex.cost_model.topology, host_window=1)
    res = ex.run(encs, async_dispatch=True)
    for n in encs:
        np.testing.assert_array_equal(np.asarray(res[n].array), cols[n],
                                      err_msg=n)


def test_constructor_knob_and_pipeline_passthrough():
    """StreamingExecutor(async_dispatch=True) makes run() default to worker
    issuance; ColumnPipeline forwards the knob."""
    from repro.data.loader import ColumnPipeline

    encs, cols = _columns()
    ex = StreamingExecutor(chunk_bytes=1 << 14, chunk_decode=True,
                           cache=ProgramCache(), async_dispatch=True)
    assert ex.async_dispatch
    for n, e in encs.items():
        ex.compile(n, e)
    res = ex.run(encs)      # no per-call override: constructor default rules
    for n in encs:
        np.testing.assert_array_equal(np.asarray(res[n].array), cols[n],
                                      err_msg=n)
    pipe = ColumnPipeline({"a": P.make_plan("bitpack")}, chunk_bytes=4096,
                          async_dispatch=True)
    assert pipe.async_dispatch and pipe.executor.async_dispatch


# --------------------------------------------------- thread-safety contracts

def test_program_cache_compiles_exactly_once_under_hammer():
    """N racing threads asking for the same key build it ONCE: the losers of
    the per-key compile lock re-find the winner's program on the re-lookup
    (double-checked locking), never duplicating a trace+XLA compile."""
    cache = ProgramCache()
    builds = []

    def build():
        time.sleep(0.05)            # widen the race window
        builds.append(1)
        return object()

    n = 8
    barrier = threading.Barrier(n)
    progs = [None] * n

    def worker(i):
        barrier.wait()
        progs[i] = cache._get(("hammer-key",), build)

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(n)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60)
    assert len(builds) == 1
    assert cache.misses == 1 and cache.hits == n - 1
    assert all(p is progs[0] for p in progs)

    # and through the REAL tracing path: one graph, many threads, one miss
    graph = P.lower_graph(P.encode(P.make_plan("bitpack"),
                                   np.arange(4096, dtype=np.int32)))
    cache2 = ProgramCache()
    barrier2 = threading.Barrier(n)
    out = [None] * n

    def tracer(i):
        barrier2.wait()
        out[i] = cache2.get(graph)

    threads = [threading.Thread(target=tracer, args=(i,)) for i in range(n)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=120)
    assert cache2.stats["misses"] == 1, cache2.stats
    assert all(o is out[0] for o in out)


def test_cost_model_observe_concurrent_counts_exact():
    """observe() is atomic: N threads x M samples lose nothing -- n_observed
    and the per-signature incremental mean count exactly N*M."""
    cm = CostModel()
    cm.register(ColumnProfile(name="x", compressed_nbytes=1 << 20,
                              plain_nbytes=1 << 22, n_kernels=2,
                              signature="sig-x"))
    n_threads, per = 8, 50
    barrier = threading.Barrier(n_threads)

    def worker():
        barrier.wait()
        for _ in range(per):
            cm.observe("x", 1e-3, 2e-3)

    threads = [threading.Thread(target=worker) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60)
    assert cm.n_observed == n_threads * per
    assert cm.sig_stats["sig-x"]["n"] == pytest.approx(n_threads * per)


# ------------------------------------------- per-link calibration + planning

def test_observe_link_persists_through_save_load(tmp_path):
    """Per-link EWMA scales land in topology.link_scale and round-trip
    through CostModel.save/load (the "topology" block)."""
    cm = CostModel()
    for _ in range(60):
        cm.observe_link(1, 3.0)
    cm.observe_link(1, -1.0)        # invalid samples are ignored, not folded
    cm.observe_link(1, float("nan"))
    assert cm.topology.n_links >= 2
    assert cm.topology.scale(0) == pytest.approx(1.0)
    assert cm.topology.scale(1) == pytest.approx(3.0, rel=0.05)
    path = tmp_path / "cm.json"
    cm.save(str(path))
    cm2 = CostModel.load(str(path))
    assert cm2.topology == cm.topology
    assert cm2.topology.scale(1) == pytest.approx(cm.topology.scale(1))


def test_slowed_link_shifts_assignment_bytes_away():
    """A link whose EWMA scale drifts up (measured 4x slower than predicted)
    receives strictly fewer bytes from plan_mesh_execution on the re-plan."""
    rng = np.random.default_rng(0)
    profiles = {}
    for i in range(8):
        nb = int(rng.integers(1 << 16, 1 << 20))
        profiles[f"c{i}"] = ColumnProfile(
            name=f"c{i}", compressed_nbytes=nb, plain_nbytes=nb * 3,
            n_kernels=2, signature=f"s{i}")
    cm = CostModel()
    for p in profiles.values():
        cm.register(p)

    def bytes_on(mp, dev):
        return sum(profiles[it].compressed_nbytes
                   for it, d in mp.assignment.items() if d == dev)

    mp0 = plan_mesh_execution(profiles, cm, n_devices=2)
    before = bytes_on(mp0, 1)
    assert before > 0               # balanced LPT loads both links
    for _ in range(60):
        cm.observe_link(1, 4.0)
    mp1 = plan_mesh_execution(profiles, cm, n_devices=2)
    after = bytes_on(mp1, 1)
    assert after < before, (after, before)
    # dominance contract survives heterogeneous link scales
    assert mp1.modeled_makespan_s <= mp1.baselines["round-robin"] + 1e-12
    assert mp1.modeled_makespan_s <= mp1.baselines["single-device"] + 1e-12


# --------------------------------------------------- background drain loop

def test_serve_drain_loop_liveness():
    """start() + submit() + req.wait() completes requests with NO explicit
    drain() call from the submitting thread."""
    encs, cols = _columns()
    sp = ServePlanner(StreamingExecutor(chunk_bytes="auto", chunk_decode=True,
                                        cache=ProgramCache())).start()
    try:
        reqs = [sp.submit(f"r{i}", {"grp": encs["grp"],
                                    "whole": encs["whole"]})
                for i in range(3)]
        for r in reqs:
            assert r.wait(timeout=300.0), f"{r.rid} never completed"
            assert r.error is None
            np.testing.assert_array_equal(
                np.asarray(r.results["grp"].array), cols["grp"])
            np.testing.assert_array_equal(
                np.asarray(r.results["whole"].array), cols["whole"])
    finally:
        sp.stop()
    assert sp.pending == 0
    assert sp.reports           # waves actually ran through the loop


def test_serve_stop_completes_inflight_work():
    """stop() right after a burst of submits strands nothing: the final sweep
    services every pre-stop submission; start() afterwards works again."""
    encs, cols = _columns()
    sp = ServePlanner(StreamingExecutor(chunk_bytes="auto", chunk_decode=True,
                                        cache=ProgramCache())).start()
    reqs = [sp.submit(f"w{i}", {"rle": encs["rle"]}) for i in range(4)]
    sp.stop()                       # work in flight; join includes the sweep
    for r in reqs:
        assert r.done and r.error is None, r.rid
        np.testing.assert_array_equal(np.asarray(r.results["rle"].array),
                                      cols["rle"])
    assert sp.pending == 0
    sp.start()                      # clean restart after a stop
    again = sp.submit("again", {"rle": encs["rle"]})
    assert again.wait(timeout=120.0) and again.error is None
    sp.stop()


def test_serve_engine_surfaces_wave_errors_per_request():
    """A decode wave that dies surfaces its exception on the submitting
    caller's Request (engine admission keeps running; a later healthy
    request still completes)."""
    import jax

    from repro.configs import SMOKES
    from repro.models import get_model
    from repro.serve.engine import ServeEngine

    cfg = SMOKES["qwen1.5-0.5b"]
    model = get_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, batch_slots=2, max_len=64, eos=-1)
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab, 4).astype(np.int32)
    plan = P.make_plan("bitpack")

    boom = RuntimeError("wave exploded")
    orig = eng.planner._run_wave
    eng.planner._run_wave = lambda wave: (_ for _ in ()).throw(boom)
    req = eng.submit_compressed(0, P.encode(plan, toks), max_new=3)
    done = eng.run_to_completion(max_steps=20)
    assert req.done and req.error is boom
    assert req.out == [] and 0 in done

    eng.planner._run_wave = orig
    req2 = eng.submit_compressed(1, P.encode(plan, toks), max_new=3)
    done = eng.run_to_completion(max_steps=60)
    assert req2.error is None and len(done[1]) == 3
    np.testing.assert_array_equal(req2.prompt, toks)


# ------------------------------------------------- multi-device (subprocess)

_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax
import numpy as np
from repro.core import plan as P, planner
from repro.core.compiler import ProgramCache
from repro.core.executor import StreamingExecutor

assert jax.device_count() == 4

rng = np.random.default_rng(7)
cols = {
    "big": np.concatenate([np.zeros(50_000, np.int32),
                           rng.integers(0, 60, 30_000).astype(np.int32)]),
    "rle": np.repeat(rng.integers(0, 50, 400),
                     rng.integers(1, 90, 400)).astype(np.int32),
    "small0": rng.integers(0, 9, 5_000).astype(np.int32),
    "small1": rng.integers(0, 9, 5_000).astype(np.int32),
}
plans = {"big": P.Plan("ans", params={"chunk_size": 512}),
         "rle": P.make_plan("rle"),
         "small0": P.Plan("ans", params={"chunk_size": 512}),
         "small1": P.Plan("ans", params={"chunk_size": 512})}
encs = {n: P.encode(plans[n], a) for n, a in cols.items()}

ex = StreamingExecutor(chunk_bytes="auto", chunk_decode=True,
                       cache=ProgramCache())
for n, e in encs.items():
    ex.compile(n, e)
profiles = {n: ex.column_profile(n) for n in encs}
mp = planner.plan_mesh_execution(profiles, ex.cost_model, n_devices=4,
                                 shard_threshold_bytes=0)
assert "big" in mp.shards and len(mp.shards["big"]) == 4

# sequential per-device loop is the reference; concurrent engine issuance
# over all 4 links must match it bitwise (and the raw arrays)
seq = ex.run_sharded(mp, encs, concurrent=False)
conc = ex.run_sharded(mp, encs, concurrent=True)
for n in encs:
    np.testing.assert_array_equal(np.asarray(seq[n].array), cols[n],
                                  err_msg=n)
    np.testing.assert_array_equal(np.asarray(conc[n].array),
                                  np.asarray(seq[n].array), err_msg=n)
assert set(conc.device_launches) == set(range(4))
assert len(set(conc["big"].shard_devices)) > 1

# run_sharded defaults to concurrent when >1 device leg has work
dflt = ex.run_sharded(mp, encs)
for n in encs:
    np.testing.assert_array_equal(np.asarray(dflt[n].array), cols[n],
                                  err_msg=n)

# the concurrent legs' measured/predicted transfer ratios fed per-link EWMAs
topo = ex.cost_model.topology
assert topo.n_links >= 4 and len(topo.link_scale) >= 4
assert all(s > 0 for s in topo.link_scale)
print("ASYNC_MESH_OK")
"""


def test_concurrent_sharded_subprocess():
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _SUBPROC], env=env,
                         capture_output=True, text=True, timeout=420)
    assert "ASYNC_MESH_OK" in out.stdout, out.stdout + "\n" + out.stderr[-3000:]
