"""rANS construction invariants (the <=1-word renorm bound that makes the lockstep
decode branch-free) + paper Fig. 14/15 qualitative properties."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.algos.ans import (L, M, SCALE_BITS, decode_chunks_np, encode_chunks_np,
                             normalize_freqs)
from repro.core import plan as P


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(0, 2**20), min_size=1, max_size=256))
def test_normalize_freqs_invariants(counts):
    c = np.zeros(256, np.int64)
    c[: len(counts)] = counts
    f = normalize_freqs(c)
    assert f.sum() == M
    assert ((c > 0) <= (f > 0)).all(), "present symbol lost its slot"


@settings(max_examples=10, deadline=None)
@given(st.binary(min_size=64, max_size=4096))
def test_encoder_emits_at_most_one_word_per_symbol(data):
    """The invariant that keeps every decode step a single branch-free select."""
    raw = np.frombuffer(data, np.uint8)
    cs = 64
    n_chunks = -(-raw.size // cs)
    padded = np.zeros(n_chunks * cs, np.uint8)
    padded[: raw.size] = raw
    freq = normalize_freqs(np.bincount(padded, minlength=256))
    cum = np.concatenate([[0], np.cumsum(freq)[:-1]])
    streams, states = encode_chunks_np(padded.reshape(n_chunks, cs), freq, cum)
    assert streams.shape[0] <= cs + 1          # <= one word per symbol
    assert (states >= L).all()                 # decoder state invariant
    sym = np.repeat(np.arange(256), freq)
    out = decode_chunks_np(streams, states, sym, freq, cum, cs)
    np.testing.assert_array_equal(out.reshape(-1)[: raw.size], raw)


def test_skew_insensitivity_of_decode_work(rng):
    """Paper Fig. 14: decode work per symbol is constant w.r.t. skew (unlike
    nvCOMP) -- every step consumes <= 1 word regardless of frequency shape."""
    for p in ([1 / 3] * 3, [0.90, 0.05, 0.05]):
        arr = rng.choice(np.arange(3, dtype=np.uint8), 30000, p=p)
        enc = P.encode(P.Plan("ans", params={"chunk_size": 1024}), arr)
        np.testing.assert_array_equal(P.decode_np(enc), arr)
        # stripe height bounds the lockstep work: always <= chunk_size + 1
        assert enc.buffers["streams"].shape[0] <= 1025


def test_chunk_size_ratio_tradeoff(rng):
    """Paper Fig. 15: larger chunks -> better ratio (less padding/table overhead),
    smaller chunks -> more lockstep parallelism."""
    arr = rng.choice(np.arange(4, dtype=np.uint8) + 60, 1 << 17,
                     p=[.7, .2, .05, .05])
    sizes = [256, 1024, 8192]
    ratios, chunks = [], []
    for cs in sizes:
        enc = P.encode(P.Plan("ans", params={"chunk_size": cs}), arr)
        ratios.append(enc.ratio)
        chunks.append(enc.meta["n_chunks"])
    assert ratios == sorted(ratios), f"ratio should grow with chunk size {ratios}"
    assert chunks == sorted(chunks, reverse=True)
