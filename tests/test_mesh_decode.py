"""Sharded multi-device streaming decode (topology-aware planning).

In-process tests need no devices: they pin the ``simulate_stream_multi``
model (exact reduction to the single-link simulator at N=1), the mesh
planner's assignment-dominance contract (chosen makespan <= round-robin and
single-device BY CONSTRUCTION -- both are scored candidates), the
``LinkTopology`` persistence round-trip (unknown keys tolerated, so old JSON
caches keep loading; pre-D2D topology blocks load with the fabric OFF), the
``observe_d2d`` fabric EWMA, and the D2D redistribution contract under
``placement="sharded"`` (decode-in-place always scored, so redistribution
wins only when its makespan -- fabric copies included -- beats it).

The multi-device execution paths -- bitwise equality of sharded vs
single-device decode (including a group-span-sharded column), elastic
re-planning on simulated device loss, a ``ServePlanner`` wave spanning
two devices, and fabric-rebalanced execution (D2D legs through the dispatch
engine, final ``NamedSharding`` on the requested placement) -- need >1 jax
device, and XLA's host-device count is locked at first init, so they run in
a subprocess with forced host devices (the same pattern
tests/test_elastic.py uses).
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import scheduler
from repro.core.costmodel import ColumnProfile, CostModel, LinkTopology
from repro.core.planner import (SHARD_SEP, plan_mesh_execution,
                                shard_column_of, shard_name)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ------------------------------------------------------ simulate_stream_multi

def _jobs():
    jobs = [scheduler.Job("a", 3.0, 1.0), scheduler.Job("b", 0.5, 2.0),
            scheduler.Job("c", 1.5, 1.5), scheduler.Job("d", 0.2, 0.1)]
    infos = [scheduler.ChunkInfo(4, chunk_decode=True,
                                 weights=((0.4, 0.4), (0.3, 0.3),
                                          (0.2, 0.2), (0.1, 0.1))),
             scheduler.ChunkInfo(1), scheduler.ChunkInfo(2, chunk_decode=True),
             scheduler.ChunkInfo(1)]
    return jobs, infos


def test_multi_reduces_to_single_link():
    """N=1, default link params: EXACTLY the single-link chunk simulator,
    makespan and per-job finishes both."""
    jobs, infos = _jobs()
    for window in (1, 2, 4):
        for order in (None, [3, 1, 0, 2]):
            mk1, fin1 = scheduler.simulate_stream_finish(
                jobs, infos, order=order, window=window)
            mkN, finN = scheduler.simulate_stream_multi(
                jobs, infos, assignment=[0] * 4, n_links=1,
                order=order, window=window)
            assert mkN == pytest.approx(mk1, abs=1e-12)
            assert finN == pytest.approx(fin1, abs=1e-12)


def test_multi_parallel_links_beat_one():
    """Independent links: splitting jobs over 2 links cannot be slower than
    serializing them on one, and a degenerate all-on-link-0 assignment with
    n_links=2 equals the single-link makespan."""
    jobs, infos = _jobs()
    mk_one, _ = scheduler.simulate_stream_multi(jobs, infos, [0] * 4,
                                                n_links=2)
    mk_single, _ = scheduler.simulate_stream_finish(jobs, infos)
    assert mk_one == pytest.approx(mk_single, abs=1e-12)
    mk_split, _ = scheduler.simulate_stream_multi(jobs, infos, [0, 1, 0, 1],
                                                  n_links=2)
    assert mk_split <= mk_one + 1e-12


def test_multi_link_scale_and_latency():
    """A slower link stretches only ITS transfers; per-put latency adds per
    chunk on that link."""
    jobs, infos = _jobs()
    assign = [1, 0, 1, 0]          # heavy jobs a, c ride link 1
    base, base_fin = scheduler.simulate_stream_multi(jobs, infos, assign,
                                                     n_links=2)
    slow, _ = scheduler.simulate_stream_multi(
        jobs, infos, assign, n_links=2, link_scale=(1.0, 3.0))
    assert slow > base
    lat, lat_fin = scheduler.simulate_stream_multi(
        jobs, infos, assign, n_links=2, link_latency_s=(0.0, 0.5))
    assert lat > base
    # the untouched link's jobs finish exactly as before
    untouched = [i for i, d in enumerate(assign) if d == 0]
    for i in untouched:
        assert lat_fin[i] == pytest.approx(base_fin[i], abs=1e-12)


def test_multi_shared_host_window_serializes():
    """host_window=1: one shared staging slot forces near-serial behaviour
    even over independent links -- the budget binds across links."""
    jobs, infos = _jobs()
    free, _ = scheduler.simulate_stream_multi(jobs, infos, [0, 1, 0, 1],
                                              n_links=2)
    tight, _ = scheduler.simulate_stream_multi(jobs, infos, [0, 1, 0, 1],
                                               n_links=2, host_window=1)
    assert tight >= free - 1e-12


# ----------------------------------------------------------- planner contract

def _profiles(n=7, seed=0, groups=64):
    rng = np.random.default_rng(seed)
    out = {}
    for i in range(n):
        nb = int(rng.integers(1 << 16, 1 << 21))
        presum = np.linspace(0, nb // 4, groups + 1).astype(np.int64)
        out[f"c{i}"] = ColumnProfile(
            name=f"c{i}", compressed_nbytes=nb, plain_nbytes=nb * 3,
            n_kernels=2, signature=f"s{i % 3}", group_chunkable=True,
            n_groups=groups, group_bytes=float(nb) / groups, group_align=1,
            pattern="np", group_out_presum=presum)
    return out


@pytest.mark.parametrize("n_devices", [1, 2, 4, 8])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_mesh_assignment_dominance(n_devices, seed):
    """Chosen modeled makespan <= round-robin AND single-device baselines on
    every (seed, N) -- they are always among the scored candidates."""
    profiles = _profiles(seed=seed)
    cm = CostModel()
    for p in profiles.values():
        cm.register(p)
    mp = plan_mesh_execution(profiles, cm, n_devices=n_devices)
    mk = mp.modeled_makespan_s
    assert mk <= mp.baselines["round-robin"] + 1e-12
    assert mk <= mp.baselines["single-device"] + 1e-12
    assert mk == pytest.approx(min(mp.baselines.values()), abs=1e-12)
    # every item assigned exactly once, shards only for oversized columns
    assert sorted(mp.assignment[i] for i in mp.items) == sorted(
        mp.assignment.values())
    for col, specs in mp.shards.items():
        assert [s.index for s in specs] == list(range(len(specs)))
        assert specs[0].g_lo == 0
        assert specs[-1].g_hi == profiles[col].n_groups
        for a, b in zip(specs, specs[1:]):
            assert a.g_hi == b.g_lo and a.out_hi == b.out_lo


def test_mesh_plan_covers_all_columns():
    profiles = _profiles()
    cm = CostModel()
    for p in profiles.values():
        cm.register(p)
    mp = plan_mesh_execution(profiles, cm, n_devices=4,
                             shard_threshold_bytes=0)
    assert set(mp.columns()) == set(profiles)
    assert mp.shards        # threshold 0 forces group-span sharding
    per_plan = [it for plan in mp.plans for it in plan.order]
    assert sorted(per_plan) == sorted(mp.items)
    assert shard_column_of(shard_name("x", 3)) == "x"
    assert shard_column_of("plain") == "plain"
    assert SHARD_SEP in shard_name("x", 0)


def test_single_device_mesh_matches_base_planner():
    """N=1 mesh planning degenerates to one plan holding every column."""
    profiles = _profiles(n=4)
    cm = CostModel()
    for p in profiles.values():
        cm.register(p)
    mp = plan_mesh_execution(profiles, cm, n_devices=1)
    assert mp.n_devices == 1 and len(mp.plans) == 1
    assert not mp.shards
    assert sorted(mp.plans[0].order) == sorted(profiles)


# -------------------------------------------------------- topology round-trip

def test_link_topology_save_load_roundtrip(tmp_path):
    cm = CostModel()
    cm.topology = LinkTopology(n_links=4, link_scale=(1.0, 1.25, 1.0, 0.75),
                               link_latency_s=(1e-5, 2e-5, 1e-5, 1e-5),
                               host_window=8)
    path = tmp_path / "cm.json"
    cm.save(str(path))
    cm2 = CostModel.load(str(path))
    assert cm2.topology == cm.topology
    assert cm2.topology.scale(1) == pytest.approx(1.25)
    assert cm2.topology.latency_s(3) == pytest.approx(1e-5)


def test_link_topology_load_ignores_unknown_keys(tmp_path):
    """Old caches (no topology) and FUTURE caches (extra keys) both load."""
    cm = CostModel()
    path = tmp_path / "cm.json"
    cm.save(str(path))
    data = json.loads(path.read_text())
    old = {k: v for k, v in data.items() if k != "topology"}
    path.write_text(json.dumps(old))
    assert CostModel.load(str(path)).topology == LinkTopology()
    data["topology"] = {"n_links": 2, "link_scale": [1.0, 2.0],
                        "from_the_future": {"x": 1}}
    path.write_text(json.dumps(data))
    cm3 = CostModel.load(str(path))
    assert cm3.topology.n_links == 2
    assert cm3.topology.scale(1) == pytest.approx(2.0)

    resized = cm3.topology.resized(3)
    assert resized.n_links == 3 and resized.scale(1) == pytest.approx(2.0)


def test_d2d_topology_roundtrip(tmp_path):
    """Fabric tier persists through save/load; topology blocks written BEFORE
    the D2D tier existed load with the fabric OFF (d2d_copy_s -> inf, so the
    planner never proposes redistribution from a stale cache)."""
    cm = CostModel()
    cm.topology = LinkTopology(n_links=2, link_scale=(1.0, 2.0),
                               d2d_scale=0.12, d2d_latency_s=3e-5)
    path = tmp_path / "cm.json"
    cm.save(str(path))
    cm2 = CostModel.load(str(path))
    assert cm2.topology == cm.topology and cm2.topology.has_fabric
    assert cm2.topology.d2d_copy_s(1.0) == pytest.approx(0.12 + 3e-5)
    data = json.loads(path.read_text())
    for k in ("d2d_scale", "d2d_latency_s"):
        data["topology"].pop(k, None)
    path.write_text(json.dumps(data))
    cm3 = CostModel.load(str(path))
    assert cm3.topology.d2d_scale is None and not cm3.topology.has_fabric
    assert cm3.topology.d2d_copy_s(1.0) == float("inf")
    assert cm3.topology.scale(1) == pytest.approx(2.0)  # link tier survived


def test_observe_d2d_updates_fabric_ewma():
    """Invalid D2D samples are dropped; the first valid one SEEDS the fabric
    scale (turning the tier on), later ones blend with the EWMA alpha."""
    cm = CostModel()
    assert not cm.topology.has_fabric
    for bad in (float("nan"), float("inf"), -1.0, 0.0):
        cm.observe_d2d(bad)
    assert not cm.topology.has_fabric, "invalid samples must not seed"
    cm.observe_d2d(0.2)
    assert cm.topology.d2d_scale == pytest.approx(0.2)
    cm.observe_d2d(0.4)
    assert cm.topology.d2d_scale == pytest.approx(
        0.2 + cm.alpha * (0.4 - 0.2))
    cm.observe_d2d(float("nan"))     # still dropped after seeding
    assert cm.topology.d2d_scale == pytest.approx(
        0.2 + cm.alpha * (0.4 - 0.2))
    # the pricing unit the samples are expressed in: calibrated host-link s
    assert cm.h2d_equiv_s(10_000_000) > cm.h2d_equiv_s(1_000) > 0.0
    assert cm.h2d_equiv_s(0) == 0.0


@pytest.mark.parametrize("n_devices", [2, 4])
@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_redistribute_never_loses_to_decode_in_place(n_devices, seed):
    """placement="sharded" with a fabric: decode-in-place (shards pinned to
    their required device) is ALWAYS a scored candidate, so the chosen plan
    -- fabric copies included -- can only tie or beat it; every proposed leg
    bridges landing to the required placement."""
    profiles = _profiles(seed=seed)
    cm = CostModel()
    for p in profiles.values():
        cm.register(p)
    skew = tuple(4.0 if i == 0 else 1.0 for i in range(n_devices))
    topo = LinkTopology(n_links=n_devices, link_scale=skew, d2d_scale=0.1)
    mp = plan_mesh_execution(profiles, cm, n_devices=n_devices,
                             shard_threshold_bytes=0, topology=topo,
                             placement="sharded")
    assert mp.placement_policy == "sharded"
    assert "no-redistribution" in mp.baselines
    assert mp.modeled_makespan_s <= mp.baselines["no-redistribution"] + 1e-12
    assert mp.modeled_makespan_s == pytest.approx(
        min(mp.baselines[k] for k in mp.baselines if k != "serial-issue"),
        abs=1e-12)
    for item, src, dst in mp.redistribution:
        assert mp.assignment[item] == src and src != dst
        assert mp.placement[item] == dst == mp.final_device(item)
        spec = next(s for ss in mp.shards.values() for s in ss
                    if s.name == item)
        assert dst == spec.index % n_devices
    for specs in mp.shards.values():
        for s in specs:                 # placement honored for EVERY shard
            assert mp.final_device(s.name) == s.index % n_devices
    # no fabric -> redistribution never proposed; any sharded item decodes
    # exactly where it must finally sit
    mp2 = plan_mesh_execution(
        profiles, cm, n_devices=n_devices, shard_threshold_bytes=0,
        topology=LinkTopology(n_links=n_devices, link_scale=skew),
        placement="sharded")
    assert not mp2.redistribution
    for specs in mp2.shards.values():
        for s in specs:
            assert mp2.assignment[s.name] == s.index % n_devices


def test_skewed_link_with_fabric_prefers_redistribution():
    """One 6x-slow host link + a cheap fabric: streaming a pinned shard's
    bytes over the slow link costs more than landing them on a fast link and
    paying one fabric copy -- the plan must carry D2D legs and model a
    strictly better makespan than decode-in-place."""
    profiles = _profiles(n=6, seed=5)
    cm = CostModel()
    for p in profiles.values():
        cm.register(p)
    topo = LinkTopology(n_links=4, link_scale=(6.0, 1.0, 1.0, 1.0),
                        d2d_scale=0.05)
    mp = plan_mesh_execution(profiles, cm, n_devices=4,
                             shard_threshold_bytes=0, topology=topo,
                             placement="sharded")
    assert mp.redistribution, "cheap fabric should beat the 6x link"
    assert mp.modeled_makespan_s < mp.baselines["no-redistribution"] - 1e-12
    assert "redistribute" in mp.policy
    # the legs drain the slow link: no redistributed shard STAYS on link 0
    for item, src, dst in mp.redistribution:
        assert mp.assignment[item] == src != dst


def test_replan_suffix_repartitions_remaining():
    """Device loss mid-stream: completed columns never move; the suffix
    re-plans over the survivors with the topology resized."""
    from repro.launch.elastic import replan_suffix

    profiles = _profiles()
    cm = CostModel()
    for p in profiles.values():
        cm.register(p)
    mp = plan_mesh_execution(profiles, cm, n_devices=4)
    done = list(mp.columns())[:3]
    mp2 = replan_suffix(mp, done, surviving_device_ids=(0, 2, 3),
                        cost_model=cm, profiles=profiles)
    assert mp2.n_devices == 3 and mp2.device_ids == (0, 2, 3)
    assert set(mp2.columns()) == set(profiles) - set(done)
    assert mp2.topology.n_links == 3
    assert mp2.modeled_makespan_s <= mp2.baselines["single-device"] + 1e-12
    # nothing left -> no plan
    assert replan_suffix(mp, list(profiles), (0, 1), cm, profiles) is None


# ------------------------------------------------- multi-device (subprocess)

_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax
import numpy as np
from repro.core import plan as P, planner
from repro.core.compiler import ProgramCache
from repro.core.executor import StreamingExecutor
from repro.core.serve_planner import ServePlanner
from repro.launch.elastic import replan_suffix

assert jax.device_count() == 4

rng = np.random.default_rng(7)
cols = {
    # big skewed ANS chunk grid: group-span shardable, ragged group_words
    "big": np.concatenate([np.zeros(50_000, np.int32),
                           rng.integers(0, 60, 30_000).astype(np.int32)]),
    "rle": np.repeat(rng.integers(0, 50, 400),
                     rng.integers(1, 90, 400)).astype(np.int32),
    # dictionary-fed presum with a bit-packed index leaf: group-streams via
    # the host-pushed presum + span-graft layout
    "sdbp": np.frombuffer(b"the quick brown fox jumps. " * 1500,
                          dtype=np.uint8).copy(),
    "small0": rng.integers(0, 9, 5_000).astype(np.int32),
    "small1": rng.integers(0, 9, 5_000).astype(np.int32),
}
plans = {"big": P.Plan("ans", params={"chunk_size": 512}),
         "rle": P.make_plan("rle"),
         "sdbp": P.Plan("stringdict",
                        children={"index": P.make_plan("bitpack")}),
         "small0": P.Plan("ans", params={"chunk_size": 512}),
         "small1": P.Plan("ans", params={"chunk_size": 512})}
encs = {n: P.encode(plans[n], a) for n, a in cols.items()}

# single-device reference
ref_ex = StreamingExecutor(chunk_bytes=None, cache=ProgramCache())
refs = {n: np.asarray(r.array) for n, r in ref_ex.run(encs).items()}
for n, a in cols.items():
    np.testing.assert_array_equal(refs[n], a)

ex = StreamingExecutor(chunk_bytes="auto", chunk_decode=True,
                       cache=ProgramCache())
for n, e in encs.items():
    ex.compile(n, e)
profiles = {n: ex.column_profile(n) for n in encs}

# sharded vs single-device decode: bitwise, incl. a group-span-sharded column
mp = planner.plan_mesh_execution(profiles, ex.cost_model, n_devices=4,
                                 shard_threshold_bytes=0)
assert "big" in mp.shards and len(mp.shards["big"]) == 4, mp.shards
res = ex.run_sharded(mp, encs)
for n in encs:
    np.testing.assert_array_equal(np.asarray(res[n].array), refs[n],
                                  err_msg=n)
big = res["big"]
assert len(set(big.shard_devices)) > 1, big.shard_devices
assert set(res.device_launches) == set(range(4))
# even-size shards land as one sharding-annotated global array
if len({s.n_out for s in mp.shards["big"]}) == 1:
    assert len(res["big"].array.sharding.device_set) == 4

# elastic re-plan on simulated device loss: survivors decode the suffix
done = [it for it in res.per_device[0]
        if planner.SHARD_SEP not in it]
mp2 = replan_suffix(mp, done, surviving_device_ids=(1, 2, 3),
                    cost_model=ex.cost_model, profiles=profiles,
                    shard_threshold_bytes=0)
res2 = ex.run_sharded(mp2, encs)
for n in mp2.columns():
    np.testing.assert_array_equal(np.asarray(res2[n].array), refs[n],
                                  err_msg=n)
assert set(res2.per_device) <= {1, 2, 3}

# ServePlanner wave spanning 2 devices
sp = ServePlanner(StreamingExecutor(chunk_bytes="auto", chunk_decode=True,
                                    cache=ProgramCache()), mesh=2)
sp.submit("q1", {"big": encs["big"], "small0": encs["small0"]})
sp.submit("q2", {"rle": encs["rle"], "small1": encs["small1"]})
served = sp.drain()
np.testing.assert_array_equal(np.asarray(served["q1"].arrays["big"]),
                              refs["big"])
np.testing.assert_array_equal(np.asarray(served["q2"].arrays["rle"]),
                              refs["rle"])
rep = sp.reports[-1]
assert rep.chosen.startswith("mesh:"), rep.chosen
assert len(rep.devices) == 2 and rep.device_launches, rep

# D2D redistribution: slow host link 0 + cheap fabric, shards pinned to their
# logical device -- decode lands where the links are fast, fabric copies
# bridge to the requested placement; result stays bitwise identical
from repro.core.costmodel import LinkTopology
topo = LinkTopology(n_links=4, link_scale=(6.0, 1.0, 1.0, 1.0),
                    d2d_scale=0.05)
mp3 = planner.plan_mesh_execution(profiles, ex.cost_model, n_devices=4,
                                  shard_threshold_bytes=0, topology=topo,
                                  placement="sharded")
assert mp3.redistribution, "skewed link + cheap fabric should rebalance"
res3 = ex.run_sharded(mp3, encs)
for n in encs:
    np.testing.assert_array_equal(np.asarray(res3[n].array), refs[n],
                                  err_msg=n)
# every executed leg matches a plan leg, physical src != dst, copy timed
legs = {it: (src, dst) for it, src, dst in mp3.redistribution}
assert set(res3.d2d_copies) == set(legs), (res3.d2d_copies, legs)
for it, (src_id, dst_id, secs) in res3.d2d_copies.items():
    want_src, want_dst = legs[it]
    assert src_id == mp3.device_ids[want_src], it
    assert dst_id == mp3.device_ids[want_dst], it
    assert src_id != dst_id and secs >= 0.0
# assembled shards sit on the REQUESTED placement devices, and even-size
# sharded columns carry the matching NamedSharding over those devices
devs = jax.devices()
for col, specs in mp3.shards.items():
    rec = res3[col]
    want = tuple(int(mp3.device_ids[mp3.final_device(s.name)])
                 for s in specs)
    assert rec.shard_devices == want, (col, rec.shard_devices, want)
    if len({s.n_out for s in specs}) == 1:
        mesh_devs = list(rec.array.sharding.mesh.devices.flat)
        assert [d.id for d in mesh_devs] == list(want), col
# the measured copies seeded/updated the fabric EWMA
assert ex.cost_model.topology.has_fabric
print("MESH_OK")
"""


def test_mesh_decode_subprocess():
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _SUBPROC], env=env,
                         capture_output=True, text=True, timeout=420)
    assert "MESH_OK" in out.stdout, out.stdout + "\n" + out.stderr[-3000:]


# -------------------------------------------------------- ragged ANS stripes

def test_ragged_stripe_row_caps_bitexact():
    """Skewed ANS chunk grid: the schedule caps stripe rows per span (saving
    transfer bytes vs the padded layout) and decode stays bitwise exact."""
    from repro.core import plan as P
    from repro.core.compiler import ProgramCache
    from repro.core.executor import ROW_CAP_QUANTUM, StreamingExecutor

    rng = np.random.default_rng(3)
    arr = np.concatenate([np.zeros(40_000, np.int32),
                          rng.integers(0, 60, 25_000).astype(np.int32)])
    enc = P.encode(P.Plan("ans", params={"chunk_size": 512}), arr)
    ex = StreamingExecutor(chunk_bytes=1 << 14, chunk_decode=True,
                           cache=ProgramCache())
    ex.compile("c", enc)
    sched = ex.chunk_schedule("c")
    assert sched is not None and sched.kind == "group"
    assert sched.row_caps, "skewed ANS stripe should carry row caps"
    ops = P.host_operands(enc)
    saved = 0
    for nm, caps in sched.row_caps.items():
        full = int(np.asarray(ops[nm]).shape[0])
        assert all(1 <= c <= full for c in caps)
        assert any(c < full for c in caps), (caps, full)
        assert all(c == full or c % ROW_CAP_QUANTUM == 0 for c in caps)
        for k, (lo, hi) in enumerate(sched.slices[nm]):
            saved += (full - caps[k]) * (hi - lo)
            piece = sched.piece(np.asarray(ops[nm]), nm, k)
            assert piece.shape == (caps[k], hi - lo)
    assert saved > 0
    res = ex.run({"c": enc})["c"]
    assert res.chunk_decoded
    np.testing.assert_array_equal(np.asarray(res.array), arr)
