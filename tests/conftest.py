"""Shared fixtures.  NOTE: no XLA_FLAGS here by design -- smoke tests and benches
must see the single real CPU device; only launch/dryrun.py forces 512 devices."""
import os

import numpy as np
import pytest


def pytest_configure(config):
    """Opt-in hang watchdog for threaded-executor tests (the async dispatch
    engine runs worker threads; a deadlock would otherwise hang CI silently
    until the job-level timeout with no stacks).  REPRO_TEST_TIMEOUT_S=<secs>
    arms faulthandler to dump EVERY thread's traceback and hard-exit once the
    whole pytest run exceeds the budget -- the dump names the blocked thread,
    which a plain timeout kill never would."""
    secs = os.environ.get("REPRO_TEST_TIMEOUT_S")
    if secs:
        import faulthandler

        faulthandler.dump_traceback_later(float(secs), exit=True)


@pytest.fixture
def rng():
    return np.random.default_rng(0)
