"""Johnson's-rule pipelining scheduler (paper §3.3): optimality vs brute force,
makespan properties, and the paper's Fig. 8 example shape."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.scheduler import (Job, brute_force_best, johnson_order, makespan,
                                  serial_time)

times = st.floats(min_value=0.01, max_value=10.0, allow_nan=False)


@settings(max_examples=60, deadline=None)
@given(st.lists(st.tuples(times, times), min_size=1, max_size=6))
def test_johnson_is_optimal(pairs):
    jobs = [Job(str(i), a, b) for i, (a, b) in enumerate(pairs)]
    best, _ = brute_force_best(jobs)
    got = makespan(jobs, johnson_order(jobs))
    assert got <= best + 1e-9


@settings(max_examples=60, deadline=None)
@given(st.lists(st.tuples(times, times), min_size=1, max_size=8))
def test_pipeline_never_worse_than_serial(pairs):
    jobs = [Job(str(i), a, b) for i, (a, b) in enumerate(pairs)]
    assert makespan(jobs, johnson_order(jobs)) <= serial_time(jobs) + 1e-9


@settings(max_examples=40, deadline=None)
@given(st.lists(st.tuples(times, times), min_size=1, max_size=8))
def test_makespan_lower_bounds(pairs):
    jobs = [Job(str(i), a, b) for i, (a, b) in enumerate(pairs)]
    m = makespan(jobs, johnson_order(jobs))
    assert m >= sum(j.transfer_s for j in jobs) - 1e-9      # link is serial
    assert m >= max(j.transfer_s + j.decompress_s for j in jobs) - 1e-9


def test_fig8_order_b_before_a():
    """Paper Fig. 8: A = high transfer / fast decompress; B = the converse.
    Johnson runs B (transfer-light) first."""
    a = Job("A", transfer_s=4.0, decompress_s=1.0)
    b = Job("B", transfer_s=1.0, decompress_s=4.0)
    order = johnson_order([a, b])
    assert order == [1, 0]
    assert makespan([a, b], [1, 0]) < makespan([a, b], [0, 1])
