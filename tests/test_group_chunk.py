"""Group-boundary chunked decode (the CHUNK_GROUP tentpole).

Pins the invariants: (1) group-chunked streaming decode is bitwise-identical to
whole-column decode for Group-Parallel (RLE, DeltaStride) and Non-Parallel (ANS)
graphs, including uneven tail spans and the ANS end-of-stream trim; (2) the
planner's profile mirrors the executor's schedule (planned span counts ==
executed launches) and selects chunk mode for a CHUNK_GROUP graph when the
model favors it; (3) the geometry-tied candidate ladder is actually aligned --
element candidates to kernel tile multiples, group candidates to group-boundary
prefix sums; (4) body/tail span programs are shared across same-structure
columns; (5) cost-model persistence round-trips scales + per-signature timings.
"""
import dataclasses
import json

import numpy as np
import pytest

from repro.core import plan as P
from repro.core.compiler import ProgramCache
from repro.core.costmodel import (ColumnProfile, CostModel,
                                  aligned_chunk_elems, groups_per_chunk)
from repro.core.executor import StreamingExecutor
from repro.core.geometry import native_subtile
from repro.core.ir import CHUNK_GROUP, group_chunk_layout
from repro.core.planner import CHUNK, plan_execution

mp = P.make_plan


def _rle_column(rng, n_groups=500, max_run=120):
    return np.repeat(rng.integers(0, 50, n_groups),
                     rng.integers(1, max_run, n_groups)).astype(np.int32)


# ----------------------------------------------------------- bitwise identity

def test_rle_group_chunk_bitexact(rng):
    """Skewed run lengths + uneven tail span: group-chunked == whole-column."""
    arr = _rle_column(rng, n_groups=501)
    enc = P.encode(mp("rle"), arr)
    whole = StreamingExecutor(chunk_bytes=None, cache=ProgramCache())
    chunked = StreamingExecutor(chunk_bytes=256, chunk_decode=True,
                                cache=ProgramCache())
    chunked.compile("c", enc)
    assert chunked.graph("c").chunkability == CHUNK_GROUP
    sched = chunked.chunk_schedule("c")
    assert sched is not None and sched.kind == "group" and sched.n_chunks > 2
    assert sched.g_sizes[-1] < sched.g_sizes[0]        # uneven tail span
    a = np.asarray(whole.run({"c": enc})["c"].array)
    res = chunked.run({"c": enc})["c"]
    assert res.chunk_decoded and res.decode_launches > 2
    np.testing.assert_array_equal(np.asarray(res.array), a)
    np.testing.assert_array_equal(np.asarray(res.array), arr)


def test_ans_group_chunk_bitexact(rng):
    """ANS chunk-grid spans (stripe column slices): bit-exact incl. the
    end-of-stream trim, for multi-byte and single-byte dtypes."""
    for dtype, n, cb in ((np.int32, 30_000, 4096), (np.uint8, 3_001, 512)):
        arr = rng.integers(0, 40, n).astype(dtype)
        enc = P.encode(P.Plan("ans", params={"chunk_size": 512}), arr)
        ex = StreamingExecutor(chunk_bytes=cb, chunk_decode=True,
                               cache=ProgramCache())
        ex.compile("c", enc)
        assert ex.graph("c").chunkability == CHUNK_GROUP
        res = ex.run({"c": enc})["c"]
        assert res.chunk_decoded and res.decode_launches > 1, dtype
        np.testing.assert_array_equal(np.asarray(res.array), arr)
        np.testing.assert_array_equal(np.asarray(res.array), P.decode_np(enc))


def test_deltastride_group_chunk_bitexact(rng):
    mono = np.arange(80_000, dtype=np.int32)
    mono[17::97] += 3
    enc = P.encode(mp("deltastride"), mono)
    ex = StreamingExecutor(chunk_bytes=2048, chunk_decode=True,
                           cache=ProgramCache())
    res = ex.run({"c": enc})["c"]
    assert res.chunk_decoded and res.decode_launches > 1
    np.testing.assert_array_equal(np.asarray(res.array), mono)


def test_group_chunk_programs_shared_across_columns(rng):
    """Same-structure RLE columns share prologue + body/tail span programs."""
    cache = ProgramCache()
    ex = StreamingExecutor(chunk_bytes=256, chunk_decode=True, cache=cache)
    counts = rng.integers(1, 60, 400)
    # values cycle so no adjacent runs merge: every column has exactly 400
    # groups with the same counts -> identical structure (and signature)
    encs = {f"c{i}": P.encode(mp("rle"),
                              np.repeat((np.arange(400) + i) % 50,
                                        counts).astype(np.int32))
            for i in range(3)}
    results = ex.run(encs)
    for n, enc in encs.items():
        assert results[n].chunk_decoded, n
        np.testing.assert_array_equal(np.asarray(results[n].array),
                                      P.decode_np(enc))
    # whole program (compile) + prologue + body + tail span programs, shared:
    # 3 columns x K spans hit <= 4 cache entries
    assert cache.stats["misses"] <= 4
    assert cache.stats["hits"] >= 2 * (results["c0"].decode_launches - 2)


# ------------------------------------------------------------ planner mirror

def test_planner_mirrors_executor_span_counts(rng):
    """Profile-predicted span counts == executed decode launches (minus the
    one-shot prologue), through a real plan round trip."""
    arr = _rle_column(rng, n_groups=800)
    ans = rng.integers(0, 40, 60_000).astype(np.int32)
    ex = StreamingExecutor(chunk_bytes="auto", chunk_decode=True,
                           policy="adaptive", cache=ProgramCache())
    ex.compile("rle", P.encode(mp("rle"), arr))
    ex.compile("ans", P.encode(P.Plan("ans", params={"chunk_size": 1024}), ans))
    # inject measurements WITHOUT calibration (scales stay 1.0) so the modeled
    # launch overhead is the raw chip estimate and overlap wins
    ex.cost_model.measured["rle"] = (0.05, 0.05)
    ex.cost_model.measured["ans"] = (0.04, 0.06)
    ep = ex.plan()
    assert ep.decisions["rle"].decode_mode == CHUNK
    assert ep.decisions["ans"].decode_mode == CHUNK
    assert ep.modeled_makespan_s <= min(ep.baselines.values()) + 1e-9
    res = ex.run(plan=ep)
    for n, extra in (("rle", 1), ("ans", 0)):       # rle has a presum prologue
        d, r = ep.decisions[n], res[n]
        assert r.chunk_decoded, n
        assert r.decode_launches == d.n_chunks + extra, n
    np.testing.assert_array_equal(np.asarray(res["rle"].array), arr)
    np.testing.assert_array_equal(np.asarray(res["ans"].array), ans)


def test_chunk_decision_carries_uneven_weights(rng):
    """Group decisions model per-chunk byte counts, not uniform splits: the
    whole-resident bytes land ahead of span 0 and decode follows the
    group-boundary prefix sums."""
    arr = _rle_column(rng, n_groups=600)
    ex = StreamingExecutor(chunk_bytes=512, chunk_decode=True,
                           policy="chunk-johnson", cache=ProgramCache())
    ex.compile("rle", P.encode(mp("rle"), arr))
    ex.cost_model.measured["rle"] = (0.05, 0.05)
    ep = ex.plan()
    d = ep.decisions["rle"]
    assert d.decode_mode == CHUNK and len(d.weights) == d.n_chunks
    t, dws = zip(*d.weights)
    assert t[0] > t[1]                      # span 0 carries the resident bytes
    assert abs(sum(t) - 1.0) < 1e-9 and abs(sum(dws) - 1.0) < 1e-9
    sched = ex.chunk_schedule("rle", d.chunk_bytes)
    np.testing.assert_allclose(
        dws, np.asarray(sched.out_sizes) / sum(sched.out_sizes), rtol=1e-9)


# ----------------------------------------------------------- geometry ladder

def test_geometry_ladder_is_aligned():
    """Element candidates snap to kernel tile multiples, group candidates to
    group-boundary (alignment-multiple) spans -- under the same shared formulas
    the executor slices with."""
    cm = CostModel()
    tile = native_subtile("fp", cm.spec.name)
    elem_p = ColumnProfile(
        name="e", compressed_nbytes=1 << 22, plain_nbytes=1 << 24, n_kernels=1,
        signature="sig-e", leaves=((1 << 20, 1 << 22),), chunkable=True,
        n_out=1 << 22, per_elem_bytes=1.0, align=32)
    ladder = cm.chunk_ladder(elem_p)
    assert ladder, "element ladder must not be empty"
    for cb in ladder:
        elems = aligned_chunk_elems(cb, elem_p.per_elem_bytes, elem_p.align)
        assert elems % tile == 0 and elems % elem_p.align == 0, (cb, elems)
    presum = np.arange(0, 4097 * 7, 7, dtype=np.int64)
    group_p = ColumnProfile(
        name="g", compressed_nbytes=1 << 16, plain_nbytes=1 << 20, n_kernels=2,
        signature="sig-g", leaves=((4096, 1 << 16),), group_chunkable=True,
        n_out=int(presum[-1]), n_groups=4096, group_bytes=4.0, group_align=8,
        pattern="gp", group_out_presum=presum)
    gladder = cm.chunk_ladder(group_p)
    assert gladder, "group ladder must not be empty"
    for cb in gladder:
        g = groups_per_chunk(cb, group_p.group_bytes, group_p.group_align)
        assert g % group_p.group_align == 0 and g < group_p.n_groups, (cb, g)


def test_ladder_prunes_overhead_dominated_candidates():
    """After calibration inflates the launch-overhead estimate, tiny candidates
    (per-chunk decode < 2x overhead) drop off the ladder."""
    cm = CostModel()
    p = ColumnProfile(
        name="e", compressed_nbytes=1 << 20, plain_nbytes=1 << 22, n_kernels=4,
        signature="s", leaves=((1 << 18, 1 << 20),), chunkable=True,
        n_out=1 << 20, per_elem_bytes=1.0, align=8)
    cm.register(p)
    full = cm.chunk_ladder(p)
    cm.observe("e", 0.1, 0.1)               # decode_scale explodes (CPU-like)
    pruned = cm.chunk_ladder(p)
    assert len(pruned) <= len(full)
    assert min(pruned) >= min(full)


# ------------------------------------------------------------- persistence

def test_cost_model_save_load_roundtrip(rng, tmp_path):
    """A fresh process (new CostModel) plans from persisted history: scales and
    per-signature timing summaries survive; predictions for a same-structure
    column match the stored means."""
    arr = _rle_column(rng, n_groups=300)
    enc = P.encode(mp("rle"), arr)
    ex = StreamingExecutor(chunk_bytes=None, cache=ProgramCache())
    ex.run({"c": enc})
    cm = ex.cost_model
    path = str(tmp_path / "cost.json")
    cm.save(path)
    with open(path) as f:
        data = json.load(f)
    assert data["n_observed"] >= 1 and data["signatures"]

    cm2 = CostModel.load(path)
    assert cm2.n_observed == cm.n_observed
    assert cm2.transfer_scale == pytest.approx(cm.transfer_scale)
    assert cm2.decode_scale == pytest.approx(cm.decode_scale)
    # a fresh executor over the SAME structure predicts the persisted means
    ex2 = StreamingExecutor(chunk_bytes=None, cache=ProgramCache(),
                            cost_model=cm2)
    ex2.compile("fresh", P.encode(mp("rle"), arr))
    sig = ex2.graph("fresh").signature
    assert sig in cm2.sig_stats
    t, d = cm2.predict("fresh")
    assert t == pytest.approx(cm2.sig_stats[sig]["transfer_s"])
    assert d == pytest.approx(cm2.sig_stats[sig]["decode_s"])
    # and jobs() stays in consistent wall-clock units without re-measuring
    jobs = cm2.jobs(["fresh"])
    assert jobs[0].transfer_s == pytest.approx(t)


def test_plan_survives_forced_whole_mode(rng):
    """Forcing whole decode through the plan bypasses group chunking."""
    arr = _rle_column(rng, n_groups=400)
    enc = P.encode(mp("rle"), arr)
    ex = StreamingExecutor(chunk_bytes=256, chunk_decode=True,
                           cache=ProgramCache())
    ex.compile("c", enc)
    ep = ex.plan()
    from repro.core.planner import WHOLE
    whole = dataclasses.replace(
        ep, decisions={n: dataclasses.replace(d, decode_mode=WHOLE)
                       for n, d in ep.decisions.items()})
    res = ex.run({"c": enc}, plan=whole)["c"]
    assert not res.chunk_decoded and res.decode_launches == 1
    np.testing.assert_array_equal(np.asarray(res.array), arr)


def test_tpch_group_columns_bitexact_under_auto_plan():
    """TPC-H: every column decodes bit-identically under the adaptive auto
    plan, and the ANS column (L_RETURNFLAG) is group-chunkable."""
    from repro.data.columns import TABLE2_PLANS
    from repro.data.loader import ColumnPipeline
    from repro.data.tpch import generate

    cols = generate(scale=0.002, seed=5)
    names = ["L_RETURNFLAG", "L_ORDERKEY", "L_QUANTITY"]
    pipe = ColumnPipeline({n: TABLE2_PLANS[n] for n in names},
                          chunk_bytes="auto", chunk_decode=True,
                          policy="adaptive")
    pipe.compress({n: cols[n] for n in names})
    assert pipe.executor.graph("L_RETURNFLAG").chunkability == CHUNK_GROUP
    assert group_chunk_layout(pipe.executor.graph("L_RETURNFLAG")) is not None
    results = pipe.run()
    for n in names:
        np.testing.assert_array_equal(np.asarray(results[n].array), cols[n],
                                      err_msg=n)
    ep = pipe.plan()
    assert ep.modeled_makespan_s <= min(ep.baselines.values()) + 1e-9
    # force the group-streamed path on the ANS column (span = one group) and
    # compare bit-for-bit against the whole-column result
    enc = P.encode(TABLE2_PLANS["L_RETURNFLAG"], cols["L_RETURNFLAG"])
    ex = StreamingExecutor(chunk_bytes=256, chunk_decode=True,
                           cache=ProgramCache())
    res = ex.run({"c": enc})["c"]
    assert res.chunk_decoded and res.decode_launches > 1
    np.testing.assert_array_equal(np.asarray(res.array), cols["L_RETURNFLAG"])
