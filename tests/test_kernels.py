"""Per-kernel Pallas (interpret=True) vs pure-jnp oracle, sweeping shapes, dtypes and
<L,S,C> geometries -- the assignment's per-kernel allclose requirement."""
import numpy as np
import pytest

from repro.core import plan as P
from repro.core.compiler import compile_decoder, device_buffers
from repro.core.geometry import Geometry

mp = P.make_plan

GEOMS = [Geometry(1, 8, 128), Geometry(2, 8, 128), Geometry(1, 16, 256),
         Geometry(4, 8, 512)]


def check(pl, arr, geom):
    enc = P.encode(pl, arr)
    bufs = device_buffers(enc)
    ref = compile_decoder(enc, backend="jnp", fuse=True)(bufs)
    geoms = {"fp": geom, "gp": geom, "np": Geometry(1, 8, geom.C)}
    got = compile_decoder(enc, backend="pallas", fuse=True, geometry=geoms,
                          interpret=True)(bufs)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
    np.testing.assert_array_equal(np.asarray(got), arr)


@pytest.mark.parametrize("geom", GEOMS, ids=str)
@pytest.mark.parametrize("bw", [1, 3, 7, 8, 13, 17, 25, 31, 32])
def test_fully_parallel_bitpack_bitwidths(geom, bw, rng):
    n = 5000
    arr = rng.integers(0, 2**bw - 1 if bw < 32 else 2**31 - 1, n,
                       dtype=np.int64).astype(np.int32)
    check(mp("bitpack"), arr, geom)


@pytest.mark.parametrize("geom", GEOMS[:2], ids=str)
@pytest.mark.parametrize("n", [1, 7, 127, 1024, 4097, 70000])
def test_fully_parallel_sizes(geom, n, rng):
    arr = rng.integers(-1000, 1000, n).astype(np.int32)
    check(mp("bitpack"), arr, geom)


@pytest.mark.parametrize("dtype", [np.int32, np.float32])
def test_fully_parallel_dtypes(dtype, rng):
    if dtype == np.float32:
        arr = (rng.integers(0, 10**6, 3000) / 100).astype(np.float32)
        check(P.Plan("float2int", children={"ints": mp("bitpack")}), arr,
              GEOMS[0])
    else:
        arr = rng.integers(0, 100, 3000).astype(np.int32)
        check(P.Plan("dictionary", children={"index": mp("bitpack")}), arr,
              GEOMS[0])


@pytest.mark.parametrize("geom", GEOMS, ids=str)
@pytest.mark.parametrize("dist", ["even2", "even64", "random", "outlier"])
def test_group_parallel_distributions(geom, dist, rng):
    """Paper Fig. 13's group-size distributions through the balanced kernel."""
    if dist == "even2":
        counts = np.full(500, 2)
    elif dist == "even64":
        counts = np.full(50, 64)
    elif dist == "random":
        counts = rng.integers(1, 64, 300)
    else:  # outlier: mostly 1s with rare huge groups
        counts = np.where(rng.random(400) < 0.02, 1024, 1)
    values = rng.integers(0, 1000, counts.size).astype(np.int32)
    arr = np.repeat(values, counts).astype(np.int32)
    check(P.Plan("rle", children={"counts": mp("bitpack"),
                                  "values": mp("bitpack")}), arr, geom)


@pytest.mark.parametrize("geom", GEOMS[:2], ids=str)
def test_group_parallel_stringdict(geom, rng):
    words = [b"alpha", b"beta", b"gamma.", b"d"]
    text = b" ".join(rng.choice(words, 800))
    arr = np.frombuffer(text, np.uint8).copy()
    check(P.Plan("stringdict", children={"index": mp("bitpack")}), arr, geom)


def test_group_parallel_deltastride(rng):
    arr = np.sort(rng.choice(10**6, 5000, replace=False)).astype(np.int32)
    check(mp("deltastride"), arr, GEOMS[0])


@pytest.mark.parametrize("chunk", [256, 1024])
@pytest.mark.parametrize("skew", [0.34, 0.9])
def test_non_parallel_ans(chunk, skew, rng):
    arr = rng.choice(np.arange(3, dtype=np.uint8) + 65, 20000,
                     p=[skew, (1 - skew) / 2, (1 - skew) / 2]).astype(np.uint8)
    check(P.Plan("ans", params={"chunk_size": chunk}), arr, GEOMS[0])


def test_non_parallel_ans_int32(rng):
    arr = rng.integers(0, 50, 6000).astype(np.int32)
    check(P.Plan("ans", params={"chunk_size": 512}), arr, GEOMS[1])


def test_fused_chain_pallas(rng):
    """dict|bitpack fuses to ONE kernel and still matches (Fig. 7(c))."""
    arr = rng.choice([3, 7, 11, 900], 4000).astype(np.int32)
    enc = P.encode(P.Plan("dictionary", children={"index": mp("bitpack")}), arr)
    dec = compile_decoder(enc, backend="pallas", fuse=True,
                          geometry={"fp": GEOMS[0], "gp": GEOMS[0],
                                    "np": GEOMS[0]}, interpret=True)
    assert dec.n_kernels == 1
    np.testing.assert_array_equal(np.asarray(dec(device_buffers(enc))), arr)
