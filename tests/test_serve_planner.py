"""Multi-query serving planner: shared transfer queue, cross-query batching,
SLO ordering, preemption, and the extended per-job simulator."""
import threading

import numpy as np
import pytest

from repro.core import plan as P, scheduler
from repro.core.executor import StreamingExecutor
from repro.core.serve_planner import ServePlanner, qualify, rid_of
from repro.data.columns import TABLE2_PLANS
from repro.data.tpch import QUERY_COLUMNS, generate


@pytest.fixture(scope="module")
def cols():
    return generate(scale=0.002, seed=0)


def encs_for(cols, names):
    """Fresh blobs per call: distinct requests ship distinct buffers."""
    return {n: P.encode(TABLE2_PLANS[n], cols[n]) for n in names}


def make_executor(**kw):
    kw.setdefault("chunk_bytes", "auto")
    kw.setdefault("chunk_decode", True)
    kw.setdefault("policy", "adaptive")
    return StreamingExecutor(**kw)


# ------------------------------------------------------------ simulator


def test_simulate_stream_finish_consistent():
    jobs = [scheduler.Job("a", 3.0, 1.0), scheduler.Job("b", 1.0, 4.0),
            scheduler.Job("c", 2.0, 2.0)]
    infos = [scheduler.ChunkInfo(n_chunks=4, chunk_decode=True),
             scheduler.ChunkInfo(), scheduler.ChunkInfo(n_chunks=3)]
    for order in ([0, 1, 2], [2, 0, 1], [1, 2, 0]):
        for window in (None, 2):
            mk, fin = scheduler.simulate_stream_finish(jobs, infos, order,
                                                       window)
            assert mk == scheduler.simulate_stream(jobs, infos, order, window)
            assert max(fin) == mk
            # completion order follows issue order
            assert sorted(range(3), key=lambda i: fin[i]) == list(order)
    # default infos reduce exactly to the classic two-machine makespan
    mk, fin = scheduler.simulate_stream_finish(jobs)
    assert mk == pytest.approx(scheduler.makespan(jobs))


def test_qualify_roundtrip():
    assert qualify("r1", "L_TAX") == "r1/L_TAX"
    assert rid_of("r1/L_TAX") == "r1"
    assert rid_of("r1/weird/col") == "r1"


# ------------------------------------------------ correctness under sharing


def test_concurrent_submissions_bitwise_identical_to_serial(cols):
    """Many threads submit at once; ONE shared wave must decode every column
    bitwise-identically to each request run serially on its own."""
    mixes = [QUERY_COLUMNS[1], QUERY_COLUMNS[6], QUERY_COLUMNS[13],
             QUERY_COLUMNS[6]]
    all_encs = [encs_for(cols, names) for names in mixes]
    planner = ServePlanner(make_executor(), policy="shared")
    errs = []

    def submit(i):
        try:
            planner.submit(f"r{i}", all_encs[i])
        except Exception as e:          # pragma: no cover
            errs.append(e)

    threads = [threading.Thread(target=submit, args=(i,))
               for i in range(len(mixes))]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    done = planner.drain()
    assert set(done) == {f"r{i}" for i in range(len(mixes))}

    # serial reference: each request decoded alone on a fresh executor
    serial_ex = make_executor()
    for i, encs in enumerate(all_encs):
        res = serial_ex.run({f"s/{n}": e for n, e in encs.items()})
        req = done[f"r{i}"]
        assert req.done
        for n, enc in encs.items():
            shared_arr = np.asarray(req.results[n].array)
            np.testing.assert_array_equal(shared_arr,
                                          np.asarray(res[f"s/{n}"].array))
            np.testing.assert_array_equal(shared_arr, P.decode_np(enc))
        for n in [f"s/{c}" for c in encs]:
            serial_ex.unregister(n)
    # per-request state is gone; signature calibration history survives
    assert not planner.executor._encoded
    assert planner.executor.cost_model.sig_stats


def test_dedup_identical_blob_decodes_once(cols):
    enc = P.encode(TABLE2_PLANS["L_TAX"], cols["L_TAX"])
    planner = ServePlanner(make_executor(), policy="shared")
    planner.submit("a", {"L_TAX": enc})
    planner.submit("b", {"L_TAX": enc})
    done = planner.drain()
    ra, rb = done["a"].results["L_TAX"], done["b"].results["L_TAX"]
    assert ra is rb                      # one decode fanned out, not two
    np.testing.assert_array_equal(np.asarray(ra.array), P.decode_np(enc))


# ----------------------------------------------------- cross-query batching


def test_cross_query_batching_reduces_launches(cols):
    """Same-signature columns from different requests decode in one vmap
    launch under the shared plan; per-query execution cannot do that."""
    mixes = [QUERY_COLUMNS[6], QUERY_COLUMNS[6], QUERY_COLUMNS[1]]
    blobs = [encs_for(cols, names) for names in mixes]

    shared = ServePlanner(make_executor(), policy="shared")
    for i, encs in enumerate(blobs):
        shared.submit(f"r{i}", encs)
    shared.drain()
    rep = shared.reports[-1]

    naive = ServePlanner(make_executor(), policy="fifo-per-query", max_wave=1)
    for i, encs in enumerate([encs_for(cols, names) for names in mixes]):
        naive.submit(f"r{i}", encs)
    naive.drain()
    naive_launches = sum(r.decode_launches for r in naive.reports)

    assert rep.decode_launches < naive_launches
    # the saved-launch counter is derived from cross-rid batched groups, so
    # cross_batched_saved > 0 proves a group spanned requests
    assert rep.cross_batched_saved > 0
    assert rep.naive_makespan_s >= rep.shared_makespan_s


def test_shared_makespan_never_exceeds_naive_composition(cols):
    mixes = [QUERY_COLUMNS[1], QUERY_COLUMNS[13], QUERY_COLUMNS[6],
             QUERY_COLUMNS[6]]
    planner = ServePlanner(make_executor(), policy="shared")
    for i, names in enumerate(mixes):
        planner.submit(f"r{i}", encs_for(cols, names))
    planner.drain()
    rep = planner.reports[-1]
    assert rep.shared_makespan_s <= rep.naive_makespan_s * (1 + 1e-9)
    assert "fifo-per-query" in rep.candidates
    assert rep.naive_makespan_s == pytest.approx(
        rep.candidates["fifo-per-query"])
    # every request got a modeled completion under both compositions
    for i in range(len(mixes)):
        assert rep.modeled_finish_s[f"r{i}"] > 0
        assert rep.naive_finish_s[f"r{i}"] > 0
    assert max(rep.modeled_finish_s.values()) == pytest.approx(
        rep.shared_makespan_s)


# ------------------------------------------------------------ SLO + preempt


def test_slo_policy_bounds_point_latency_under_bulk(cols):
    planner = ServePlanner(make_executor(), policy="slo")
    planner.submit("bulk", encs_for(cols, QUERY_COLUMNS[1]), klass="bulk")
    planner.submit("pt", encs_for(cols, ["O_ORDERKEY"]), klass="point")
    done = planner.drain()
    rep = planner.reports[-1]
    # the point query's simulated completion never degrades past the naive
    # per-query FIFO composition, and it beats the bulk scan's
    assert rep.modeled_finish_s["pt"] <= rep.naive_finish_s["pt"] * (1 + 1e-9)
    assert rep.modeled_finish_s["pt"] < rep.modeled_finish_s["bulk"]
    for rid in ("bulk", "pt"):
        for c, rec in done[rid].results.items():
            np.testing.assert_array_equal(np.asarray(rec.array),
                                          P.decode_np(done[rid].encs[c]))


def test_executor_preempt_hook_fires_at_chunk_boundaries(cols):
    """The executor's preempt hook yields at chunk boundaries; a nested run
    on the SAME executor completes there and stays bitwise-correct."""
    ex = StreamingExecutor(chunk_bytes=1 << 13, chunk_decode=True,
                           policy="adaptive")
    bulk = {f"bulk/{n}": P.encode(TABLE2_PLANS[n], cols[n])
            for n in QUERY_COLUMNS[6]}
    pt_enc = P.encode(TABLE2_PLANS["O_ORDERKEY"], cols["O_ORDERKEY"])
    calls = {"n": 0}
    nested = {}

    def preempt():
        calls["n"] += 1
        if calls["n"] == 1:             # point query cuts in exactly once
            nested["res"] = ex.run_one(pt_enc, name="pt/O_ORDERKEY")

    res = ex.run(bulk, preempt=preempt)
    assert calls["n"] >= 1
    np.testing.assert_array_equal(np.asarray(nested["res"]),
                                  P.decode_np(pt_enc))
    for qn, enc in bulk.items():
        np.testing.assert_array_equal(np.asarray(res[qn].array),
                                      P.decode_np(enc))


def test_preemptive_wave_services_point_mid_drain(cols):
    """A point request arriving while a bulk wave is executing is serviced by
    a nested preemptive wave at the next yield point (deterministically
    driven through the planner's preempt callback)."""
    planner = ServePlanner(make_executor(), policy="slo")
    pt_encs = encs_for(cols, ["O_ORDERKEY"])
    planner.submit("pt-late", pt_encs, klass="point")
    planner._in_wave = True             # as if a bulk wave were mid-run
    try:
        planner._preempt()
    finally:
        planner._in_wave = False
    assert planner.pending == 0
    done = planner.drain()              # nothing pending; returns the served
    assert "pt-late" in done
    req = done["pt-late"]
    assert req.done and req.preempted_in
    np.testing.assert_array_equal(np.asarray(req.results["O_ORDERKEY"].array),
                                  P.decode_np(pt_encs["O_ORDERKEY"]))


def test_on_ready_fires_for_every_column(cols):
    ex = make_executor()
    encs = {f"r/{n}": P.encode(TABLE2_PLANS[n], cols[n])
            for n in QUERY_COLUMNS[6]}
    ready = []
    ex.run(encs, on_ready=ready.append)
    assert sorted(ready) == sorted(encs)


# ------------------------------------------------------------ serve engine


def test_serve_engine_compressed_prompts_and_empty_prompt():
    import jax

    from repro.configs import SMOKES
    from repro.core.plan import make_plan
    from repro.models import get_model
    from repro.serve.engine import Request, ServeEngine

    cfg = SMOKES["qwen1.5-0.5b"]
    model = get_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, batch_slots=2, max_len=64, eos=-1)
    rng = np.random.default_rng(0)
    prompts = {rid: rng.integers(0, cfg.vocab, 4).astype(np.int32)
               for rid in range(2)}
    plan = make_plan("bitpack")
    for rid, toks in prompts.items():
        eng.submit_compressed(rid, P.encode(plan, toks), max_new=3)
    # an empty prompt must not crash admission (previously: NameError)
    eng.submit(Request(9, np.zeros((0,), np.int32), max_new=3))
    done = eng.run_to_completion(max_steps=60)
    assert set(done) == {0, 1, 9}
    assert all(len(v) == 3 for v in done.values())
    # compressed prompts round-tripped exactly into the requests
    for rid, toks in prompts.items():
        req = next(r for r in eng._requests if r.rid == rid)
        np.testing.assert_array_equal(req.prompt, toks)
    # both compressed prompts decoded through the shared serving planner
    assert eng.planner.reports
    assert eng.planner.pending == 0
