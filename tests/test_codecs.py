"""Roundtrip correctness for every codec and nesting, three decode paths:
numpy oracle, pure-jnp stages (fused + unfused).  Property-based via hypothesis."""
import numpy as np
import pytest
hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import plan as P
from repro.core.compiler import compile_decoder, device_buffers

mp = P.make_plan


def roundtrip(pl, arr, backends=("jnp",)):
    enc = P.encode(pl, arr)
    out = P.decode_np(enc)
    np.testing.assert_array_equal(out, arr, err_msg="numpy oracle")
    bufs = device_buffers(enc)
    for backend in backends:
        for fuse in (False, True):
            dec = compile_decoder(enc, backend=backend, fuse=fuse)
            got = np.asarray(dec(bufs))
            np.testing.assert_array_equal(got, arr,
                                          err_msg=f"{backend} fuse={fuse}")
    return enc


ints = st.integers(min_value=-2**30, max_value=2**30)


@settings(max_examples=25, deadline=None)
@given(st.lists(ints, min_size=1, max_size=300))
def test_bitpack_roundtrip(xs):
    roundtrip(mp("bitpack"), np.asarray(xs, np.int32))


@settings(max_examples=25, deadline=None)
@given(st.lists(ints, min_size=1, max_size=300))
def test_delta_bitpack_roundtrip(xs):
    roundtrip(P.Plan("delta", children={"deltas": mp("bitpack")}),
              np.asarray(xs, np.int32))


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, 9), min_size=1, max_size=200),
       st.lists(st.integers(1, 30), min_size=1, max_size=200))
def test_rle_roundtrip(vals, counts):
    n = min(len(vals), len(counts))
    arr = np.repeat(np.asarray(vals[:n], np.int32), counts[:n])
    if arr.size == 0:
        return
    enc = roundtrip(P.Plan("rle", children={"counts": mp("bitpack"),
                                            "values": mp("bitpack")}), arr)
    assert enc.meta["n_groups"] <= n


@settings(max_examples=25, deadline=None)
@given(st.lists(st.sampled_from([3, 7, 11, -2, 1000]), min_size=1, max_size=400))
def test_dictionary_roundtrip(xs):
    roundtrip(P.Plan("dictionary", children={"index": mp("bitpack")}),
              np.asarray(xs, np.int32))


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, 10**7), min_size=1, max_size=300),
       st.integers(0, 3))
def test_float2int_roundtrip(ks, d):
    arr = (np.asarray(ks, np.int64) / 10.0**d).astype(np.float32)
    roundtrip(P.Plan("float2int", children={"ints": mp("bitpack")}), arr)


@settings(max_examples=20, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 10**6), st.integers(-5, 5),
                          st.integers(1, 50)), min_size=1, max_size=50))
def test_deltastride_roundtrip(runs):
    parts = [start + stride * np.arange(count, dtype=np.int64)
             for start, stride, count in runs]
    arr = np.concatenate(parts).astype(np.int32)
    roundtrip(mp("deltastride"), arr)


@settings(max_examples=15, deadline=None)
@given(st.binary(min_size=1, max_size=2000))
def test_ans_roundtrip_bytes(data):
    arr = np.frombuffer(data, np.uint8).copy()
    roundtrip(P.Plan("ans", params={"chunk_size": 256}), arr)


@settings(max_examples=15, deadline=None)
@given(st.lists(st.integers(-100, 100), min_size=1, max_size=500))
def test_ans_roundtrip_int32(xs):
    roundtrip(P.Plan("ans", params={"chunk_size": 512}),
              np.asarray(xs, np.int32))


@settings(max_examples=15, deadline=None)
@given(st.text(alphabet="abcdef .", min_size=1, max_size=800))
def test_stringdict_roundtrip(text):
    arr = np.frombuffer(text.encode(), np.uint8).copy()
    if arr.size == 0:
        return
    roundtrip(P.Plan("stringdict", children={"index": mp("bitpack")}), arr)


def test_table2_plans_roundtrip():
    """Every paper-Table-2 plan roundtrips on the synthetic TPC-H columns."""
    from repro.data.columns import TABLE2_PLANS
    from repro.data.tpch import generate

    cols = generate(scale=0.002, seed=1)
    for name, pl in TABLE2_PLANS.items():
        enc = P.encode(pl, cols[name])
        out = P.decode_np(enc)
        np.testing.assert_array_equal(out, cols[name], err_msg=name)
        dec = compile_decoder(enc, backend="jnp", fuse=True)
        got = np.asarray(dec(device_buffers(enc)))
        np.testing.assert_array_equal(got, cols[name], err_msg=name + " jnp")


def test_compression_ratio_sanity():
    """Table-2 plans actually compress the TPC-H-shaped data."""
    from repro.data.columns import TABLE2_PLANS
    from repro.data.tpch import generate

    cols = generate(scale=0.005, seed=2)
    total_plain = total_comp = 0
    for name, pl in TABLE2_PLANS.items():
        enc = P.encode(pl, cols[name])
        total_plain += enc.plain_nbytes
        total_comp += enc.compressed_nbytes
    assert total_plain / total_comp > 2.5, \
        f"aggregate ratio too low: {total_plain / total_comp:.2f}"


def test_auto_plan_chooser():
    from repro.data.columns import auto_plan
    from repro.data.tpch import generate

    cols = generate(scale=0.002, seed=3)
    pl, ratio = auto_plan(cols["O_ORDERKEY"])
    assert ratio > 4, f"auto plan failed to find a good plan ({ratio:.1f})"
    enc = P.encode(pl, cols["O_ORDERKEY"])
    np.testing.assert_array_equal(P.decode_np(enc), cols["O_ORDERKEY"])
