"""Elastic re-mesh + multi-device sharding tests.

These need >1 device, and XLA's host-device count is locked at first jax init, so
they run in a subprocess with XLA_FLAGS set (the same pattern launch/dryrun.py uses).
"""
import os
import subprocess
import sys

import pytest

from repro.launch.elastic import plan_remesh

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_plan_remesh_keeps_tp_groups():
    p = plan_remesh(surviving_chips=240, model_size=16)
    assert p.shape == (15, 16)
    assert p.dropped_chips == 0
    p = plan_remesh(surviving_chips=250, model_size=16)
    assert p.shape == (15, 16) and p.dropped_chips == 10
    with pytest.raises(RuntimeError):
        plan_remesh(surviving_chips=8, model_size=16)


_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np, tempfile
from repro.launch.elastic import ElasticCoordinator, make_mesh_from_plan, \
    plan_remesh, reshard
from repro.launch.mesh import shard_tree
from repro.models import get_model
from repro.configs import SMOKES
from repro.train import checkpoint as ckpt

cfg = SMOKES["qwen1.5-0.5b"]
model = get_model(cfg)
params, specs = model.init(jax.random.PRNGKey(0))

# full mesh: 4 data x 2 model; "lose" 2 chips -> 3 x 2
full = plan_remesh(8, model_size=2)
assert full.shape == (4, 2)
mesh = make_mesh_from_plan(full)
placed = reshard(params, specs, mesh)
batch = {"tokens": jnp.zeros((8, 32), jnp.int32),
         "labels": jnp.zeros((8, 32), jnp.int32)}
loss_full = jax.jit(lambda p, b: model.train_loss(p, b))(placed, batch)

with tempfile.TemporaryDirectory() as d:
    ckpt.save(d, 3, params)
    coord = ElasticCoordinator(model_size=2, ckpt_dir=d)
    survivors = jax.devices()[:6]           # 2 chips died
    placed2, mesh2, step = coord.recover(params, specs, survivors)
    assert dict(mesh2.shape) == {"data": 3, "model": 2}, mesh2.shape
    assert step == 3
    # the resharded model computes the same loss on the smaller mesh
    b2 = {"tokens": jnp.zeros((6, 32), jnp.int32),
          "labels": jnp.zeros((6, 32), jnp.int32)}
    loss_small = jax.jit(lambda p, b: model.train_loss(p, b))(placed2, b2)
    assert np.isfinite(float(loss_small))
    np.testing.assert_allclose(float(loss_full), float(loss_small),
                               rtol=1e-3)   # same data distribution, same params
print("ELASTIC_OK")
"""


def test_elastic_remesh_subprocess():
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _SUBPROC], env=env,
                         capture_output=True, text=True, timeout=420)
    assert "ELASTIC_OK" in out.stdout, out.stdout + "\n" + out.stderr[-2000:]


_SUBPROC_SHARD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.launch.mesh import make_production_mesh, shard_tree, mesh_axes
# mini production-mesh analogue: shard_tree divisibility fallbacks
mesh = jax.sharding.Mesh(np.asarray(jax.devices()).reshape(2, 4),
                         ("data", "model"))
shapes = {"w": jax.ShapeDtypeStruct((6, 8), jnp.float32),   # 6 % 2 == 0, 8 % 4 == 0
          "odd": jax.ShapeDtypeStruct((5, 7), jnp.float32)} # indivisible -> replicated
specs = {"w": ("fsdp", "tp"), "odd": ("fsdp", "tp")}
sh = shard_tree(shapes, specs, mesh)
assert sh["w"].spec == jax.sharding.PartitionSpec("data", "model"), sh["w"].spec
assert sh["odd"].spec == jax.sharding.PartitionSpec(None, None), sh["odd"].spec
print("SHARD_OK")
"""


def test_shard_tree_divisibility_subprocess():
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _SUBPROC_SHARD], env=env,
                         capture_output=True, text=True, timeout=240)
    assert "SHARD_OK" in out.stdout, out.stdout + "\n" + out.stderr[-2000:]
