"""Holistic execution planner: cost model calibration, policy objects over the
generalized per-chunk simulator, plan optimality vs the fixed baselines, and
plan round-tripping through the streaming executor.

(Deliberately hypothesis-free -- these must run in environments where
``test_scheduler.py`` importorskips.)
"""
import dataclasses

import numpy as np
import pytest

from repro.core import plan as P, scheduler
from repro.core.compiler import ProgramCache
from repro.core.costmodel import ColumnProfile, CostModel
from repro.core.executor import StreamingExecutor
from repro.core.planner import CHUNK, WHOLE, ExecutionPlan, plan_execution
from repro.core.scheduler import (AdaptivePolicy, ChunkInfo, ChunkJohnsonPolicy,
                                  FifoPolicy, JohnsonPolicy, chunk_jobs,
                                  column_of, get_policy, makespan,
                                  simulate_stream)


# ------------------------------------------------------------ scheduler layer

def test_simulate_stream_defaults_reduce_to_makespan():
    rng = np.random.default_rng(0)
    jobs = [scheduler.Job(str(i), float(a), float(b))
            for i, (a, b) in enumerate(rng.uniform(0.01, 5.0, (8, 2)))]
    order = scheduler.johnson_order(jobs)
    assert simulate_stream(jobs, None, order) == pytest.approx(
        makespan(jobs, order))


def test_simulate_stream_chunk_decode_never_worse_than_whole():
    """Per-chunk decode only adds overlap (zero launch overhead)."""
    rng = np.random.default_rng(1)
    for _ in range(50):
        jobs = [scheduler.Job(str(i), float(a), float(b)) for i, (a, b)
                in enumerate(rng.uniform(0.01, 5.0, (rng.integers(1, 6), 2)))]
        ks = rng.integers(1, 9, len(jobs))
        whole = [ChunkInfo(n_chunks=int(k)) for k in ks]
        chunked = [ChunkInfo(n_chunks=int(k), chunk_decode=True) for k in ks]
        order = list(range(len(jobs)))
        assert (simulate_stream(jobs, chunked, order)
                <= simulate_stream(jobs, whole, order) + 1e-9)


def test_chunk_jobs_uneven_tail_preserves_totals():
    jobs = [scheduler.Job("a", 4.0, 2.0), scheduler.Job("b", 1.0, 4.0)]
    cjobs = chunk_jobs(jobs, [4, 3], tail_frac=[0.25, 1.0])
    assert len(cjobs) == 7
    assert sum(j.transfer_s for j in cjobs) == pytest.approx(5.0)
    assert sum(j.decompress_s for j in cjobs) == pytest.approx(6.0)
    # tail chunk of "a" carries a quarter share; body chunks a full share each
    a_chunks = [j for j in cjobs if column_of(j.name) == "a"]
    assert a_chunks[-1].transfer_s == pytest.approx(a_chunks[0].transfer_s / 4)


def test_chunk_naming_escapes_separator():
    """Column names containing '#' survive the chunk naming round trip."""
    jobs = [scheduler.Job("tbl#col", 2.0, 1.0), scheduler.Job("plain", 1.0, 2.0)]
    cjobs = chunk_jobs(jobs, [3, 2])
    names = {column_of(j.name) for j in cjobs}
    assert names == {"tbl#col", "plain"}
    assert scheduler.column_order([j.name for j in cjobs]) == ["tbl#col", "plain"]
    # pathological: name ending in the separator
    assert column_of(chunk_jobs([scheduler.Job("x#", 1, 1)], [2])[0].name) == "x#"


def test_policy_registry_and_adaptive_dominance():
    rng = np.random.default_rng(2)
    for name in ("fifo", "johnson", "chunk-johnson", "adaptive"):
        assert get_policy(name).name == name
    with pytest.raises(ValueError):
        get_policy("nope")
    for _ in range(30):
        n = int(rng.integers(1, 7))
        jobs = [scheduler.Job(str(i), float(a), float(b))
                for i, (a, b) in enumerate(rng.uniform(0.01, 5.0, (n, 2)))]
        infos = [ChunkInfo(n_chunks=int(k), chunk_decode=bool(c),
                           tail_frac=float(t))
                 for k, c, t in zip(rng.integers(1, 7, n),
                                    rng.integers(0, 2, n),
                                    rng.uniform(0.1, 1.0, n))]
        mk_ad = AdaptivePolicy().modeled_makespan(jobs, infos)
        for pol in (FifoPolicy(), JohnsonPolicy(), ChunkJohnsonPolicy()):
            assert mk_ad <= pol.modeled_makespan(jobs, infos) + 1e-9


# -------------------------------------------------------------- planner layer

def _synthetic_profiles(rng, n):
    """Profiles + injected measured timings for simulation-only planning."""
    cm = CostModel()
    profiles = {}
    for i in range(n):
        name = f"col{i}"
        nbytes = int(rng.integers(1 << 16, 1 << 23))
        profiles[name] = ColumnProfile(
            name=name, compressed_nbytes=nbytes, plain_nbytes=4 * nbytes,
            n_kernels=int(rng.integers(1, 4)), signature=f"sig{i}",
            leaves=((nbytes // 4, nbytes),), chunkable=bool(rng.integers(0, 2)),
            n_out=nbytes, per_elem_bytes=1.0, align=8)
        cm.register(profiles[name])
        cm.measured[name] = (float(rng.uniform(0.001, 0.05)),
                             float(rng.uniform(0.001, 0.05)))
    return profiles, cm


def test_planner_never_exceeds_fixed_baselines():
    """Adaptive plan's simulated makespan <= min(FIFO, whole-column Johnson,
    fixed-chunk Johnson) on randomized (seeded) job sets."""
    rng = np.random.default_rng(7)
    for trial in range(25):
        profiles, cm = _synthetic_profiles(rng, int(rng.integers(2, 9)))
        ep = plan_execution(profiles, cm, policy="adaptive",
                            chunk_bytes="auto")
        assert ep.baselines.keys() == {"fifo", "johnson", "chunk-johnson"}
        assert ep.modeled_makespan_s <= min(ep.baselines.values()) + 1e-9, \
            f"trial {trial}: {ep.modeled_makespan_s} vs {ep.baselines}"
        assert set(ep.order) == set(profiles)


def test_single_column_plan_is_trivial():
    """One column: one order, no baseline sweep (per-request serve path)."""
    rng = np.random.default_rng(7)
    profiles, cm = _synthetic_profiles(rng, 1)
    ep = plan_execution(profiles, cm, policy="johnson", chunk_bytes=None)
    assert ep.order == tuple(profiles) and ep.baselines == {}
    (d,) = ep.decisions.values()
    assert d.decode_mode == WHOLE and d.chunk_bytes is None


def test_plan_is_explainable():
    rng = np.random.default_rng(8)
    profiles, cm = _synthetic_profiles(rng, 4)
    ep = plan_execution(profiles, cm, policy="adaptive", chunk_bytes="auto")
    text = ep.explain()
    assert "policy=adaptive" in text and "baseline" in text
    for name in profiles:
        assert name in text


def test_fixed_policies_preserve_legacy_shapes():
    """Non-adaptive policies plan the configuration the knobs imply."""
    rng = np.random.default_rng(9)
    profiles, cm = _synthetic_profiles(rng, 5)
    ep = plan_execution(profiles, cm, policy="fifo", chunk_bytes=None)
    assert ep.order == tuple(profiles)          # submission order
    assert all(d.decode_mode in (WHOLE, "batched") and d.chunk_bytes is None
               for d in ep.decisions.values())
    ep2 = plan_execution(profiles, cm, policy="johnson", chunk_bytes=1 << 18,
                         chunk_decode=True)
    chunked = [d for d in ep2.decisions.values() if d.decode_mode == CHUNK]
    assert all(profiles[d.name].chunkable and d.n_chunks > 1 for d in chunked)


# ----------------------------------------------------- executor round-tripping

def test_plan_round_trips_through_executor():
    """Plan says per-chunk => the executor's records show chunk_decoded with
    the planned launch count; plan says whole => single launch."""
    rng = np.random.default_rng(11)
    encs = {
        "big": P.encode(P.make_plan("bitpack"),
                        rng.integers(0, 3000, 400_000).astype(np.int32)),
        "small": P.encode(P.make_plan("bitpack"),
                          rng.integers(0, 3000, 2_000).astype(np.int32)),
    }
    ex = StreamingExecutor(chunk_bytes=16384, chunk_decode=True,
                           cache=ProgramCache())
    for n, e in encs.items():
        ex.compile(n, e)
    ep = ex.plan()
    assert ep.decisions["big"].decode_mode == CHUNK
    assert ep.decisions["small"].decode_mode == WHOLE
    results = ex.run(encs, plan=ep)
    for n, e in encs.items():
        np.testing.assert_array_equal(np.asarray(results[n].array),
                                      P.decode_np(e))
    assert results["big"].chunk_decoded
    assert results["big"].decode_launches == ep.decisions["big"].n_chunks > 1
    assert not results["small"].chunk_decoded
    assert results["small"].decode_launches == 1
    # forcing whole-column decode through the plan is honoured too
    whole = dataclasses.replace(
        ep, decisions={n: dataclasses.replace(d, decode_mode=WHOLE)
                       for n, d in ep.decisions.items()})
    res2 = ex.run(encs, plan=whole)
    assert not res2["big"].chunk_decoded
    np.testing.assert_array_equal(np.asarray(res2["big"].array),
                                  P.decode_np(encs["big"]))


def test_whole_blob_transfer_is_honoured_with_chunk_decode():
    """chunk_bytes=None means whole-blob transfer -- chunk_decode=True must not
    smuggle a default chunk size back in (the baseline substitutes one for
    reporting only, never for execution)."""
    rng = np.random.default_rng(14)
    enc = P.encode(P.make_plan("bitpack"),
                   rng.integers(0, 3000, 400_000).astype(np.int32))
    ex = StreamingExecutor(chunk_bytes=None, chunk_decode=True,
                           cache=ProgramCache())
    res = ex.run({"c": enc})["c"]
    assert not res.chunk_decoded and res.decode_launches == 1
    ep = ex.plan()
    assert ep.decisions["c"].chunk_bytes is None
    assert ep.decisions["c"].decode_mode == WHOLE


def test_adaptive_guarantee_holds_with_chunk_bytes_none():
    """chunk_bytes=None constrains the baselines too: every reported baseline
    is a configuration the search may pick, so the documented
    planned <= min(baselines) invariant survives the no-chunking constraint."""
    rng = np.random.default_rng(16)
    for _ in range(10):
        profiles, cm = _synthetic_profiles(rng, int(rng.integers(2, 7)))
        ep = plan_execution(profiles, cm, policy="adaptive", chunk_bytes=None,
                            chunk_decode=True)
        assert ep.modeled_makespan_s <= min(ep.baselines.values()) + 1e-9
        assert all(d.chunk_bytes is None for d in ep.decisions.values())


def test_explicit_policy_wins_over_pipeline_false():
    rng = np.random.default_rng(17)
    ex = StreamingExecutor(pipeline=False, chunk_bytes=None,
                           cache=ProgramCache())
    for n in ("a", "b"):
        ex.compile(n, P.encode(P.make_plan("bitpack"),
                               rng.integers(0, 99, 4_000).astype(np.int32)))
    assert ex.plan().policy == "fifo"             # constructor default degrades
    assert ex.plan(policy="johnson").policy == "johnson"   # explicit arg wins


def test_run_rejects_plan_missing_columns():
    rng = np.random.default_rng(15)
    mk = lambda: P.encode(P.make_plan("bitpack"),
                          rng.integers(0, 100, 5_000).astype(np.int32))
    ex = StreamingExecutor(chunk_bytes=None, cache=ProgramCache())
    ex.compile("a", mk())
    stale = ex.plan()
    with pytest.raises(ValueError, match="does not cover"):
        ex.run({"a": ex._encoded["a"], "b": mk()}, plan=stale)


def test_executor_feeds_actuals_back_into_cost_model():
    """CostModel predictions tighten after a measured run (EWMA feedback)."""
    rng = np.random.default_rng(12)
    encs = {f"c{i}": P.encode(P.make_plan("bitpack"),
                              rng.integers(0, 1000, 50_000).astype(np.int32))
            for i in range(3)}
    ex = StreamingExecutor(chunk_bytes=8192, cache=ProgramCache())
    for n, e in encs.items():
        ex.compile(n, e)
    cm = ex.cost_model
    raw_pred = {n: cm.predict(n) for n in encs}
    assert cm.n_observed == 0 and cm.transfer_scale == 1.0
    results = ex.run(encs)
    assert cm.n_observed == len(encs)
    assert set(cm.measured) == set(encs)
    # after observation, predictions ARE the measurements for seen columns...
    for n, r in results.items():
        assert cm.predict(n) == (r.transfer_s, r.decode_s)
    # ...and the calibrated estimate for an UNSEEN same-shaped column moved
    # toward wall-clock scale (CPU device_put is far slower than the chip model)
    new = P.encode(P.make_plan("bitpack"),
                   rng.integers(0, 1000, 50_000).astype(np.int32))
    ex.compile("fresh", new)
    fresh_pred = cm.predict("fresh")
    meas_t = np.mean([r.transfer_s for r in results.values()])
    raw_t = raw_pred["c0"][0]
    assert (abs(np.log(fresh_pred[0] / meas_t))
            < abs(np.log(raw_t / meas_t))), \
        "calibrated transfer prediction must be tighter than the raw model"


def test_cost_model_jobs_unit_consistency():
    rng = np.random.default_rng(13)
    profiles, cm = _synthetic_profiles(rng, 3)
    names = list(profiles)
    # all measured -> jobs reflect measurements exactly
    jobs = cm.jobs(names)
    for j in jobs:
        assert (j.transfer_s, j.decompress_s) == cm.measured[j.name]
    # one unmeasured -> every job switches to the calibrated estimate
    del cm.measured[names[0]]
    jobs = cm.jobs(names)
    for j in jobs:
        t, d = cm.raw_estimate(j.name)
        assert j.transfer_s == pytest.approx(t * cm.transfer_scale)
        assert j.decompress_s == pytest.approx(d * cm.decode_scale)


def test_pipeline_policy_threads_through():
    """ColumnPipeline(policy=...) reaches the executor; on the TPC-H Q1 column
    set the adaptive plan's simulated makespan <= every fixed baseline."""
    from repro.data.columns import TABLE2_PLANS
    from repro.data.tpch import QUERY_COLUMNS, generate

    cols = generate(scale=0.002, seed=3)
    names = QUERY_COLUMNS[1]
    from repro.data.loader import ColumnPipeline
    pipe = ColumnPipeline({n: TABLE2_PLANS[n] for n in names},
                          chunk_bytes="auto", policy="adaptive")
    pipe.compress({n: cols[n] for n in names})
    results = pipe.run()
    for n in names:
        np.testing.assert_array_equal(np.asarray(results[n].array), cols[n])
    ep = pipe.plan()
    assert isinstance(ep, ExecutionPlan) and ep.policy == "adaptive"
    assert ep.modeled_makespan_s <= min(ep.baselines.values()) + 1e-9
