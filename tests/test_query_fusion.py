"""Decode-fused query execution (codec x operator fusion, late materialization):
Q1/Q6 fused == reference engines, per-chunk partial aggregates across uneven
tails, RLE per-run aggregation, Eq.-2 traffic delta, planner fused-vs-
materialize decisions, and the finite in-flight window in ``simulate_stream``."""
import numpy as np
import pytest

from repro.core import plan as P, scheduler
from repro.core.compiler import ProgramCache
from repro.core.ir import query_chunk_layout
from repro.core.fusion import hbm_traffic_bytes
from repro.core.query import Bin, Col, Const, Pred, QueryPlan, lower_query
from repro.data.columns import TABLE2_PLANS
from repro.data.loader import ColumnPipeline
from repro.data.queries import Q1_PLAN, Q6_PLAN, q1_engine, q6_engine
from repro.data.tpch import QUERY_COLUMNS, generate, scale_columns

mp = P.make_plan


def _tpch(scale=0.002, factor=1):
    cols = generate(scale=scale, seed=0)
    if factor > 1:
        cols = scale_columns(cols, factor,
                             [n for n in cols if n.startswith("L_")])
    return cols


def _encode(cols, names):
    return {n: P.encode(TABLE2_PLANS[n], cols[n]) for n in names}


def _run_whole(fq, res=None):
    """Evaluate the fused graph in ONE chunk launch spanning all items."""
    import jax.numpy as jnp

    from repro.core.compiler import compile_query_chunk_graph

    n_items = fq.graph.stages[-1].n_in      # the terminal Reduce's item axis
    prog = compile_query_chunk_graph(fq.graph, n_items)
    bufs = {k: jnp.asarray(v) for k, v in fq.operands.items()}
    if res:
        bufs.update({k: jnp.asarray(v) for k, v in res.items()})
    return prog(bufs, 0)


# ------------------------------------------------------ fused == engine

def test_q6_fused_matches_engine_bitwise_count():
    cols = _tpch()
    encs = _encode(cols, QUERY_COLUMNS[6])
    fq = lower_query(Q6_PLAN, encs)
    assert not fq.resident          # all four Q6 columns fuse
    acc = np.asarray(_run_whole(fq))
    ref = float(q6_engine({k: np.asarray(v) for k, v in
                           ((n, cols[n]) for n in QUERY_COLUMNS[6])}))
    np.testing.assert_allclose(float(fq.finalize(acc)), ref, rtol=1e-5)
    # integer-predicate count lane is exact: recompute the mask in numpy
    # (float32 constants keep the discount comparison in float32, like jax)
    d = cols["L_DISCOUNT"]
    m = ((cols["L_SHIPDATE"] >= 8766) & (cols["L_SHIPDATE"] < 9131)
         & (d >= np.float32(0.05)) & (d <= np.float32(0.07))
         & (cols["L_QUANTITY"] < 24))
    assert fq.selected_rows(acc) == float(m.sum())


def test_q1_fused_matches_engine():
    cols = _tpch()
    encs = _encode(cols, QUERY_COLUMNS[1])
    fq = lower_query(Q1_PLAN, encs)
    assert "L_RETURNFLAG" in fq.resident      # ANS column gathers decoded
    # resident input comes from the normal decode path
    acc = np.asarray(_run_whole(
        fq, {fq.resident_input("L_RETURNFLAG"): cols["L_RETURNFLAG"]}))
    out = np.asarray(fq.finalize(acc))
    ref = np.asarray(q1_engine({n: np.asarray(cols[n])
                                for n in QUERY_COLUMNS[1]}))
    np.testing.assert_allclose(out, ref, rtol=1e-4)


# ------------------------------------------------- chunked partial aggregates

@pytest.mark.parametrize("chunk_bytes", [1 << 12, 1 << 14])
def test_q6_chunked_uneven_tail(chunk_bytes):
    """Per-chunk partial aggregates over row spans with an uneven tail sum to
    the whole-graph answer bitwise."""
    cols = _tpch()
    names = QUERY_COLUMNS[6]
    pipe = ColumnPipeline({n: TABLE2_PLANS[n] for n in names},
                          chunk_bytes=chunk_bytes, chunk_decode=True)
    pipe.compress({n: cols[n] for n in names})
    qe = pipe.run_query(Q6_PLAN)
    assert qe.n_chunks > 1          # the tail chunk is a different trace size
    ref = float(q6_engine({n: np.asarray(cols[n]) for n in names}))
    np.testing.assert_allclose(float(np.asarray(qe.result)), ref, rtol=1e-5)
    # late materialization: only partial-aggregate lanes reached HBM
    assert qe.traffic_bytes < qe.prefuse_traffic_bytes
    assert qe.plain_bytes > 0


def test_q1_chunked_resident_gather():
    cols = _tpch()
    names = QUERY_COLUMNS[1]
    pipe = ColumnPipeline({n: TABLE2_PLANS[n] for n in names},
                          chunk_bytes=1 << 14, chunk_decode=True)
    pipe.compress({n: cols[n] for n in names})
    qe = pipe.run_query(Q1_PLAN)
    assert "L_RETURNFLAG" in qe.resident
    ref = np.asarray(q1_engine({n: np.asarray(cols[n]) for n in names}))
    np.testing.assert_allclose(np.asarray(qe.result), ref, rtol=1e-4)


def test_fused_graph_never_materializes_rows():
    """No fused stage writes a row-count-sized output: the column never
    exists in HBM."""
    cols = _tpch()
    encs = _encode(cols, QUERY_COLUMNS[6])
    fq = lower_query(Q6_PLAN, encs)
    n = fq.n_rows
    for st in fq.graph.stages:
        assert getattr(st, "n_out", 0) != n, st.name
    layout = query_chunk_layout(fq.graph)
    assert layout is not None and layout.tiled


# ------------------------------------------------------- RLE per-run path

def test_rle_per_run_aggregation():
    """Predicated sum over an RLE column aggregates per RUN (run-length
    weighted), never expanding to the row axis."""
    from repro.algos.rle import run_reduce_graph

    rng = np.random.default_rng(3)
    counts = rng.integers(1, 60, 400)
    values = np.cumsum(rng.integers(1, 4, 400)).astype(np.int32)
    arr = np.repeat(values, counts).astype(np.int32)
    enc = P.encode(P.Plan("rle", children={"counts": mp("bitpack"),
                                           "values": mp("bitpack")}), arr)
    lo = int(np.quantile(values, 0.3))
    g = run_reduce_graph(enc, lambda v: v >= lo, [lambda v: v * 2],
                         digest="t")
    from repro.core.compiler import compile_query_chunk_graph, device_buffers

    prog = compile_query_chunk_graph(g, g.stages[-1].n_in)
    acc = np.asarray(prog(device_buffers(enc), 0))
    m = arr >= lo
    np.testing.assert_allclose(acc[0], float((arr[m] * 2).sum()), rtol=1e-6)
    assert acc[1] == float(m.sum())
    # run-granular: no stage output is row-count sized
    assert all(getattr(st, "n_out", 0) != enc.n for st in g.stages)


# -------------------------------------------------------- traffic + planner

def test_operator_fusion_traffic_delta():
    cols = _tpch()
    encs = _encode(cols, QUERY_COLUMNS[6])
    fq = lower_query(Q6_PLAN, encs)
    pre = hbm_traffic_bytes(fq.prefuse_stages, fq.operands)
    post = hbm_traffic_bytes(fq.graph.stages, fq.operands)
    plain = sum(e.plain_nbytes for e in encs.values())
    assert post < pre               # fusion removed round-trips
    assert pre - post >= plain      # at least the decoded columns' bytes


def test_plan_explain_reports_fused():
    cols = _tpch()
    names = QUERY_COLUMNS[6]
    pipe = ColumnPipeline({n: TABLE2_PLANS[n] for n in names},
                          chunk_bytes=1 << 14, chunk_decode=True)
    pipe.compress({n: cols[n] for n in names})
    pipe.run()                      # measured timings calibrate the model
    qe = pipe.run_query(Q6_PLAN)    # observed selectivity feeds the EWMA
    ep = pipe.query_plan(Q6_PLAN)
    fused = [n for n, d in ep.decisions.items() if d.fused]
    assert fused                    # low selectivity: fusing must win somewhere
    text = ep.explain()
    assert "+fused" in text and "sel=" in text
    for n in fused:
        sel = ep.decisions[n].selectivity
        np.testing.assert_allclose(sel, qe.selectivity, atol=1e-6)


def test_selectivity_ewma_learns():
    from repro.core.costmodel import DEFAULT_SELECTIVITY

    cols = _tpch()
    names = QUERY_COLUMNS[6]
    pipe = ColumnPipeline({n: TABLE2_PLANS[n] for n in names},
                          chunk_bytes=1 << 14, chunk_decode=True)
    pipe.compress({n: cols[n] for n in names})
    cm = pipe.executor.cost_model
    qe = pipe.run_query(Q6_PLAN)
    assert qe.selectivity < DEFAULT_SELECTIVITY / 2     # Q6 is selective
    for c in names:
        if c in cm.profiles:
            assert cm.selectivity_for(c) != DEFAULT_SELECTIVITY


# ------------------------------------------------- finite in-flight window

def _jobs():
    jobs = [scheduler.Job(f"c{i}", 0.004, 0.006) for i in range(3)]
    infos = [scheduler.ChunkInfo(n_chunks=8, chunk_decode=True,
                                 launch_overhead_s=1e-4) for _ in range(3)]
    return jobs, infos, list(range(3))


def test_simulate_stream_window_none_unchanged():
    jobs, infos, order = _jobs()
    base = scheduler.simulate_stream(jobs, infos, order)
    assert scheduler.simulate_stream(jobs, infos, order, window=None) == base
    # a huge window is the same as unbounded
    assert scheduler.simulate_stream(jobs, infos, order, window=1000) == base


def test_simulate_stream_window_monotone():
    jobs, infos, order = _jobs()
    base = scheduler.simulate_stream(jobs, infos, order)
    prev = None
    for w in (1, 2, 4, 8):
        t = scheduler.simulate_stream(jobs, infos, order, window=w)
        assert t >= base - 1e-12          # a bound can only slow things down
        if prev is not None:
            assert t <= prev + 1e-12      # wider window never hurts
        prev = t


def test_plan_window_is_cost_driven():
    cols = _tpch()
    names = QUERY_COLUMNS[6]
    pipe = ColumnPipeline({n: TABLE2_PLANS[n] for n in names},
                          chunk_bytes=1 << 14, chunk_decode=True,
                          policy="adaptive")
    pipe.compress({n: cols[n] for n in names})
    pipe.run()
    ep = pipe.plan()
    assert 2 <= ep.window <= 8
    # the chosen window's simulated makespan matches the unbounded plan
    jobs = [scheduler.Job(n, *pipe.executor.timings[n]) for n in ep.order]
    infos = [scheduler.ChunkInfo(
        n_chunks=ep.decisions[n].n_chunks,
        chunk_decode=ep.decisions[n].decode_mode == "chunk",
        launch_overhead_s=pipe.executor.cost_model.launch_overhead_s(n))
        for n in ep.order]
    bound = scheduler.simulate_stream(jobs, infos, window=ep.window)
    free = scheduler.simulate_stream(jobs, infos)
    assert bound <= free * (1 + 1e-6)


# ------------------------------------------------------------ ad-hoc query

def test_adhoc_between_projection():
    """A hand-built QueryPlan (not Q1/Q6) lowers and runs end to end."""
    rng = np.random.default_rng(1)
    n = 6000
    a = rng.integers(0, 100, n).astype(np.int32)
    b = (rng.integers(0, 500, n) / 100.0).astype(np.float32)
    plans = {"A": mp("bitpack"),
             "B": P.Plan("float2int", children={"ints": mp("bitpack")})}
    qp = QueryPlan(name="adhoc",
                   predicates=(Pred("A", "between", 10, 60),),
                   aggregates=(("s", Bin("*", Col("B"), Const(3.0))),))
    pipe = ColumnPipeline(plans, chunk_bytes=1 << 12, chunk_decode=True)
    pipe.compress({"A": a, "B": b})
    qe = pipe.run_query(qp)
    m = (a >= 10) & (a <= 60)
    b2 = np.round(b, 2).astype(np.float32)      # float2int quantizes
    np.testing.assert_allclose(float(np.asarray(qe.result)),
                               float((b2[m] * 3.0).sum()), rtol=1e-5)
