"""Batched serving with continuous batching + compressed KV paging.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SMOKES
from repro.models import get_model
from repro.serve.engine import Request, ServeEngine
from repro.serve.kvcache import page_in, page_out

cfg = SMOKES["qwen1.5-0.5b"]
model = get_model(cfg)
params, _ = model.init(jax.random.PRNGKey(0))

# --- continuous batching over 2 slots, 5 requests ---
eng = ServeEngine(cfg, params, batch_slots=2, max_len=128, eos=-1)
rng = np.random.default_rng(0)
for rid in range(5):
    eng.submit(Request(rid, rng.integers(0, cfg.vocab, 6).astype(np.int32),
                       max_new=8))
done = eng.run_to_completion(max_steps=500)
for rid in sorted(done):
    print(f"request {rid}: generated {done[rid]}")

# --- ZipFlow KV paging: quantize+bitpack a cold cache block to host ---
block = jnp.asarray(rng.normal(size=(2, 64, cfg.n_kv_heads, cfg.hd))
                    .astype(np.float32))
pb = page_out(block)
restored = page_in(pb, jnp.float32)
err = float(jnp.max(jnp.abs(restored - block)))
print(f"\nKV paging: {block.nbytes} B block -> {pb.packed.nbytes} B on the wire "
      f"({block.nbytes / pb.packed.nbytes:.1f}x), max dequant err {err:.4f}")
