"""End-to-end TPC-H data movement + query execution (the paper's headline scenario).

Compresses the columns of TPC-H Q1/Q6 with the paper's Table-2 plans, moves them
host->device with Johnson-ordered pipelining, decompresses, and runs the queries in
the JAX mini-engine.  Compares noCOMP / cascaded-baseline / ZipFlow movement costs.

Run:  PYTHONPATH=src python examples/tpch_pipeline.py [--scale 0.01]
"""
import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.queries import QUERY_PLANS, q1_engine, q6_engine
from repro.core import plan as P
from repro.core.costmodel import CostModel
from repro.data.columns import TABLE2_PLANS
from repro.data.loader import ColumnPipeline
from repro.data.tpch import QUERY_COLUMNS, generate

ap = argparse.ArgumentParser()
ap.add_argument("--scale", type=float, default=0.01)
ap.add_argument("--chunk-kib", type=int, default=1024,
                help="streaming transfer chunk size (KiB); 0 = whole-blob")
ap.add_argument("--chunk-decode", action="store_true",
                help="launch one decode per transferred chunk (element- and "
                     "group-chunkable columns; others fall back to "
                     "whole-column decode)")
ap.add_argument("--policy", default="chunk-johnson",
                choices=["fifo", "johnson", "chunk-johnson", "adaptive"],
                help="scheduling policy for the execution planner; 'adaptive' "
                     "searches orders and chunk configurations by modeled "
                     "makespan")
ap.add_argument("--auto-chunks", action="store_true",
                help="let the planner size chunks per column (overrides "
                     "--chunk-kib)")
ap.add_argument("--cost-cache", default="",
                help="path to a persisted CostModel (JSON): loaded before "
                     "planning so a fresh process plans from calibrated "
                     "history, saved back (updated) on exit")
ap.add_argument("--mesh", type=int, default=0, metavar="N",
                help="shard decode over N logical devices via the "
                     "topology-aware mesh planner (run_sharded); 0 keeps the "
                     "single-device streaming path.  With >1 visible devices "
                     "oversized group-chunkable columns split into per-device "
                     "group-span shards")
ap.add_argument("--async-dispatch", action="store_true",
                help="issue host->device transfers from per-link worker "
                     "threads so multi-device issuance overlaps (mesh path "
                     "decodes shards concurrently)")
ap.add_argument("--placement", default=None, choices=["sharded"],
                help="'sharded' pins shard i of every split column to logical "
                     "device i; the planner may land shards elsewhere and "
                     "rebalance over the D2D fabric when that is modeled "
                     "faster (decode-where-landed)")
args = ap.parse_args()
chunk_bytes = "auto" if args.auto_chunks else (args.chunk_kib * 1024 or None)

cost_model = None
if args.cost_cache:
    if os.path.exists(args.cost_cache):
        cost_model = CostModel.load(args.cost_cache)
        print(f"cost cache: loaded {args.cost_cache} "
              f"({len(cost_model.sig_stats)} signatures, "
              f"{cost_model.n_observed} prior observations)")
    else:
        cost_model = CostModel()
        print(f"cost cache: {args.cost_cache} not found, starting cold")

cols = generate(scale=args.scale, seed=0)
print(f"generated TPC-H-like tables at scale {args.scale} "
      f"({cols['L_ORDERKEY'].size:,} lineitems)")

for q, engine in ((1, q1_engine), (6, q6_engine)):
    names = QUERY_COLUMNS[q]
    qcols = {n: cols[n] for n in names}
    raw_bytes = sum(a.nbytes for a in qcols.values())

    pipe = ColumnPipeline({n: TABLE2_PLANS[n] for n in names},
                          chunk_bytes=chunk_bytes,
                          chunk_decode=args.chunk_decode, policy=args.policy,
                          cost_model=cost_model,
                          mesh=args.mesh or None,
                          async_dispatch=args.async_dispatch,
                          placement=args.placement)
    ratios = pipe.compress(qcols)
    comp_bytes = sum(pipe._encoded[n].compressed_nbytes for n in names)
    mesh_res = None
    t0 = time.perf_counter()
    if args.mesh and args.mesh > 1:
        mesh_res = pipe.run_sharded()   # topology-aware per-device windows
        results = mesh_res.columns
    else:
        results = pipe.run()    # planned streaming: order/chunks/modes from plan
    t_move = time.perf_counter() - t0
    device_cols = {n: r.array for n, r in results.items()}
    if mesh_res is not None:
        # the mini-engine is single-device: gather the mesh-landed columns
        # (query-on-mesh stays with the fused per-shard path in core.serve)
        device_cols = {n: jax.device_put(a, jax.devices()[0])
                       for n, a in device_cols.items()}
    t0 = time.perf_counter()
    out = jax.block_until_ready(jax.jit(engine)(device_cols))
    t_query = time.perf_counter() - t0
    print(f"\nTPC-H Q{q}: {raw_bytes / 1e6:.1f} MB raw -> "
          f"{comp_bytes / 1e6:.2f} MB compressed "
          f"({raw_bytes / comp_bytes:.1f}x)")
    for n in names:
        print(f"   {n:18s} ratio {ratios[n]:7.1f}x  "
              f"plan {TABLE2_PLANS[n].describe()}")
    print(f"   movement+decode {t_move * 1e3:.1f} ms, query {t_query * 1e3:.1f} ms"
          f" -> result {np.asarray(out).ravel()[:4]}")
    stats = pipe.cache_stats
    print(f"   programs: {stats['programs']} jitted for {len(names)} columns "
          f"(cache hits {stats['hits']}, evictions {stats['evictions']})")
    if args.chunk_decode:
        launches = {n: r.decode_launches for n, r in results.items()}
        print(f"   per-chunk decode: "
              f"{sum(r.chunk_decoded for r in results.values())}/{len(names)} "
              f"columns chunked, launches {launches}")
    if mesh_res is not None:
        sharded = [n for n, s in mesh_res.plan.shards.items() if len(s) > 1]
        print(f"   mesh x{args.mesh}"
              f"{' (async dispatch)' if args.async_dispatch else ''}: "
              f"sharded {sharded or 'none'}; per-device launches "
              f"{dict(sorted(mesh_res.device_launches.items()))}")
        if mesh_res.d2d_copies:
            legs = ", ".join(f"{it}: d{src}->d{dst} {s * 1e3:.2f}ms"
                             for it, (src, dst, s)
                             in sorted(mesh_res.d2d_copies.items()))
            print(f"   d2d rebalance ({len(mesh_res.d2d_copies)} legs): {legs}")
        elif args.placement:
            print("   d2d rebalance: no legs (decode landed on placement, or "
                  "no fabric modeled)")
        continue  # planner/fused-query reporting below is single-device
    # makespans reuse the timings measured during run() -- no re-measurement
    mk_nopipe = pipe.modeled_makespan(pipeline=False)
    mk_pipe = pipe.modeled_makespan(pipeline=True, johnson=True)
    mk_chunk = pipe.modeled_makespan(pipeline=True, johnson=True, chunked=True)
    print(f"   pipelining: serial {mk_nopipe * 1e3:.1f} ms -> "
          f"Johnson {mk_pipe * 1e3:.1f} ms "
          f"({mk_nopipe / max(mk_pipe, 1e-9):.2f}x) -> "
          f"chunked {mk_chunk * 1e3:.1f} ms "
          f"({mk_nopipe / max(mk_chunk, 1e-9):.2f}x)")
    # re-plan from the measured timings: planned vs measured makespan
    ep = pipe.plan()
    print(f"   planner ({ep.policy}): planned {ep.modeled_makespan_s * 1e3:.1f} "
          f"ms vs measured move+decode {t_move * 1e3:.1f} ms; baselines "
          + " ".join(f"{k}={v * 1e3:.1f}ms" for k, v in sorted(ep.baselines.items())))
    for line in ep.explain().splitlines():
        print(f"     {line}")
    # decode-fused execution (late materialization): the query's operators
    # ride the per-chunk decode launches -- only partial aggregates hit HBM
    qp = QUERY_PLANS[q]
    qe = pipe.run_query(qp)         # cold call traces the chunk programs
    t0 = time.perf_counter()
    qe = pipe.run_query(qp)
    t_fused = time.perf_counter() - t0
    np.testing.assert_allclose(np.asarray(qe.result), np.asarray(out),
                               rtol=1e-4)
    ep_q = pipe.query_plan(qp)
    n_fused = sum(d.fused for d in ep_q.decisions.values())
    print(f"   decode-fused Q{q}: {t_fused * 1e3:.1f} ms warm (cold "
          f"materialize+query above: {(t_move + t_query) * 1e3:.1f} ms); "
          f"selectivity "
          f"{qe.selectivity:.4f}; {qe.n_chunks} chunks / "
          f"{qe.decode_launches} launches; HBM traffic "
          f"{qe.traffic_bytes / 1e6:.2f} MB (pre-fusion "
          f"{qe.prefuse_traffic_bytes / 1e6:.2f} MB); "
          f"{qe.plain_bytes / 1e6:.2f} MB of decoded rows never written; "
          f"planner fused {n_fused}/{len(names)} columns")

if args.cost_cache and cost_model is not None:
    cost_model.save(args.cost_cache)
    print(f"\ncost cache: saved {args.cost_cache} "
          f"({len(cost_model.sig_stats)} signatures, "
          f"{cost_model.n_observed} observations)")
