"""ZipFlow-JAX quickstart: compress a column, move it, decompress on device.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import plan as P
from repro.core.compiler import compile_decoder, device_buffers
from repro.core.fusion import fuse
from repro.core.plan import lower

# 1. some TPC-H-shaped data: dates with ~2.5k distinct values
rng = np.random.default_rng(0)
column = rng.integers(8035, 10591, 1_000_000).astype(np.int32)

# 2. a nested plan from the paper's Table 2: dictionary | bit-packing
plan = P.Plan("dictionary", children={"index": P.make_plan("bitpack")})

# 3. compress on the host
enc = P.encode(plan, column)
print(f"plan {plan.describe()}: {enc.plain_nbytes / 1e6:.1f} MB -> "
      f"{enc.compressed_nbytes / 1e6:.2f} MB (ratio {enc.ratio:.1f}x)")

# 4. the compiler lowers the plan to pattern stages and fuses them
stages = lower(enc)
fused = fuse(list(stages))
print(f"stages: {[s.name for s in stages]} -> fused: {[s.name for s in fused]}")

# 4b. ...or to a DecodeGraph: buffer defs + the structural signature that keys the
#     ProgramCache (blobs with equal signatures share ONE jitted program)
graph = P.lower_graph(enc)
print(f"graph: {graph.nesting}, {len(graph.buffers)} transfer buffers, "
      f"signature {graph.signature[:12]}")

# 5. move the compressed buffers and decode on device (pure-jnp backend here;
#    backend='pallas' runs the TPU kernels, interpret=True off-TPU)
decoder = compile_decoder(enc, backend="jnp", fuse=True)
out = decoder(device_buffers(enc))
assert np.array_equal(np.asarray(out), column)
print("device decode matches:", True)

# 6. device-geometry scheduling: the <L,S,C> native config for this chip
from repro.core.geometry import native_config, chip
g = native_config("fp", chip("v5e"))
print(f"v5e Fully-Parallel native config: {g} (tile={g.tile} elems)")
