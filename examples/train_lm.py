"""End-to-end LM training through the compressed data pipeline.

Trains a reduced qwen1.5-family model for a few hundred steps on CPU; tokens move
host->device bit-packed (fixed width) and decompress inside the jitted step prologue.
Demonstrates: ZipFlow loader, AdamW, fault-tolerant loop with compressed
checkpoints, restart-from-checkpoint.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 200]
(The same driver scales to the full config on a TPU slice via
 ``python -m repro.launch.train --arch qwen1.5-0.5b --production-mesh``.)
"""
import argparse
import dataclasses
import tempfile

import jax

from repro.configs import SMOKES
from repro.data.loader import CompressedTokenLoader
from repro.models import get_model
from repro.train import checkpoint as ckpt
from repro.train import optimizer
from repro.train.loop import LoopConfig, run
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import make_train_step

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--seq", type=int, default=128)
ap.add_argument("--d-model", type=int, default=128)
ap.add_argument("--layers", type=int, default=4)
args = ap.parse_args()

cfg = dataclasses.replace(
    SMOKES["qwen1.5-0.5b"], d_model=args.d_model, n_layers=args.layers,
    n_heads=4, n_kv_heads=4, d_ff=args.d_model * 3, vocab=4096)
model = get_model(cfg)
params, _ = model.init(jax.random.PRNGKey(0))
n = sum(int(x.size) for x in jax.tree.leaves(params))
print(f"model: {cfg.name} variant, {n / 1e6:.2f}M params")

opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps)
opt_state = optimizer.init(params)
step = jax.jit(make_train_step(cfg, opt_cfg), donate_argnums=(0, 1))

loader = CompressedTokenLoader(cfg.vocab, args.batch, args.seq)
decode = loader.decode_fn()


def step_with_decode(p, o, bufs):
    # ZipFlow integration: decompression is the first op of the jitted step
    return step(p, o, decode(bufs))


def batch_fn(i):
    return {k: jax.device_put(v) for k, v in loader.encode_host(i).items()}


with tempfile.TemporaryDirectory() as d:
    loop_cfg = LoopConfig(total_steps=args.steps, ckpt_dir=d,
                          ckpt_every=max(args.steps // 4, 10), log_every=20)
    params, opt_state, hist = run(loop_cfg, step_with_decode, params,
                                  opt_state, batch_fn)
    print(f"\nloss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f} over "
          f"{len(hist)} steps")
    print(f"tokens moved compressed: ratio {loader.ratio:.2f}x "
          f"({loader.bytes_compressed / 1e6:.1f} MB vs "
          f"{loader.bytes_plain / 1e6:.1f} MB plain)")
    rep = ckpt.compression_report(d)
    print(f"checkpoint shards: ratio {rep['ratio']:.3f}x")
assert hist[-1]["loss"] < hist[0]["loss"], "training did not learn"
print("OK")
